"""Benchmark harness (one module per paper table; see run.py)."""
