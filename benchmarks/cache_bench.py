"""Route-cache benchmark: hit-rate x qps x p99 on Zipfian near-dup traffic.

  PYTHONPATH=src python -m benchmarks.cache_bench [--smoke] [--out BENCH_cache.json]

Replays the IDENTICAL seeded traffic stream (`repro.traffic`) through a bare
`SemanticRouter` and one fronted by `SemanticRouteCache`, at Zipf exponents
s in {0.8, 1.1, 1.4}, on a 25k-tool corpus (`scale_tool_corpus`) where the
score+top-K path is memory-bound and worth skipping. Queries come from the
metatool-like benchmark's own train split (token-tiled to length 24 so the
bag-encoder direction is preserved exactly while one-token paraphrase jitter
stays inside the cache's cosine threshold), so routing decisions are real
tool resolutions, not noise.

A second leg replays the s=1.1 stream under adversarial churn — hot-set
rotations in the generator plus control-plane table swaps and StageSet
promotions fired between batches — and holds the staleness gate: every
served `(table_version, stage_version)` must lie inside the live version
window around its `route_batch` call (`repro.traffic.drive` checks each
result; the gateway's own tripwire counter must also stay 0).

CI gates (checked AFTER the artifact is written, `--smoke` and full):
  * zero stale-version serves, in every leg;
  * hit-rate on the s=1.1 curve above the floor (warm cache, near-dup
    traffic: misses should be first-sights and paraphrase LSH escapes only);
  * churn-leg p99 within budget x the bare router's p99 on the same
    stream shape (a swap costs the cache its contents, never the batch a
    multi-ms stall).
Full run only (smoke's shorter streams are warm-up dominated):
  * effective qps >= 2x bare at s=1.1;
  * top-1 routing agreement with the bare replay >= 0.98 at s=1.1.

Results land in BENCH_cache.json:
  {"rows": [{zipf_s, hit_rate, qps_cached, qps_bare, speedup, agreement,
             p99_cached_ms, p99_bare_ms, stale_serves, ...}, ...],
   "churn": {...}, "derived": {...}, "gates": {...}}
"""
from __future__ import annotations

import argparse
import contextlib
import gc
import json
import os
from typing import List, Optional

import numpy as np

ZIPF_CURVE = (0.8, 1.1, 1.4)
QUERY_LEN = 24  # tiled intent length: 1-token jitter keeps cosine ~0.958
WARMUP_BATCH_SIZES = (1, 2, 4, 8, 16, 32)  # every pow2 bucket the stream hits


def _corpus(smoke: bool, seed: int):
    """(bench, records, table, encoder) at the bench scale.

    `noise=0.2` is per-dimension, i.e. a perturbation of norm ~3.9 in 384-d:
    clones become inert decoys and top-1 competition stays among the 199
    real tools (the default 0.02 keeps clones at cosine ~0.93 of their
    source, making top-1 a coin flip between clone and original — that
    measures clone degeneracy, not cache agreement).
    """
    from repro.data.benchmarks import make_metatool_like, scale_tool_corpus
    from repro.embedding.bag_encoder import BagEncoder
    from repro.router.tooldb import ToolRecord

    n_tools = 6_000 if smoke else 25_000
    bench = make_metatool_like(seed=seed, n_queries=400)
    enc = BagEncoder(bench.vocab)
    base = enc.encode(bench.desc_tokens)
    table = scale_tool_corpus(base, n_tools, seed=seed, noise=0.2)
    records = [
        ToolRecord(i, f"t{i}", bench.desc_tokens[i % bench.n_tools], 0)
        for i in range(n_tools)
    ]
    return bench, records, table, enc


def _tiled_pool(bench) -> List[np.ndarray]:
    """Train-split queries tiled to QUERY_LEN tokens: tiling a bag of tokens
    scales every count uniformly, so the embedding direction is bit-for-bit
    the original's while paraphrase jitter (drop+append one of 24) is mild."""
    return [
        np.tile(t, -(-QUERY_LEN // len(t)))
        for t in (bench.query_tokens[i] for i in bench.train_idx)
    ]


def _build_router(records, table, enc, cache):
    from repro.router.gateway import SemanticRouter
    from repro.router.tooldb import ToolsDatabase

    db = ToolsDatabase(list(records), table.copy())
    return SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode,
        k=5, metrics=False, cache=cache,
    )


@contextlib.contextmanager
def _nogc():
    """Collector pauses (20-40 ms here) land on arbitrary batches and a
    short stream's p99 is its max — same discipline as pinning warmup."""
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        gc.enable()


def _warm(router, batch, cache=None) -> None:
    """Compile every pow2 miss-bucket shape, then forget the warmup traffic
    (an unwarmed bucket is a multi-ms retrace the p99 would absorb)."""
    for m in WARMUP_BATCH_SIZES:
        router.route_batch(batch[:m])
    if cache is not None:
        cache.clear()


def _curve_point(records, table, enc, pool, zipf_s: float, n_batches: int,
                 seed: int) -> dict:
    """One Zipf exponent: identical stream through bare and cached routers."""
    from repro.cache import CacheConfig, SemanticRouteCache
    from repro.traffic import TrafficConfig, ZipfTrafficGenerator, agreement, drive

    cfg = TrafficConfig(
        zipf_s=zipf_s, pool_size=256, query_len=QUERY_LEN, batch_size=32,
        paraphrase_p=0.35, jitter_tokens=1, seed=seed + 3,
    )
    batches = list(ZipfTrafficGenerator(cfg, pool=pool).stream(n_batches))
    cache = SemanticRouteCache(CacheConfig(threshold=0.95), metrics=False)
    cached = _build_router(records, table, enc, cache)
    bare = _build_router(records, table, enc, None)
    _warm(cached, batches[0], cache)
    _warm(bare, batches[0])
    try:
        with _nogc():
            rep_c = drive(cached, batches, record=True)
        with _nogc():
            rep_b = drive(bare, batches, record=True)
        agr = agreement(rep_c.results, rep_b.results)
    finally:
        cached.close()
        bare.close()
    return {
        "zipf_s": zipf_s,
        "batches": rep_c.batches,
        "queries": rep_c.queries,
        "hit_rate": rep_c.hit_rate,
        "qps_cached": rep_c.qps,
        "qps_bare": rep_b.qps,
        "speedup": rep_c.qps / rep_b.qps if rep_b.qps else 0.0,
        "agreement": agr,
        "p50_cached_ms": rep_c.p50_ms,
        "p99_cached_ms": rep_c.p99_ms,
        "p50_bare_ms": rep_b.p50_ms,
        "p99_bare_ms": rep_b.p99_ms,
        "stale_serves": rep_c.stale_serves + rep_b.stale_serves,
        "stale_examples": rep_c.stale_examples + rep_b.stale_examples,
    }


def _churn_leg(records, table, enc, pool, n_batches: int, swap_every: int,
               seed: int) -> dict:
    """s=1.1 stream with the cache under active attack: generator hot-set
    rotations plus mid-stream control-plane churn (table swap / stage
    promotion / rollback, all CAS'd against the live snapshot). Every swap
    is content-identical — version bumps that MUST invalidate the cache
    without changing what correct routing returns — so any stale serve is
    unambiguously a cache bug, not a routing change."""
    from repro.cache import CacheConfig, SemanticRouteCache
    from repro.traffic import TrafficConfig, ZipfTrafficGenerator, drive

    cfg = TrafficConfig(
        zipf_s=1.1, pool_size=256, query_len=QUERY_LEN, batch_size=32,
        paraphrase_p=0.35, jitter_tokens=1, seed=seed + 3,
        hot_set_rotate_every=max(2 * swap_every, 10),
    )
    batches = list(ZipfTrafficGenerator(cfg, pool=pool).stream(n_batches))
    cache = SemanticRouteCache(CacheConfig(threshold=0.95), metrics=False)
    router = _build_router(records, table, enc, cache)
    _warm(router, batches[0], cache)
    swaps = {"table_swap": 0, "rollback": 0, "stage_swap": 0}

    def churn(i: int) -> None:
        if i == 0 or i % swap_every:
            return
        step = (i // swap_every) % 3
        if step == 0:
            version, live = router.db.snapshot()
            router.db.swap_table(live.copy(), expect_current=version)
            swaps["table_swap"] += 1
        elif step == 1 and len(router.db.retained_versions()) > 0:
            router.db.rollback(expect_current=router.db.table_version)
            swaps["rollback"] += 1
        else:
            sv, stages = router.stage_set()
            router.set_stages(stages, expect_version=sv)
            swaps["stage_swap"] += 1

    try:
        with _nogc():
            rep = drive(router, batches, on_batch=churn)
        tripwire = 0
        if router._obs is not None:  # metrics=False here, but stay robust
            tripwire = int(router._obs.cache_stale.value)
    finally:
        router.close()
    return {
        "batches": rep.batches,
        "queries": rep.queries,
        "hit_rate": rep.hit_rate,
        "qps": rep.qps,
        "p50_ms": rep.p50_ms,
        "p99_ms": rep.p99_ms,
        "stale_serves": rep.stale_serves,
        "stale_examples": rep.stale_examples,
        "tripwire_demotions": tripwire,
        "swap_every": swap_every,
        "hot_set_rotate_every": cfg.hot_set_rotate_every,
        "control_plane_ops": swaps,
        "cache_invalidations": cache.stats["invalidated"],
    }


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_cache.json") -> dict:
    # fail on an unwritable destination BEFORE the minutes of measurement
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    n_batches = 40 if smoke else 150
    curve = (1.1,) if smoke else ZIPF_CURVE
    bench, records, table, enc = _corpus(smoke, seed)
    pool = _tiled_pool(bench)

    rows = []
    for s in curve:
        row = _curve_point(records, table, enc, pool, s, n_batches, seed)
        rows.append(row)
        print(f"zipf s={s:<4} hit={row['hit_rate']:.3f} "
              f"agreement={row['agreement']:.4f} "
              f"speedup={row['speedup']:.2f}x "
              f"p99={row['p99_cached_ms']:.1f}ms (bare {row['p99_bare_ms']:.1f}ms) "
              f"stale={row['stale_serves']}", flush=True)

    churn = _churn_leg(records, table, enc, pool, n_batches,
                       swap_every=8 if smoke else 15, seed=seed)
    print(f"churn       hit={churn['hit_rate']:.3f} p99={churn['p99_ms']:.1f}ms "
          f"ops={churn['control_plane_ops']} "
          f"invalidations={churn['cache_invalidations']} "
          f"stale={churn['stale_serves']}", flush=True)

    s11 = next(r for r in rows if r["zipf_s"] == 1.1)
    derived = {
        "n_tools": len(records),
        "speedup_zipf11": s11["speedup"],
        "agreement_zipf11": s11["agreement"],
        "hit_rate_zipf11": s11["hit_rate"],
        "stale_serves_total": sum(r["stale_serves"] for r in rows)
                              + churn["stale_serves"],
        "churn_p99_over_bare": (churn["p99_ms"] / s11["p99_bare_ms"]
                                if s11["p99_bare_ms"] else 0.0),
        "smoke": smoke,
    }
    # smoke streams are warm-up dominated (first sight of each of the 256
    # intents is an unavoidable miss), so the floors are looser there; the
    # >=2x qps and >=0.98 agreement acceptance gates are full-run contracts
    gates = {
        "zero_stale": derived["stale_serves_total"] == 0,
        "hit_rate_floor": s11["hit_rate"] >= (0.70 if smoke else 0.90),
        "churn_p99_budget": derived["churn_p99_over_bare"] <= 2.5,
    }
    if not smoke:
        gates["speedup_2x"] = s11["speedup"] >= 2.0
        gates["agreement_098"] = s11["agreement"] >= 0.98

    report = {"bench": "route_cache", "rows": rows, "churn": churn,
              "derived": derived, "gates": gates}
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    failed = [g for g, ok in gates.items() if not ok]
    print(f"zipf-1.1: {s11['speedup']:.2f}x qps, "
          f"agreement {s11['agreement']:.4f}, hit {s11['hit_rate']:.3f} | "
          f"churn p99 {derived['churn_p99_over_bare']:.2f}x bare | "
          f"stale {derived['stale_serves_total']} | "
          f"gates: {'FAILED ' + ','.join(failed) if failed else 'ok'} -> {out}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_cache.json")
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke, seed=args.seed, out=args.out)
    return 1 if any(not ok for ok in report["gates"].values()) else 0


if __name__ == "__main__":
    raise SystemExit(main())
