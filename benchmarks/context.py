"""Shared benchmark context: both synthetic benchmarks, all method results,
and the serving-path latency harness (built once, reused by every table)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.evaluate import DEFAULT_METHODS, BenchmarkEvaluator, MethodResult
from repro.data.benchmarks import Benchmark, make_metatool_like, make_toolbench_like
from repro.embedding import transformer as tenc
from repro.embedding.bag_encoder import pad_token_lists
from repro.router.latency import LatencyStats, measure_latency

# Paper numbers for side-by-side reporting (Tables 4/5/6).
PAPER_NDCG5 = {
    "metatool-like": {
        "random": 0.298, "bm25": 0.595, "se": 0.869, "se+lexical": 0.816,
        "oats-s1": 0.940, "oats-s2": 0.869, "oats-s3": 0.931,
    },
    "toolbench-like": {
        "random": 0.692, "bm25": 0.853, "se": 0.834, "se+lexical": 0.854,
        "oats-s1": 0.848, "oats-s2": 0.823, "oats-s3": 0.841,
    },
}
PAPER_R1 = {
    "metatool-like": {"random": 0.096, "bm25": 0.397, "se": 0.716, "oats-s1": 0.830,
                      "oats-s2": 0.716, "oats-s3": 0.810, "se+lexical": 0.640},
    "toolbench-like": {"random": 0.238, "bm25": 0.392, "se": 0.382, "oats-s1": 0.381,
                       "oats-s2": 0.372, "oats-s3": 0.387, "se+lexical": 0.388},
}


@dataclasses.dataclass
class BenchContext:
    benches: Dict[str, Benchmark]
    evaluators: Dict[str, BenchmarkEvaluator]
    results: Dict[str, Dict[str, MethodResult]]
    latency: Dict[str, Dict[str, LatencyStats]]

    @classmethod
    def build(
        cls,
        methods=DEFAULT_METHODS,
        seed: int = 0,
        fast: bool = False,
        latency_requests: int = 120,
        verbose: bool = True,
    ) -> "BenchContext":
        if fast:
            benches = {
                "metatool-like": make_metatool_like(seed, n_tools=120, n_queries=1000),
                "toolbench-like": make_toolbench_like(seed, n_tools=600, n_queries=300),
            }
        else:
            benches = {
                "metatool-like": make_metatool_like(seed),
                "toolbench-like": make_toolbench_like(seed),
            }
        evaluators, results = {}, {}
        for name, b in benches.items():
            t0 = time.time()
            ev = BenchmarkEvaluator(b, seed=seed)
            res = {m: ev.rankings_for(m) for m in methods}
            evaluators[name], results[name] = ev, res
            if verbose:
                print(f"# built {name}: {time.time() - t0:.1f}s", flush=True)
        ctx = cls(benches=benches, evaluators=evaluators, results=results, latency={})
        ctx._measure_latencies(latency_requests, verbose)
        return ctx

    # ---- serving-path latency (Tables 1 & 6 protocol, §5.5) --------------
    def _measure_latencies(self, n_requests: int, verbose: bool):
        """Per-request p50/p99 over: MiniLM-shaped encoder forward (22M params,
        the production encoder cost) + similarity + top-K (+ stage extras)."""
        enc_params = tenc.init_encoder(jax.random.PRNGKey(0))
        for name, bench in self.benches.items():
            ev = self.evaluators[name]
            test = bench.test_idx[:n_requests]
            tokens = [bench.query_tokens[i] for i in test]
            ids, mask = pad_token_lists(tokens, max_len=16)
            stats: Dict[str, LatencyStats] = {}

            def make_serve(table, extra=None):
                def serve(i):
                    q = np.asarray(
                        tenc.encode(enc_params, ids[i : i + 1], mask[i : i + 1])
                    )[0]
                    sims = table @ q
                    top = np.argpartition(-sims, 5)[:5]
                    if extra is not None:
                        extra(i, q, sims, top)
                    return top

                return serve

            # BM25 (lexical only, no encoder forward)
            bm = ev._bm25
            stats["bm25"] = measure_latency(
                lambda i: bm.scores([tokens[i]])[0].argsort()[-5:], len(test)
            )
            stats["se"] = measure_latency(make_serve(ev.tool_emb), len(test))
            s1 = self.results[name]["oats-s1"].pipeline
            stats["oats-s1"] = measure_latency(make_serve(s1.tool_table), len(test))
            # S2/S3 pay the same encoder forward + the re-rank (+adapter) extras
            q_embs = ev.query_emb[test]

            def make_stage(pipe):
                def serve(i):
                    _ = np.asarray(
                        tenc.encode(enc_params, ids[i : i + 1], mask[i : i + 1])
                    )
                    return pipe.rank([tokens[i]], 5, query_emb=q_embs[i : i + 1])

                return serve

            s2 = self.results[name]["oats-s2"].pipeline
            stats["oats-s2"] = measure_latency(make_stage(s2), len(test))
            s3 = self.results[name]["oats-s3"].pipeline
            stats["oats-s3"] = measure_latency(make_stage(s3), len(test))
            self.latency[name] = stats
            if verbose:
                p = {k: round(v.p50_ms, 2) for k, v in stats.items()}
                print(f"# latency p50 ms ({name}): {p}", flush=True)
