"""Control-plane benchmark: refinement quality over time + serving latency
under concurrent table swaps.

  PYTHONPATH=src python -m benchmarks.control_bench [--smoke] [--out BENCH_control.json]

Two measurements, recorded into BENCH_control.json:

1. **NDCG@5 over time** (metatool-like, 199 tools): outcomes stream into the
   `OutcomeStore` window by window; after every `RefinementController.step`
   the held-out NDCG@5 of the *live* table is measured through the actual
   router. The series shows the §7.2 loop converting traffic into retrieval
   quality with no serving-path changes.

2. **p99 route latency during swaps** (toolbench-like, 2,413 tools): a
   churn thread calls `swap_table` continuously while the foreground times
   batched `route_batch` calls — the worst case for the router's
   version-keyed device cache, which must re-upload the table on every
   version change. Reported against the paper's 10 ms budget, next to a
   churn-free baseline on the same router.

`scripts/ci_check.sh` smoke-runs this module; any controller/gate/guard
exception fails CI, keeping the loop runnable end-to-end.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

BUDGET_MS = 10.0


def _build(bench, store_capacity=200_000, **router_kw):
    from repro.control import OutcomeStore
    from repro.embedding.bag_encoder import BagEncoder
    from repro.router.gateway import SemanticRouter
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    enc = BagEncoder(bench.vocab)
    db = ToolsDatabase(
        [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
         for i in range(bench.n_tools)],
        enc.encode(bench.desc_tokens),
    )
    store = OutcomeStore(n_tools=len(db), capacity=store_capacity)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append, **router_kw,
    )
    return enc, db, store, router


def bench_ndcg_over_time(smoke: bool, seed: int) -> dict:
    from repro.control import (
        ControllerConfig, GuardConfig, RefinementController, TableGuard,
    )
    from repro.data.benchmarks import make_metatool_like
    from repro.metrics.retrieval import ndcg_at_k

    n_queries = 800 if smoke else 2400
    n_windows = 3 if smoke else 6
    bench = make_metatool_like(seed=seed, n_queries=n_queries)
    enc, db, store, router = _build(bench)
    guard = TableGuard(db, GuardConfig(min_samples=32))
    controller = RefinementController(
        db, store, enc.encode, routers=[router],
        config=ControllerConfig(min_events=200 if smoke else 1000, min_queries=30),
        guard=guard,
    )
    eval_idx = bench.test_idx[: 150 if smoke else 400]

    def heldout_ndcg():
        results = router.route_batch([bench.query_tokens[qi] for qi in eval_idx])
        return float(np.mean([
            ndcg_at_k(r.tools, bench.relevant[qi], 5)
            for qi, r in zip(eval_idx, results)
        ]))

    series = [{"events": 0, "table_version": db.table_version,
               "ndcg_at_5": heldout_ndcg()}]
    for idx in np.array_split(bench.train_idx, n_windows):
        for lo in range(0, len(idx), 64):
            chunk = idx[lo : lo + 64]
            results = router.route_batch([bench.query_tokens[qi] for qi in chunk])
            for qi, res in zip(chunk, results):
                for t in res.tools:
                    router.record_outcome(
                        bench.query_tokens[qi], t, int(t in bench.relevant[qi])
                    )
                guard.observe(res.table_version, res.tools, bench.relevant[qi])
        report = controller.step()
        series.append({
            "events": store.total_ingested,
            "table_version": report.table_version,
            "swapped": report.swapped,
            "ndcg_at_5": heldout_ndcg(),
        })
        print(f"  events={store.total_ingested:6d} v{report.table_version} "
              f"{'SWAP' if report.swapped else '----'} "
              f"ndcg@5={series[-1]['ndcg_at_5']:.3f}", flush=True)
    return {
        "table": bench.name,
        "n_tools": bench.n_tools,
        "series": series,
        "ndcg_initial": series[0]["ndcg_at_5"],
        "ndcg_final": series[-1]["ndcg_at_5"],
        "n_swaps": sum(1 for s in series if s.get("swapped")),
    }


def bench_latency_under_churn(smoke: bool, seed: int) -> dict:
    from repro.data.benchmarks import make_toolbench_like
    from repro.router.latency import percentile_stats

    bench = make_toolbench_like(seed=seed, n_queries=128 if smoke else 600)
    enc, db, store, router = _build(bench)
    queries = list(bench.query_tokens)
    batch_size = 64
    n_calls = 12 if smoke else 64

    def timed_pass():
        samples = []
        for i in range(2):  # warmup / compile
            router.route_batch(queries[:batch_size])
        for i in range(n_calls):
            qs = [queries[(i * batch_size + j) % len(queries)]
                  for j in range(batch_size)]
            t0 = time.perf_counter()
            router.route_batch(qs)
            samples.append((time.perf_counter() - t0) * 1e3 / batch_size)
        return percentile_stats(samples)

    quiet = timed_pass()

    # churn thread: continuous valid swaps (jittered copies of the original
    # table) — every foreground batch potentially sees a new version and
    # must re-snapshot + re-upload the device table
    stop = threading.Event()
    n_swaps = [0]
    rng = np.random.default_rng(seed)
    base = db.embeddings.copy()
    jittered = base + rng.normal(scale=1e-3, size=base.shape).astype(np.float32)
    jittered /= np.maximum(
        np.linalg.norm(jittered, axis=-1, keepdims=True), 1e-9
    )

    def churn():
        tables = [jittered, base]
        while not stop.is_set():
            db.swap_table(tables[n_swaps[0] % 2])
            n_swaps[0] += 1
            time.sleep(0.002)

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        churned = timed_pass()
    finally:
        stop.set()
        t.join()
    return {
        "table": bench.name,
        "n_tools": bench.n_tools,
        "batch_size": batch_size,
        "n_calls": n_calls,
        "no_churn": quiet.as_dict(),
        "under_churn": churned.as_dict(),
        "n_swaps_during_run": n_swaps[0],
        "budget_ms": BUDGET_MS,
    }


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_control.json") -> dict:
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)
    print("[1/2] NDCG@5 over streamed outcomes", flush=True)
    ndcg = bench_ndcg_over_time(smoke, seed)
    print("[2/2] route_batch p99 under concurrent table swaps", flush=True)
    churn = bench_latency_under_churn(smoke, seed)
    p99 = churn["under_churn"]["p99_ms"]
    report = {
        "bench": "control_plane",
        "ndcg_over_time": ndcg,
        "latency_under_churn": churn,
        "derived": {
            "ndcg_gain": ndcg["ndcg_final"] - ndcg["ndcg_initial"],
            "p99_under_churn_ms": p99,
            "p99_within_budget": p99 <= BUDGET_MS,
        },
        "smoke": smoke,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"ndcg@5 {ndcg['ndcg_initial']:.3f} -> {ndcg['ndcg_final']:.3f} "
          f"over {ndcg['n_swaps']} swaps | p99/query under churn "
          f"{p99:.3f}ms across {churn['n_swaps_during_run']} swaps "
          f"(budget {BUDGET_MS}ms, quiet p99 "
          f"{churn['no_churn']['p99_ms']:.3f}ms) -> {out}")
    if not report["derived"]["p99_within_budget"]:
        raise SystemExit(
            f"p99 under churn {p99:.3f}ms exceeds the {BUDGET_MS}ms budget"
        )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_control.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
