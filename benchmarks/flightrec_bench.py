"""Flight-recorder smoke: an incident produces exactly one usable dump.

  PYTHONPATH=src python -m benchmarks.flightrec_bench [--smoke] [--out BENCH_flightrec.json]

Three acceptance gates, all enforced with SystemExit (CI smoke-runs this
via scripts/ci_check.sh):

1. **Breach dump**: the full telemetry plane serves live traffic while an
   injected embed latency burns the second-scale latency SLO. The armed
   `FlightRecorder` must write exactly ONE dump for the whole incident
   storm — the ``slo_burn`` trigger dumps, a follow-on ``rollback``
   published inside the debounce window is suppressed — with version
   stamps matching the live (table_version, stage_version) composition,
   >=1 dumped trace carrying the same stamps (including the burn event's
   p99 exemplar), and a ``repro-obs replay`` rendering that names the
   trigger. Nothing may dump during the healthy window.

2. **Crash dump**: a `RefinementController` daemon whose step raises on
   every iteration must produce exactly one crash dump (debounce absorbs
   the crash loop AND the bus-side ``loop_error``), naming the source.

3. **Recorder overhead**: arming a recorder adds a bus subscription and
   zero per-batch work — serving qps with an armed recorder must stay
   within the 5 % obs budget of the identical un-armed stack, measured
   with the same slice-interleaved paired rounds as obs_bench.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.obs_bench import OVERHEAD_BUDGET, _timed_pair
from benchmarks.slo_bench import _build_router, _fetch, _serve_thread, _wait_for

BATCH = 16
TICK_S = 0.25  # ring cadence: every tick also evaluates the SLO engine
SLOW_EMBED_S = 0.015  # injected per-batch embed latency (> the 10 ms budget)
DEBOUNCE_S = 60.0  # one incident window: the whole scenario fits inside


def _blocks(bench, batch=BATCH, n=4):
    return [
        [bench.query_tokens[qi] for qi in bench.train_idx[lo : lo + batch]]
        for lo in range(0, batch * n, batch)
    ]


def run_breach(bench, enc, smoke: bool, seed: int) -> dict:
    """Gate 1: latency injection -> slo_burn -> exactly one debounced dump."""
    from repro.obs import (
        SLO,
        BurnWindow,
        EventBus,
        FlightRecorder,
        JitProfiler,
        MetricsRegistry,
        QualityMonitor,
        RouteTracer,
        SLOEngine,
        TimeSeriesRing,
        list_dumps,
        load_dump,
        render_replay,
    )
    from repro.obs.report import main as report_main

    registry = MetricsRegistry()
    bus = EventBus()
    tracer = RouteTracer(sample_every=1, seed=seed)
    quality = QualityMonitor(registry=registry, bus=bus)

    delay = {"s": 0.0}  # mutable latency injection knob, read per batch

    def slow_embed(tokens):
        if delay["s"]:
            time.sleep(delay["s"])
        return enc.encode(tokens)

    db, router = _build_router(
        bench, enc, registry, tracer=tracer, bus=bus, quality=quality,
        embed_batch_fn=slow_embed,
    )
    # second-scale SLO, objective 0.90 — same shape as slo_bench's burn
    slo = SLO(
        name="route_latency_budget",
        kind="latency",
        hist_key="route_batch_ms",
        threshold_ms=10.0,
        objective=0.90,
        windows=(BurnWindow(long_s=2.0, short_s=0.6, factor=1.0),),
    )
    ring = TimeSeriesRing(registry, bus=bus)
    engine = SLOEngine(ring, slos=(slo,), bus=bus, registry=registry)
    profiler = JitProfiler(registry=registry)
    dump_dir = tempfile.mkdtemp(prefix="flightrec-bench-")
    recorder = FlightRecorder(
        dump_dir, bus=bus, registry=registry, tracer=tracer, ring=ring,
        slo=engine, profiler=profiler, routers=[router],
        debounce_s=DEBOUNCE_S,
    )

    blocks = _blocks(bench)
    for b in blocks:  # jit warmup off the ring, so the first window is clean
        router.route_batch(b)
    profiler.collect()  # baseline the warmup compiles

    ring.start(interval_s=TICK_S,
               on_tick=lambda _r: (profiler.collect(), engine.evaluate()))
    stop, t, serve_errors = _serve_thread(router, blocks)
    try:
        # healthy window: the armed recorder must stay silent
        time.sleep(1.2)
        if recorder.dumps_written != 0:
            raise SystemExit(
                f"recorder dumped on healthy traffic: "
                f"{[d.manifest['reason'] for d in recorder.list()]}"
            )

        # breach: every batch now pays >10 ms in embed
        delay["s"] = SLOW_EMBED_S
        burn_ev = _wait_for(lambda: bus.last("slo_burn"), 20.0,
                            "slo_burn after latency injection")
        _wait_for(lambda: recorder.dumps_written >= 1, 10.0,
                  "the slo_burn dump")
        # the rest of the incident storm lands inside the debounce window:
        # suppressed, not double-dumped
        bus.publish("rollback", plane="control",
                    condemned_version=db.table_version)
        if recorder.dumps_written != 1 or recorder.dumps_suppressed < 1:
            raise SystemExit(
                f"debounce failed: written={recorder.dumps_written} "
                f"suppressed={recorder.dumps_suppressed} (want exactly 1 "
                f"dump, >=1 suppressed)"
            )
        delay["s"] = 0.0
    finally:
        # the serve.py signal order: recorder first, then the daemons —
        # teardown publishes must not masquerade as incidents
        recorder.stop()
        stop.set()
        t.join(timeout=30)
        ring.stop()

    if serve_errors:
        raise SystemExit(f"serving thread failed during the breach smoke: "
                         f"{serve_errors[0]!r}")
    if ring.last_loop_error is not None:
        raise SystemExit(f"ring daemon flapped: {ring.last_loop_error}")

    dumps = list_dumps(dump_dir)
    if len(dumps) != 1:
        raise SystemExit(f"expected exactly one dump, found "
                         f"{[d.name for d in dumps]}")
    [dump] = dumps
    m = dump.manifest
    if m["reason"] != "slo_burn" or m["trigger"]["kind"] != "slo_burn":
        raise SystemExit(f"dump not attributed to the burn: reason="
                         f"{m['reason']} trigger={m['trigger']}")
    # version stamps must match the live serving composition
    stage_version, _stages = router.stage_set()
    [serving] = m["serving"]
    if (serving["table_version"] != db.table_version
            or serving["stage_version"] != stage_version):
        raise SystemExit(
            f"dump mis-stamped: {serving} (live table v{db.table_version}, "
            f"stage v{stage_version})"
        )
    if m["n_traces"] < 1:
        raise SystemExit("dump carries no traces (tracer samples every batch)")
    d = load_dump(dump.path)
    for tr in d["traces"]:
        if tr["table_version"] != db.table_version:
            raise SystemExit(f"dumped trace #{tr['trace_id']} stamped "
                             f"v{tr['table_version']} != live "
                             f"v{db.table_version}")
    # the burn's p99 exemplar resolves INSIDE the dump — the postmortem
    # never needs the (dead) process that produced it
    exemplar = burn_ev.details.get("p99_exemplar")
    if exemplar is None:
        raise SystemExit(f"slo_burn carries no p99 exemplar: {burn_ev.details}")
    if not any(tr["trace_id"] == exemplar for tr in d["traces"]):
        raise SystemExit(f"p99 exemplar trace #{exemplar} not in the dump's "
                         f"{len(d['traces'])} traces")
    text = render_replay(dump.path)
    if "reason: slo_burn" not in text or "trace #" not in text:
        raise SystemExit(f"replay rendering incomplete:\n{text[:400]}")
    rc = report_main(["replay", dump_dir])
    if rc != 0:
        raise SystemExit(f"repro-obs replay exited {rc} on {dump_dir}")

    row = {
        "dumps_written": recorder.dumps_written,
        "dumps_suppressed": recorder.dumps_suppressed,
        "reason": m["reason"],
        "serving": serving,
        "n_traces": m["n_traces"],
        "artifacts": m["artifacts"],
        "p99_exemplar": int(exemplar),
        "burn_details": dict(burn_ev.details),
        "replay_lines": text.count("\n"),
    }
    print(f"breach: 1 dump ({m['name']}), {recorder.dumps_suppressed} "
          f"suppressed | {m['n_traces']} traces incl. exemplar "
          f"#{exemplar} | replay {row['replay_lines']} lines", flush=True)
    router.close()
    shutil.rmtree(dump_dir, ignore_errors=True)
    return row


def run_crash(bench, enc, smoke: bool, seed: int) -> dict:
    """Gate 2: a crashing controller daemon -> exactly one crash dump."""
    from repro.control import ControllerConfig, OutcomeStore, RefinementController
    from repro.obs import EventBus, FlightRecorder, MetricsRegistry

    registry = MetricsRegistry()
    bus = EventBus()
    db, router = _build_router(bench, enc, registry, bus=bus)
    store = OutcomeStore(n_tools=len(db), capacity=64)
    dump_dir = tempfile.mkdtemp(prefix="flightrec-bench-crash-")
    recorder = FlightRecorder(dump_dir, bus=bus, registry=registry,
                              routers=[router], debounce_s=DEBOUNCE_S)
    controller = RefinementController(
        db, store, enc.encode, routers=[router],
        config=ControllerConfig(min_events=10**9, max_interval_s=10**9),
        bus=bus, flight_recorder=recorder,
    )

    def boom():
        raise RuntimeError("flightrec-bench injected daemon crash")

    controller.step = boom
    controller.start(interval_s=0.01)
    try:
        _wait_for(lambda: recorder.dumps_written >= 1, 10.0,
                  "the crash dump")
        time.sleep(0.1)  # the loop keeps crashing; debounce must absorb it
    finally:
        controller.stop()
        recorder.stop()

    dumps = recorder.list()
    if len(dumps) != 1:
        raise SystemExit(f"crash loop produced {len(dumps)} dumps "
                         f"(debounce must collapse it to one)")
    m = dumps[0].manifest
    if (m["reason"] != "crash"
            or m["trigger"]["source"] != "RefinementController"
            or "injected daemon crash" not in m["trigger"]["error"]):
        raise SystemExit(f"crash dump mis-attributed: {m['trigger']}")
    if bus.last("loop_error") is None:
        raise SystemExit("controller crash never reached the bus")

    row = {
        "dumps_written": recorder.dumps_written,
        "dumps_suppressed": recorder.dumps_suppressed,
        "trigger": dict(m["trigger"]),
    }
    print(f"crash: 1 dump from {m['trigger']['source']} "
          f"({m['trigger']['error_type']}), "
          f"{recorder.dumps_suppressed} suppressed", flush=True)
    router.close()
    shutil.rmtree(dump_dir, ignore_errors=True)
    return row


def run_recorder_overhead(bench, enc, smoke: bool, seed: int) -> dict:
    """Gate 3: armed vs un-armed recorder on otherwise identical stacks."""
    from repro.obs import (
        EventBus,
        FlightRecorder,
        MetricsRegistry,
        QualityMonitor,
        RouteTracer,
    )

    def build(armed: bool):
        registry = MetricsRegistry()
        bus = EventBus()
        tracer = RouteTracer(sample_every=64, seed=seed)
        quality = QualityMonitor(registry=registry, bus=bus)
        db, router = _build_router(bench, enc, registry, tracer=tracer,
                                   bus=bus, quality=quality)
        recorder = None
        if armed:
            recorder = FlightRecorder(
                tempfile.mkdtemp(prefix="flightrec-bench-ovh-"), bus=bus,
                registry=registry, tracer=tracer, routers=[router],
                debounce_s=DEBOUNCE_S,
            )
        return router, recorder

    unarmed, _ = build(armed=False)
    armed, recorder = build(armed=True)
    blocks = _blocks(bench, batch=64)
    for b in blocks:  # jit warmup
        unarmed.route_batch(b)
        armed.route_batch(b)

    n_calls = 32 if smoke else 48
    rounds = 7
    ratios, qps_un_all, qps_arm_all = [], [], []
    for _ in range(rounds):
        qps_un, qps_arm = _timed_pair(unarmed, armed, blocks, n_calls)
        qps_un_all.append(qps_un)
        qps_arm_all.append(qps_arm)
        ratios.append(qps_arm / qps_un)
    # same dual-estimator gate as obs_bench: a real cost breaches both the
    # peak-vs-peak and the paired-median statistics
    ratio_peak = float(max(qps_arm_all) / max(qps_un_all))
    ratio_median = float(np.median(ratios))
    overhead = 1.0 - max(ratio_peak, ratio_median)

    if recorder.dumps_written != 0:
        raise SystemExit(f"recorder dumped during the overhead measurement "
                         f"({recorder.dumps_written}) — the gate is void")
    row = {
        "n_calls_per_round": n_calls,
        "rounds": rounds,
        "qps_unarmed_peak": float(max(qps_un_all)),
        "qps_armed_peak": float(max(qps_arm_all)),
        "qps_ratio_peak": ratio_peak,
        "qps_ratio_median": ratio_median,
        "overhead_frac": overhead,
        "overhead_budget": OVERHEAD_BUDGET,
    }
    print(f"recorder overhead: peak {100 * (1.0 - ratio_peak):+.2f}% / "
          f"paired-median {100 * (1.0 - ratio_median):+.2f}% -> gate "
          f"{100 * overhead:+.2f}% (budget {100 * OVERHEAD_BUDGET:.0f}%)",
          flush=True)
    recorder.stop()
    shutil.rmtree(recorder.out_dir, ignore_errors=True)
    unarmed.close()
    armed.close()
    return row


def run(smoke: bool = False, seed: int = 0,
        out: str = "BENCH_flightrec.json") -> dict:
    from repro.data.benchmarks import make_metatool_like
    from repro.embedding.bag_encoder import BagEncoder

    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    bench = make_metatool_like(seed=seed, n_tools=64 if smoke else 199,
                               n_queries=256 if smoke else 600)
    enc = BagEncoder(bench.vocab)
    breach = run_breach(bench, enc, smoke, seed)
    crash = run_crash(bench, enc, smoke, seed)
    overhead = run_recorder_overhead(bench, enc, smoke, seed)
    report = {
        "bench": "flightrec",
        "breach": breach,
        "crash": crash,
        "overhead": overhead,
        "derived": {
            "breach_dumps": breach["dumps_written"],
            "breach_suppressed": breach["dumps_suppressed"],
            "crash_dumps": crash["dumps_written"],
            "recorder_overhead_frac": overhead["overhead_frac"],
            "overhead_budget": OVERHEAD_BUDGET,
            "smoke": smoke,
        },
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"flightrec smoke: breach->1 dump, crash->1 dump, recorder "
          f"overhead {100 * overhead['overhead_frac']:+.2f}% "
          f"(budget {100 * OVERHEAD_BUDGET:.0f}%) -> {out}")
    # the overhead gate runs LAST so the artifact is always written for
    # inspection before a violation exits nonzero
    if overhead["overhead_frac"] > OVERHEAD_BUDGET:
        raise SystemExit(
            f"armed recorder overhead {100 * overhead['overhead_frac']:.2f}% "
            f"exceeds the {100 * OVERHEAD_BUDGET:.0f}% budget on both "
            f"estimators (peak ratio {overhead['qps_ratio_peak']:.4f}, "
            f"paired-median ratio {overhead['qps_ratio_median']:.4f})"
        )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_flightrec.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
