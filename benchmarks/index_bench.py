"""Tool-index benchmark: backend qps + p99/query at MCP-registry scale.

  PYTHONPATH=src python -m benchmarks.index_bench [--smoke] [--out BENCH_index.json]

Scales the real toolbench-like table (2,413 tools, BagEncoder embeddings)
to 25k/50k/100k entries with `data.benchmarks.scale_tool_corpus`, builds
each `repro.index` backend over the scaled snapshot, and measures batched
top-5 scoring (batch 64, the gateway's hot-path shape) against the paper's
10 ms/query budget. IVF additionally reports Recall@5 vs the exact dense
oracle at its default `nprobe`.

Acceptance gates recorded in BENCH_index.json `derived` (full run, 100k):
IVF p99/query under the 10 ms budget, >= 3x qps over DenseBackend, and
Recall@5 >= 0.98 vs exact. The smoke run (CI) applies the p99 budget and
recall gates at 25k and exits nonzero on violation.

`pallas` on this CPU container serves the kernel's jnp reference path
(identical numerics to dense; the kernel itself is validated in
tests/test_kernels.py via interpret mode) — on a TPU-backed router the same
backend dispatches the fused Pallas kernel.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

BUDGET_MS = 10.0
RECALL_FLOOR = 0.98
QPS_FLOOR = 3.0  # IVF vs dense at the largest (full-run) scale
BATCH = 64
K = 5
SCALES_FULL = (25_000, 50_000, 100_000)
SCALES_SMOKE = (25_000,)
BACKENDS = ("dense", "ivf", "pallas")


def _timed_backend(backend, q_blocks, n_calls: int, warmup: int = 2) -> dict:
    from repro.router.latency import percentile_stats

    for i in range(warmup):
        backend.topk(q_blocks[i % len(q_blocks)], K)
    call_ms = []
    t_all = time.perf_counter()
    for i in range(n_calls):
        t0 = time.perf_counter()
        backend.topk(q_blocks[i % len(q_blocks)], K)
        call_ms.append((time.perf_counter() - t0) * 1e3)
    wall_s = time.perf_counter() - t_all
    stats = percentile_stats(np.asarray(call_ms) / BATCH)
    return {
        "n_calls": n_calls,
        "batch_size": BATCH,
        "p50_ms_per_query": stats.p50_ms,
        "p99_ms_per_query": stats.p99_ms,
        "mean_ms_per_query": stats.mean_ms,
        "qps": float(n_calls * BATCH / wall_s),
    }


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_index.json") -> dict:
    from repro.data.benchmarks import make_toolbench_like, scale_tool_corpus
    from repro.embedding.bag_encoder import BagEncoder
    from repro.index import build_backend

    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    bench = make_toolbench_like(seed=seed, n_queries=128 if smoke else 256)
    enc = BagEncoder(bench.vocab)
    base_table = enc.encode(bench.desc_tokens)
    queries = enc.encode(bench.query_tokens)
    n_blocks = max(len(queries) // BATCH, 1)
    q_blocks = [queries[i * BATCH : (i + 1) * BATCH] for i in range(n_blocks)]
    q_blocks = [b for b in q_blocks if len(b) == BATCH] or [queries[:BATCH]]

    scales = SCALES_SMOKE if smoke else SCALES_FULL
    n_calls = 4 if smoke else 12
    rows = []
    by_key = {}
    for scale in scales:
        table = scale_tool_corpus(base_table, scale, seed=seed)
        exact_top = None  # dense runs first: the recall oracle for IVF
        for kind in BACKENDS:
            t0 = time.perf_counter()
            backend = build_backend(kind, table, table_version=0)
            build_s = time.perf_counter() - t0
            row = _timed_backend(backend, q_blocks, n_calls)
            row.update(backend=kind, n_tools=scale, build_s=round(build_s, 3))
            if kind == "dense":
                _, exact_top = backend.topk(queries, K)
            if kind == "ivf":
                _, ivf_top = backend.topk(queries, K)
                row["recall_at_5_vs_exact"] = float(np.mean([
                    len(set(exact_top[j]) & set(ivf_top[j])) / K
                    for j in range(len(queries))
                ]))
                row["nprobe"] = backend.config.nprobe
                row["n_clusters"] = backend.n_clusters
            rows.append(row)
            by_key[(scale, kind)] = row
            extra = (f" recall@5={row['recall_at_5_vs_exact']:.4f}"
                     if kind == "ivf" else "")
            print(f"T={scale:6d} {kind:6s} build={build_s:6.1f}s "
                  f"p50={row['p50_ms_per_query']:.3f}ms "
                  f"p99={row['p99_ms_per_query']:.3f}ms "
                  f"qps={row['qps']:.0f}{extra}", flush=True)

    top_scale = scales[-1]
    ivf = by_key[(top_scale, "ivf")]
    dense = by_key[(top_scale, "dense")]
    derived = {
        "scale": top_scale,
        "ivf_p99_ms_per_query": ivf["p99_ms_per_query"],
        "ivf_qps_over_dense": ivf["qps"] / dense["qps"],
        "ivf_recall_at_5_vs_exact": ivf["recall_at_5_vs_exact"],
        "latency_budget_ms": BUDGET_MS,
        "recall_floor": RECALL_FLOOR,
        "smoke": smoke,
    }
    report = {"bench": "tool_index_backends", "rows": rows, "derived": derived}
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"T={top_scale}: ivf p99/query {ivf['p99_ms_per_query']:.3f}ms "
          f"(budget {BUDGET_MS}ms) | {derived['ivf_qps_over_dense']:.1f}x dense qps | "
          f"recall@5 {ivf['recall_at_5_vs_exact']:.4f} -> {out}")
    if ivf["p99_ms_per_query"] > BUDGET_MS:
        raise SystemExit(
            f"IVF p99/query {ivf['p99_ms_per_query']:.3f}ms exceeds the "
            f"{BUDGET_MS}ms budget at {top_scale} tools"
        )
    if ivf["recall_at_5_vs_exact"] < RECALL_FLOOR:
        raise SystemExit(
            f"IVF Recall@5 {ivf['recall_at_5_vs_exact']:.4f} below the "
            f"{RECALL_FLOOR} floor at {top_scale} tools"
        )
    # the qps gate only binds at full scale: at the 25k smoke scale dense is
    # still fast enough that the ratio is legitimately small
    if not smoke and derived["ivf_qps_over_dense"] < QPS_FLOOR:
        raise SystemExit(
            f"IVF qps only {derived['ivf_qps_over_dense']:.2f}x dense at "
            f"{top_scale} tools (acceptance floor {QPS_FLOOR}x)"
        )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_index.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
