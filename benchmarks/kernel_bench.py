"""Kernel micro-benchmarks: jnp reference wall-clock on CPU + the shapes the
TPU kernel is tiled for. (Pallas interpret mode is a correctness harness, not
a performance one, so we report the reference path's CPU numbers and the
kernels' VMEM working-set as the derived metrics.)"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.topk_sim.ref import topk_sim_ref
from repro.kernels.topk_sim.kernel import BLOCK_Q, BLOCK_T


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def kernel_rows() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    # topk_sim at both paper scales
    f = jax.jit(lambda q, t: topk_sim_ref(q, t, 5))
    for t_tools in (199, 2413):
        q = jnp.asarray(rng.normal(size=(1, 384)).astype(np.float32))
        t = jnp.asarray(rng.normal(size=(t_tools, 384)).astype(np.float32))
        us = _time(f, q, t)
        vmem_kb = (BLOCK_Q * 512 + BLOCK_T * 512 + 2 * BLOCK_Q * 32) * 4 / 1024
        rows.append({
            "name": f"kernel/topk_sim/T{t_tools}",
            "us_per_call": round(us, 1),
            "derived": {"tools": t_tools, "kernel_vmem_kb": round(vmem_kb, 1)},
        })
    # flash attention reference at a prefill tile
    fa = jax.jit(lambda q, k, v: attention_ref(q, k, v, True, 0, 0))
    q = jnp.asarray(rng.normal(size=(8, 512, 128)).astype(np.float32))
    us = _time(fa, q, q, q, iters=3)
    rows.append({
        "name": "kernel/flash_attention/ref_bh8_s512_hd128",
        "us_per_call": round(us, 1),
        "derived": {"flops": 2 * 2 * 8 * 512 * 512 * 128},
    })
    return rows
