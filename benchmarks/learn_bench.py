"""Learning-plane benchmark: stage quality across outcome density + serving
latency with every learned stage active.

  PYTHONPATH=src python -m benchmarks.learn_bench [--smoke] [--out BENCH_learn.json]

Two measurements, recorded into BENCH_learn.json:

1. **Density sweep** (metatool-like, 600 tools, outcome volume varied at
   fixed tool count): at each density point the streamed outcome window is
   frozen (`build_train_window`) and three configurations are trained from
   it and scored on the held-out test split — refine-only
   (`refine_with_gate`, the §4.1 always-on stage), +adapter
   (`AdapterTrainer`, query-side §4.3 head over the refined table), and
   +reranker (`RerankerTrainer`, the §4.2 MLP). The sweep is the paper's
   §7.3 table as measurement: the re-ranker's raw curve shows it *hurting*
   in the sparse regime, which is exactly what `recommend_stages` (also
   recorded per point) exists to prevent. A gated-promotion pass then
   replays the LearningController's decision rule (plan veto + held-out
   val gate) and the resulting config must not regress test NDCG@5 vs
   refine-only — a regressing promotion fails CI here.

2. **p99 route latency, all stages active** (toolbench-like, 2,413 tools):
   batched `route_batch` with a StageSet carrying both the adapter head and
   the MLP re-ranker, against the paper's 10 ms budget, next to the
   stage-free baseline on the same router. Exceeding the budget fails CI.

`scripts/ci_check.sh` smoke-runs this module via `benchmarks.run`.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

BUDGET_MS = 10.0
REGRESSION_TOL = 0.02  # allowed test-NDCG slack for a gated promotion


def _serve_and_log(router, bench, idx, batch_size=64):
    for lo in range(0, len(idx), batch_size):
        chunk = idx[lo : lo + batch_size]
        results = router.route_batch([bench.query_tokens[qi] for qi in chunk])
        for qi, res in zip(chunk, results):
            for t in res.tools:
                router.record_outcome(
                    bench.query_tokens[qi], t, int(t in bench.relevant[qi])
                )


def bench_density_sweep(smoke: bool, seed: int) -> dict:
    import jax.numpy as jnp

    from repro.control import OutcomeStore
    from repro.core.deployment import recommend_stages
    from repro.core.refine import RefineConfig, refine_with_gate
    from repro.data.benchmarks import make_metatool_like
    from repro.embedding.bag_encoder import BagEncoder
    from repro.learn import (
        AdapterTrainer, RerankerTrainer, build_train_window, stage_ndcg,
    )
    from repro.router.gateway import SemanticRouter, StageSet
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    n_tools = 600  # fixed tool count; >500 puts the adapter in-policy (§7.3)
    # the densest point must clear the §7.3 adapter threshold (>10K logs =
    # >2000 train queries at k=5) so the gated-promotion replay is exercised
    # even in smoke mode
    n_queries = 3000 if smoke else 4000
    bench = make_metatool_like(seed=seed, n_tools=n_tools, n_queries=n_queries)
    enc = BagEncoder(bench.vocab)
    db = ToolsDatabase(
        [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
         for i in range(bench.n_tools)],
        enc.encode(bench.desc_tokens),
    )
    store = OutcomeStore(n_tools=len(db), capacity=200_000)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,
    )
    test_idx = bench.test_idx[: 200 if smoke else 400]
    test_q = enc.encode([bench.query_tokens[i] for i in test_idx])
    test_tokens = [bench.query_tokens[i] for i in test_idx]
    test_rel = bench.relevance_matrix()[test_idx]
    refine_cfg = RefineConfig(keep_history=False, gate_metric="ndcg")

    # cumulative traffic: each point adds queries, density grows at fixed T
    fractions = (0.3, 1.0) if smoke else (0.2, 0.5, 1.0)
    cut = [int(round(f * len(bench.train_idx))) for f in fractions]
    points = []
    served = 0
    for hi in cut:
        _serve_and_log(router, bench, bench.train_idx[served:hi])
        served = hi
        plan = recommend_stages(len(db), store.total_ingested)
        window = build_train_window(db, store, enc.encode, min_queries=30, seed=seed)
        assert window is not None, "sweep window unexpectedly too sparse"
        # refine-only: the always-on Stage 1 from the same window
        result = refine_with_gate(
            jnp.asarray(window.table),
            jnp.asarray(window.query_emb[window.train_idx]),
            jnp.asarray(window.pos_mask[window.train_idx]),
            jnp.asarray(window.query_emb[window.val_idx]),
            jnp.asarray(window.pos_mask[window.val_idx]),
            refine_cfg,
        )
        refined = np.asarray(result.embeddings)
        window = dataclasses.replace(window, table=refined)
        base = StageSet()
        ndcg = {"refine_only": stage_ndcg(refined, test_q, test_tokens, test_rel, base)}
        val_q = window.query_emb[window.val_idx]
        val_tokens = window.tokens(window.val_idx)
        val_rel = window.pos_mask[window.val_idx]
        val_base = stage_ndcg(refined, val_q, val_tokens, val_rel, base)
        trained = {}
        for trainer in (AdapterTrainer(), RerankerTrainer()):
            t0 = time.time()
            try:
                ts = trainer.train(window)
            except ValueError as exc:  # window too sparse for this stage
                ndcg[f"plus_{trainer.stage}"] = None
                print(f"    {trainer.stage}: not trainable ({exc})", flush=True)
                continue
            candidate = ts.apply_to(base)
            trained[trainer.stage] = (ts, candidate)
            ndcg[f"plus_{trainer.stage}"] = stage_ndcg(
                refined, test_q, test_tokens, test_rel, candidate
            )
            print(f"    {trainer.stage}: trained in {time.time() - t0:.1f}s "
                  f"-> test NDCG@5 {ndcg[f'plus_{trainer.stage}']:.3f}", flush=True)
        # gated promotion replay: the LearningController's decision rule —
        # plan veto first, then the held-out val gate per stage
        promoted = []
        config = base
        for stage, wanted in (
            ("adapter", plan.contrastive_adapter), ("rerank", plan.mlp_reranker),
        ):
            if not wanted or stage not in trained:
                continue
            candidate = trained[stage][0].apply_to(config)
            if stage_ndcg(refined, val_q, val_tokens, val_rel, candidate) > max(
                val_base, stage_ndcg(refined, val_q, val_tokens, val_rel, config)
            ):
                config = candidate
                promoted.append(stage)
        ndcg_promoted = stage_ndcg(refined, test_q, test_tokens, test_rel, config)
        point = {
            "events": store.total_ingested,
            "density": plan.density,
            "plan": sorted(plan.stages),
            "ndcg_at_5": ndcg,
            "promoted": promoted,
            "ndcg_promoted": ndcg_promoted,
            "promotion_regressed": bool(
                ndcg_promoted < ndcg["refine_only"] - REGRESSION_TOL
            ),
        }
        points.append(point)
        print(f"  density {plan.density:5.1f} ({store.total_ingested} events): "
              f"refine {ndcg['refine_only']:.3f} | "
              f"+adapter {ndcg.get('plus_adapter')} | "
              f"+rerank {ndcg.get('plus_rerank')} | "
              f"promoted {promoted or ['(none)']} -> {ndcg_promoted:.3f}",
              flush=True)
    return {
        "table": bench.name,
        "n_tools": n_tools,
        "points": points,
        "regression_tolerance": REGRESSION_TOL,
    }


def bench_latency_all_stages(smoke: bool, seed: int) -> dict:
    import jax

    from repro.core import adapter as adapter_lib
    from repro.core import reranker as reranker_lib
    from repro.core.features import OutcomeFeaturizer
    from repro.data.benchmarks import make_toolbench_like
    from repro.embedding.bag_encoder import BagEncoder
    from repro.router.gateway import SemanticRouter, StageSet
    from repro.router.latency import percentile_stats
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    bench = make_toolbench_like(seed=seed, n_queries=128 if smoke else 600)
    enc = BagEncoder(bench.vocab)
    db = ToolsDatabase(
        [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
         for i in range(bench.n_tools)],
        enc.encode(bench.desc_tokens),
    )
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5
    )
    queries = list(bench.query_tokens)
    batch_size = 64
    n_calls = 12 if smoke else 64

    def timed_pass():
        samples = []
        for _ in range(2):  # warmup / compile
            router.route_batch(queries[:batch_size])
        for i in range(n_calls):
            qs = [queries[(i * batch_size + j) % len(queries)]
                  for j in range(batch_size)]
            t0 = time.perf_counter()
            router.route_batch(qs)
            samples.append((time.perf_counter() - t0) * 1e3 / batch_size)
        return percentile_stats(samples)

    no_stages = timed_pass()

    # all learned stages active: the adapter head (identical FLOPs whether
    # trained or fresh) + the MLP re-ranker with a real featurizer fit on a
    # slice of train traffic — the worst-case serving composition
    fit_idx = bench.train_idx[:200]
    fit_q = enc.encode([bench.query_tokens[i] for i in fit_idx])
    rel = bench.relevance_matrix()[fit_idx]
    retrieved = np.argsort(-(fit_q @ db.embeddings.T), axis=1)[:, :5]
    featurizer = OutcomeFeaturizer.fit(
        fit_q, [bench.query_tokens[i] for i in fit_idx], rel, retrieved,
        bench.tool_category, seed=seed,
    )
    key = jax.random.PRNGKey(seed)
    router.set_stages(StageSet(
        adapter_params=adapter_lib.init_adapter(key),
        mlp_params=reranker_lib.init_mlp(key),
        featurizer=featurizer,
    ))
    all_stages = timed_pass()
    return {
        "table": bench.name,
        "n_tools": bench.n_tools,
        "batch_size": batch_size,
        "n_calls": n_calls,
        "no_stages": no_stages.as_dict(),
        "all_stages": all_stages.as_dict(),
        "budget_ms": BUDGET_MS,
    }


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_learn.json") -> dict:
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)
    print("[1/2] NDCG@5 density sweep (refine-only / +adapter / +reranker)",
          flush=True)
    sweep = bench_density_sweep(smoke, seed)
    print("[2/2] route_batch p99 with all learned stages active", flush=True)
    latency = bench_latency_all_stages(smoke, seed)
    p99 = latency["all_stages"]["p99_ms"]
    regressed = [p for p in sweep["points"] if p["promotion_regressed"]]
    report = {
        "bench": "learning_plane",
        "density_sweep": sweep,
        "latency_all_stages": latency,
        "derived": {
            "p99_all_stages_ms": p99,
            "p99_within_budget": p99 <= BUDGET_MS,
            "n_promotion_regressions": len(regressed),
        },
        "smoke": smoke,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    dense = sweep["points"][-1]
    print(f"densest point ({dense['density']:.1f} ev/tool): refine-only "
          f"{dense['ndcg_at_5']['refine_only']:.3f} vs promoted "
          f"{dense['ndcg_promoted']:.3f} {dense['promoted']} | p99/query "
          f"all stages {p99:.3f}ms (budget {BUDGET_MS}ms, stage-free "
          f"{latency['no_stages']['p99_ms']:.3f}ms) -> {out}")
    if regressed:
        raise SystemExit(
            f"{len(regressed)} gated promotion(s) regressed held-out NDCG@5 "
            f"past {REGRESSION_TOL}: {regressed}"
        )
    if not report["derived"]["p99_within_budget"]:
        raise SystemExit(
            f"p99 with all stages active {p99:.3f}ms exceeds the "
            f"{BUDGET_MS}ms budget"
        )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_learn.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
