"""Telemetry-plane benchmark: instrumentation overhead + lifecycle smoke.

  PYTHONPATH=src python -m benchmarks.obs_bench [--smoke] [--out BENCH_obs.json]

Two acceptance gates, both enforced with SystemExit (CI smoke-runs this via
scripts/ci_check.sh):

1. **Overhead**: `route_batch` with the full telemetry plane attached
   (MetricsRegistry histograms + counters + gauges, 1-in-64 sampled
   RouteTracer, EventBus, per-batch QualityMonitor drift/score-gap
   collection, a live TimeSeriesRing + SLOEngine judging on a 0.5 s
   cadence, an armed FlightRecorder subscribed to the bus, and a
   JitProfiler polling the hot-path compile caches on the same cadence,
   and a metered never-hit `SemanticRouteCache` so the route cache's
   counters/gauges and `cache` phase span are inside the budget)
   must stay within ``OVERHEAD_BUDGET`` (5 %) of the
   truly bare router (`metrics=False`, no tracer, no bus; an identical
   un-metered never-hit cache keeps the serving work symmetric) on qps. Bare and
   instrumented routers serve identical query blocks slice-interleaved
   inside every round (alternating lead) so CPU frequency drift and
   container noise hit both sides equally; the gate takes the better of
   the peak-of-rounds and median-of-paired-ratios estimates, since their
   noise failure modes are disjoint. Per-phase p50/p99 estimated from the
   live histograms is recorded alongside.

2. **Lifecycle**: a threaded smoke — serving thread routing batches
   concurrently while the main thread drives a table swap, a forced
   TableGuard rollback (+ controller cooldown), index rebuilds, a StageSet
   swap, and a forced StageGuard demotion — must land EVERY expected
   lifecycle event kind on the bus with correct version stamps.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import threading

import numpy as np

OVERHEAD_BUDGET = 0.05  # instrumented route_batch must keep 95% of bare qps
BATCH = 64
TRACE_EVERY = 64  # production-shaped sampling for the overhead measurement
REQUIRED_EVENTS = (
    "swap",  # table deployments (EventBus.watch_db)
    "rebuild_start",  # index lifecycle behind each swap
    "rebuild_finish",
    "rollback",  # TableGuard condemning the bad table
    "cooldown",  # RefinementController purging the condemned-era window
    "stage_swap",  # StageSet deployments (promotion/demotion/out-of-band)
    "demotion",  # StageGuard condemning the bad StageSet
)


def _build_router(bench, enc, metrics, tracer=None, bus=None, quality=None,
                  cache=None):
    from repro.index import ToolIndexManager
    from repro.router.gateway import SemanticRouter
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    db = ToolsDatabase(
        [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
         for i in range(bench.n_tools)],
        enc.encode(bench.desc_tokens),
    )
    if bus is not None:
        bus.watch_db(db)
    if quality is not None:
        quality.watch_db(db)
    index = ToolIndexManager(db, backend="dense", metrics=metrics, bus=bus)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        index=index, metrics=metrics, tracer=tracer, bus=bus,
        quality=quality, cache=cache,
    )
    return db, router


def _timed_qps(router, blocks, n_calls: int) -> float:
    from repro.obs import clock

    t0 = clock.perf()
    for i in range(n_calls):
        router.route_batch(blocks[i % len(blocks)])
    return n_calls * BATCH / (clock.perf() - t0)


def _timed_pair(bare, inst, blocks, n_calls: int, slices: int = 6):
    """One paired round: bare and instrumented alternate in short slices.

    CPU frequency scaling and container contention drift on ~100 ms
    timescales — longer than a slice, shorter than a round — so measuring
    one full side then the other lets a frequency step charge all its cost
    to whichever side ran second. Slice-interleaving (alternating the
    leading side per slice) makes each round's two accumulated clocks
    sample the same frequency trajectory.
    """
    from repro.obs import clock

    per = max(1, n_calls // slices)
    elapsed = {"bare": 0.0, "inst": 0.0}
    for s in range(slices):
        pair = (("bare", bare), ("inst", inst))
        if s % 2:
            pair = pair[::-1]
        for name, router in pair:
            t0 = clock.perf()
            for i in range(per):
                router.route_batch(blocks[(s * per + i) % len(blocks)])
            elapsed[name] += clock.perf() - t0
    n = per * slices * BATCH
    return n / elapsed["bare"], n / elapsed["inst"]


def run_overhead(bench, enc, smoke: bool, seed: int) -> dict:
    from repro.obs import (
        EventBus,
        FlightRecorder,
        JitProfiler,
        MetricsRegistry,
        QualityConfig,
        QualityMonitor,
        RouteTracer,
        SLOEngine,
        TimeSeriesRing,
        stamp_router_costs,
        stats_from_histogram,
    )

    registry = MetricsRegistry()
    tracer = RouteTracer(sample_every=TRACE_EVERY, seed=seed)
    bus = EventBus()
    # the instrumented side carries the FULL telemetry plane, judgement layer
    # included: per-batch quality/drift collection in route_batch, plus a
    # live TimeSeriesRing cadence evaluating the SLO engine concurrently —
    # the production shape launch/serve.py wires behind --metrics-port.
    # PR 9 adds the memory layer to the same side: an armed FlightRecorder
    # (bus subscriber, idle unless a trigger fires) and a JitProfiler
    # polling the hot-path compile caches on every ring tick.
    quality = QualityMonitor(QualityConfig(drift_every=4),
                             registry=registry, bus=bus)
    # both sides carry a route cache in never-hit mode (threshold=2.0 > any
    # cosine): every batch pays the identical deterministic probe + insert +
    # eviction work, the full embed/score pipeline still runs (no hits to
    # deflate either side), and the bare/instrumented delta stays pure
    # telemetry — now including the cache's counters, gauges, and the
    # per-batch `cache` phase span
    from repro.cache import CacheConfig, SemanticRouteCache

    cache_bare = SemanticRouteCache(CacheConfig(threshold=2.0), metrics=False)
    cache_inst = SemanticRouteCache(CacheConfig(threshold=2.0),
                                    metrics=registry, bus=bus)
    cache_inst.watch(bus)
    _, bare = _build_router(bench, enc, metrics=False, cache=cache_bare)
    _, inst = _build_router(bench, enc, metrics=registry, tracer=tracer,
                            bus=bus, quality=quality, cache=cache_inst)
    ring = TimeSeriesRing(registry, bus=bus)
    engine = SLOEngine(ring, bus=bus, registry=registry)
    profiler = JitProfiler(registry=registry)
    dump_dir = tempfile.mkdtemp(prefix="obs-bench-dumps-")
    recorder = FlightRecorder(dump_dir, bus=bus, registry=registry,
                              tracer=tracer, ring=ring, slo=engine,
                              profiler=profiler, routers=[inst])

    blocks = [
        [bench.query_tokens[qi] for qi in bench.train_idx[lo : lo + BATCH]]
        for lo in range(0, BATCH * 8, BATCH)
    ]
    # smoke keeps enough calls per round that a ring tick or scheduler blip
    # landing mid-round amortizes instead of dominating the round (a 20-call
    # round is ~50 ms; ±1 ms of noise reads as ±2 % "overhead")
    n_calls = 48 if smoke else 60
    rounds = 11 if smoke else 9
    for r in (bare, inst):  # jit warmup + instrument touch, off the clock
        _timed_qps(r, blocks, 3)
    profiler.collect()  # baseline: warmup compiles never count
    stamp_router_costs(profiler, inst, batch_size=BATCH)  # off the clock too

    # judgement cadence runs for the whole measurement: every 0.5 s the ring
    # snapshots the registry, the profiler polls the jit caches, and the
    # engine judges all five default SLOs
    ring.start(interval_s=0.5,
               on_tick=lambda _r: (profiler.collect(), engine.evaluate()))
    ratios, qps_bare_all, qps_inst_all = [], [], []
    for rnd in range(rounds):
        # slice-interleaved inside the round: frequency drift hits both
        # sides equally (see _timed_pair)
        qps_bare, qps_inst = _timed_pair(bare, inst, blocks, n_calls)
        ratios.append(qps_inst / qps_bare)
        qps_bare_all.append(qps_bare)
        qps_inst_all.append(qps_inst)
    ring.stop()
    recorder.stop()
    if ring.last_loop_error is not None:
        raise SystemExit(f"ring daemon flapped during the overhead "
                         f"measurement: {ring.last_loop_error}")
    # a dump here means an SLO burned mid-measurement (noisy host) — recorded
    # for inspection, not gated: flightrec_bench gates dump semantics
    dumps_written = recorder.dumps_written
    shutil.rmtree(dump_dir, ignore_errors=True)
    # two overhead estimators with complementary failure modes: peak-vs-peak
    # assumes noise only subtracts qps (turbo-boost spikes on one side break
    # that), the median of slice-paired per-round ratios assumes slice noise
    # is symmetric (a persistently loaded sibling breaks that). A real
    # instrumentation regression breaches BOTH, so the gate takes the
    # smaller estimate — host noise has to fool two different statistics at
    # once to flake CI, and both readings land in the artifact regardless
    ratio_peak = float(max(qps_inst_all) / max(qps_bare_all))
    ratio_median = float(np.median(ratios))
    ratio = max(ratio_peak, ratio_median)
    overhead = 1.0 - ratio
    phases = {
        name: stats_from_histogram(
            registry.histogram("route_phase_ms", phase=name)
        ).as_dict()
        for name in ("embed", "cache", "adapter", "score", "assemble")
    }
    total = stats_from_histogram(registry.histogram("route_batch_ms")).as_dict()
    row = {
        "batch_size": BATCH,
        "n_calls_per_round": n_calls,
        "rounds": rounds,
        "trace_sample_every": TRACE_EVERY,
        "qps_bare_median": float(np.median(qps_bare_all)),
        "qps_instrumented_median": float(np.median(qps_inst_all)),
        "qps_bare_peak": float(max(qps_bare_all)),
        "qps_instrumented_peak": float(max(qps_inst_all)),
        "qps_ratio_median": ratio_median,
        "qps_ratio_peak": ratio_peak,
        "overhead_frac": overhead,
        "overhead_budget": OVERHEAD_BUDGET,
        "n_traces": len(tracer),
        "phase_ms": phases,
        "batch_ms": total,
        "ring_points": len(ring),
        "slo_burning": engine.burning(),
        "drift_batches": quality.summary()["n_batches"],
        "dumps_written": dumps_written,
        "jit_profile": {
            name: {"cache_size": info["cache_size"],
                   "compiles_post_warmup": info["compiles_total"],
                   "flops": (info.get("cost") or {}).get("flops")}
            for name, info in profiler.snapshot()["jits"].items()
        },
    }
    print(f"overhead: peak {100 * (1.0 - ratio_peak):+.2f}% / "
          f"paired-median {100 * (1.0 - ratio_median):+.2f}% -> gate "
          f"{100 * overhead:+.2f}% (budget {100 * OVERHEAD_BUDGET:.0f}%) | "
          f"bare {row['qps_bare_peak']:.0f} qps vs instrumented "
          f"{row['qps_instrumented_peak']:.0f} qps peak | "
          f"{row['n_traces']} traces sampled", flush=True)
    for name, s in {**phases, "total": total}.items():
        print(f"  {name:8s} p50={s['p50_ms']:.3f}ms p99={s['p99_ms']:.3f}ms "
              f"(n={s['n']})", flush=True)
    bare.close()
    inst.close()
    return row


def run_lifecycle(bench, enc, seed: int) -> dict:
    from repro.control import (
        ControllerConfig,
        GuardConfig,
        OutcomeStore,
        RefinementController,
        TableGuard,
    )
    from repro.learn import StageGuard, StageGuardConfig
    from repro.obs import EventBus, RouteTracer
    from repro.router.stages import StageSet

    bus = EventBus()
    tracer = RouteTracer(sample_every=1, seed=seed)
    db, router = _build_router(bench, enc, metrics=False, tracer=tracer, bus=bus)
    store = OutcomeStore(n_tools=len(db))
    guard = TableGuard(db, GuardConfig(min_samples=32), bus=bus)
    controller = RefinementController(
        db, store, enc.encode, routers=[router], guard=guard, bus=bus,
        # the smoke drives swaps by hand; the refinement trigger stays cold
        config=ControllerConfig(min_events=10**9, max_interval_s=10**9),
    )
    stage_guard = StageGuard(router, StageGuardConfig(min_samples=32), bus=bus)

    # concurrent serving: every lifecycle transition below lands while
    # route_batch traffic is in flight on another thread
    stop = threading.Event()
    serve_errors = []
    blocks = [
        [bench.query_tokens[qi] for qi in bench.train_idx[lo : lo + 16]]
        for lo in range(0, 64, 16)
    ]

    def serve_loop():
        i = 0
        try:
            while not stop.is_set():
                router.route_batch(blocks[i % len(blocks)])
                i += 1
        except Exception as exc:  # surfaces as a failed gate below
            serve_errors.append(exc)

    t = threading.Thread(target=serve_loop, name="obs-smoke-serve", daemon=True)
    t.start()

    def observe_table(version, good: bool, n=40):
        for _ in range(n):  # synthetic labels: deterministic guard verdicts
            guard.observe(version, [1, 2, 3], [1] if good else [9])

    def observe_stages(version, good: bool, n=40):
        for _ in range(n):
            stage_guard.observe(version, [1, 2, 3], [1] if good else [9])

    try:
        # act 1: healthy window on v0, then a swap the guard gets a baseline
        # for, then synthetic regression -> rollback + cooldown
        observe_table(db.table_version, good=True)
        rng = np.random.default_rng(seed)
        bad = db.embeddings.copy()
        rng.shuffle(bad, axis=0)
        v_bad = db.swap_table(bad)
        controller.step()  # unannounced swap: baseline frozen from v0
        observe_table(v_bad, good=False)
        report = controller.step()
        rollback_action = report.guard.action if report.guard else None
        v_restored = db.table_version
        cooldown_report = report.reason

        # act 2: StageSet swap, then synthetic regression -> demotion
        sv_before = router.stage_version
        observe_stages(sv_before, good=True)
        sv_bad = router.set_stages(StageSet())
        stage_guard.check()  # unannounced promotion: baseline frozen
        observe_stages(sv_bad, good=False)
        stage_report = stage_guard.check()
        sv_restored = router.stage_version
    finally:
        stop.set()
        t.join(timeout=30)

    counts = bus.counts()
    row = {
        "event_counts": counts,
        "rollback_action": rollback_action,
        "demotion_action": stage_report.action,
        "cooldown_reason": cooldown_report,
        "n_traces": len(tracer),
        "serve_thread_errors": [repr(e) for e in serve_errors],
    }
    print(f"lifecycle: events {counts} | rollback={rollback_action} "
          f"demotion={stage_report.action}", flush=True)

    if serve_errors:
        raise SystemExit(f"serving thread failed during the lifecycle smoke: "
                         f"{serve_errors[0]!r}")
    missing = [k for k in REQUIRED_EVENTS if not counts.get(k)]
    if missing:
        raise SystemExit(f"lifecycle event(s) never reached the bus: {missing} "
                         f"(saw {counts})")
    rb = bus.last("rollback")
    if (rb.details["condemned_version"] != v_bad
            or rb.details["restored_version"] != v_restored):
        raise SystemExit(f"rollback event mis-stamped: {rb.details} "
                         f"(condemned v{v_bad}, restored v{v_restored})")
    dm = bus.last("demotion")
    if (dm.details["condemned_version"] != sv_bad
            or dm.details["restored_version"] != sv_restored):
        raise SystemExit(f"demotion event mis-stamped: {dm.details} "
                         f"(condemned v{sv_bad}, restored v{sv_restored})")
    swap_versions = [e.details["version"] for e in bus.events(kind="swap")]
    if v_bad not in swap_versions:
        raise SystemExit(f"table swap v{v_bad} never reached the bus "
                         f"(saw versions {swap_versions})")
    if "cooldown" not in cooldown_report:
        raise SystemExit(f"rollback step did not enter cooldown: "
                         f"{cooldown_report!r}")
    router.close()
    return row


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_obs.json") -> dict:
    from repro.data.benchmarks import make_metatool_like
    from repro.embedding.bag_encoder import BagEncoder

    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    bench = make_metatool_like(seed=seed, n_tools=199,
                               n_queries=600 if smoke else 1200)
    enc = BagEncoder(bench.vocab)
    overhead = run_overhead(bench, enc, smoke, seed)
    lifecycle = run_lifecycle(bench, enc, seed)
    report = {
        "bench": "telemetry_plane",
        "overhead": overhead,
        "lifecycle": lifecycle,
        "derived": {
            "overhead_frac": overhead["overhead_frac"],
            "overhead_budget": OVERHEAD_BUDGET,
            "lifecycle_events_seen": sorted(
                k for k, v in lifecycle["event_counts"].items() if v
            ),
            "smoke": smoke,
        },
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"telemetry overhead {100 * overhead['overhead_frac']:+.2f}% "
          f"(budget {100 * OVERHEAD_BUDGET:.0f}%) | lifecycle events "
          f"{report['derived']['lifecycle_events_seen']} -> {out}")
    # the overhead gate runs LAST so the artifact is always written for
    # inspection before a violation exits nonzero
    if overhead["overhead_frac"] > OVERHEAD_BUDGET:
        raise SystemExit(
            f"instrumented route_batch overhead "
            f"{100 * overhead['overhead_frac']:.2f}% exceeds the "
            f"{100 * OVERHEAD_BUDGET:.0f}% budget on both estimators "
            f"(peak ratio {overhead['qps_ratio_peak']:.4f}, "
            f"paired-median ratio {overhead['qps_ratio_median']:.4f}; "
            f"peak bare {overhead['qps_bare_peak']:.0f} qps vs instrumented "
            f"{overhead['qps_instrumented_peak']:.0f} qps)"
        )
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
