"""Roofline summary from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by `python -m repro.launch.dryrun`)
and emits one row per (arch x shape x mesh): three terms in seconds, the
dominant bottleneck, MODEL_FLOPS = 6*N(_active)*D, the useful-flops ratio,
and per-device memory. MODEL_FLOPS is recomputed from the current configs so
the table never goes stale against the stored JSON.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import get_config
from repro.launch.specs import SHAPES, variant_for_shape

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(directory: str = DRYRUN_DIR) -> List[Dict]:
    records = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            records.append(json.load(f))
    return records


def roofline_rows(directory: str = DRYRUN_DIR) -> List[Dict]:
    """Single-pod roofline rows (the multi-pod runs are lowering proof only:
    their costs come from uncorrected while-body counts) + a one-line
    dry-run summary per mesh."""
    rows = []
    records = load_records(directory)

    def is_baseline(r):
        return (
            r.get("policy", "tp") == "tp"
            and r.get("moe_impl", "gspmd") == "gspmd"
            and not r.get("repeat_kv")
            and r.get("decode_attn", "gspmd") == "gspmd"
            and not r.get("quantize")
        )

    for mesh in ("single", "multi"):
        n = sum(1 for r in records if r["mesh"] == mesh and is_baseline(r))
        n_perf = sum(1 for r in records if r["mesh"] == mesh and not is_baseline(r))
        rows.append({
            "name": f"dryrun/{mesh}-pod-pass",
            "us_per_call": 0,
            "derived": {"combinations_compiled": n, "expected": 40,
                        "all_pass": n == 40, "perf_variant_records": n_perf},
        })
    for r in records:
        if r["mesh"] != "single":
            continue
        shape = SHAPES[r["shape"]]
        cfg = variant_for_shape(get_config(r["arch"]), shape)
        # MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D inference tokens
        factor = 6 if shape.kind == "train" else 2
        d_tokens = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1
        )
        model_flops = factor * cfg.active_param_count() * d_tokens
        chips = r["chips"]
        flops_dev = r["per_device"]["flops"]
        rt = r["roofline"]
        # annotate §Perf variants (policy/moe/decode/quant flags) so tagged
        # records are distinguishable from the tp/gspmd baseline rows
        mods = []
        if r.get("policy", "tp") != "tp":
            mods.append(r["policy"])
        if r.get("moe_impl", "gspmd") != "gspmd":
            mods.append("moe=" + r["moe_impl"])
        if r.get("repeat_kv"):
            mods.append("rkv")
        if r.get("decode_attn", "gspmd") != "gspmd":
            mods.append(r["decode_attn"])
        if r.get("quantize"):
            mods.append("int8")
        suffix = ("+" + "+".join(mods)) if mods else ""
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{suffix}",
            "us_per_call": round(max(rt["compute_s"], rt["memory_s"], rt["collective_s"]) * 1e6, 1),
            "derived": {
                "compute_s": round(rt["compute_s"], 5),
                "memory_s": round(rt["memory_s"], 5),
                "collective_s": round(rt["collective_s"], 5),
                "dominant": rt["dominant"],
                "model_flops": model_flops,
                "useful_flops_ratio": round(model_flops / max(flops_dev * chips, 1.0), 4),
                "arg_gb_per_device": round((r["per_device"]["argument_bytes"] or 0) / 1e9, 3),
                "temp_gb_per_device": round((r["per_device"]["temp_bytes"] or 0) / 1e9, 3),
                "compile_s": r["compile_s"],
            },
        })
    return rows
