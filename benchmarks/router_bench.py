"""Batched-router serving benchmark: p50/p99 latency + queries/sec.

  PYTHONPATH=src python -m benchmarks.router_bench [--smoke] [--out BENCH_router.json]

Measures the gateway hot path (`SemanticRouter.route_batch`: batched embed ->
one jitted similarity+top-K -> result assembly) at batch sizes {1, 8, 64, 256}
on both paper table sizes (metatool-like 199 tools, toolbench-like 2,413
tools), plus the sequential `route()` baseline the batch API replaces. The
headline derived metric — batch-64 queries/sec over 64 sequential calls on
the 2,413-tool table — is the speedup the ISSUE acceptance gate records.

Results land in BENCH_router.json:
  {"rows": [{table, n_tools, batch_size, p50_ms_per_query, ...}, ...],
   "derived": {"speedup_batch64_vs_sequential_2413": ..., ...}}
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import numpy as np

BATCH_SIZES = (1, 8, 64, 256)


def _build_router(bench, k: int = 5):
    from repro.embedding.bag_encoder import BagEncoder
    from repro.router.gateway import SemanticRouter
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    enc = BagEncoder(bench.vocab)
    records = [
        ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
        for i in range(bench.n_tools)
    ]
    db = ToolsDatabase(records, enc.encode(bench.desc_tokens))
    return SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=k
    )


def _timed_loop(fn, n_calls: int, warmup: int, per_call_queries: int) -> dict:
    """Run fn(i) n_calls times; aggregate per-query latency + throughput
    through the canonical `percentile_stats` (one LatencyStats definition)."""
    from repro.router.latency import percentile_stats

    for i in range(warmup):
        fn(i)
    call_ms = []
    t_all = time.perf_counter()
    for i in range(n_calls):
        t0 = time.perf_counter()
        fn(i)
        call_ms.append((time.perf_counter() - t0) * 1e3)
    wall_s = time.perf_counter() - t_all
    stats = percentile_stats(np.asarray(call_ms) / per_call_queries)
    return {
        "n_calls": n_calls,
        "p50_ms_per_query": stats.p50_ms,
        "p99_ms_per_query": stats.p99_ms,
        "mean_ms_per_query": stats.mean_ms,
        "qps": float(n_calls * per_call_queries / wall_s),
    }


def _bench_batched(router, queries: List[np.ndarray], batch_size: int,
                   n_calls: int, warmup: int = 3) -> dict:
    """Time `n_calls` route_batch calls of `batch_size` queries each.
    Warmup covers jit compilation for this (Q, T) shape."""
    n_q = len(queries)

    def call(i):
        router.route_batch(
            [queries[(i * batch_size + j) % n_q] for j in range(batch_size)]
        )

    row = _timed_loop(call, n_calls, warmup, batch_size)
    row["batch_size"] = batch_size
    return row


def _bench_sequential(router, queries: List[np.ndarray], n_requests: int,
                      warmup: int = 3) -> dict:
    """The pre-batching serving loop: one route() call per request."""
    row = _timed_loop(
        lambda i: router.route(queries[i % len(queries)]), n_requests, warmup, 1
    )
    row["batch_size"] = 0  # marker: sequential route() loop
    return row


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_router.json") -> dict:
    from repro.data.benchmarks import make_metatool_like, make_toolbench_like

    # fail on an unwritable destination BEFORE the minutes of measurement
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    from repro.analysis.retrace import hot_path_monitor
    from repro.common.bucketing import expected_buckets

    n_queries = 128 if smoke else 600
    tables = {
        "metatool-like": make_metatool_like(seed=seed, n_queries=n_queries),
        "toolbench-like": make_toolbench_like(seed=seed, n_queries=n_queries),
    }
    batch_sizes = (1, 8, 64) if smoke else BATCH_SIZES
    seq_requests = 16 if smoke else 64
    rows = []
    by_key = {}
    # the perf run doubles as the retrace contract check: across the whole
    # sweep the jitted scorer may compile once per (pow2 bucket x table) —
    # anything beyond that is a retrace the p99 numbers silently absorbed
    monitor = hot_path_monitor()
    monitor.__enter__()
    for name, bench in tables.items():
        router = _build_router(bench)
        queries = list(bench.query_tokens)
        seq = _bench_sequential(router, queries, seq_requests)
        seq.update(table=name, n_tools=bench.n_tools, mode="sequential")
        rows.append(seq)
        by_key[(name, "seq")] = seq
        print(f"{name:15s} T={bench.n_tools:5d} sequential      "
              f"p50={seq['p50_ms_per_query']:.3f}ms p99={seq['p99_ms_per_query']:.3f}ms "
              f"qps={seq['qps']:.0f}", flush=True)
        for bs in batch_sizes:
            n_calls = max(2, (4 if smoke else 32) * 64 // bs)
            r = _bench_batched(router, queries, bs, n_calls)
            r.update(table=name, n_tools=bench.n_tools, mode="batched")
            rows.append(r)
            by_key[(name, bs)] = r
            print(f"{name:15s} T={bench.n_tools:5d} batch={bs:<4d}      "
                  f"p50={r['p50_ms_per_query']:.3f}ms p99={r['p99_ms_per_query']:.3f}ms "
                  f"qps={r['qps']:.0f}", flush=True)

    monitor.__exit__(None, None, None)
    # sequential route() serves batches of 1 -> bucket 1, already in the set
    buckets = expected_buckets(list(batch_sizes) + [1])
    budget = len(buckets) * len(tables)
    retrace_violations = monitor.check(
        {"topk_dense": budget, "adapter_apply": 0, "rerank_topk_scored": 0}
    )
    for v in retrace_violations:
        print(f"RETRACE VIOLATION: {v}", flush=True)

    tb = "toolbench-like"
    derived = {
        "speedup_batch64_vs_sequential_2413": (
            by_key[(tb, 64)]["qps"] / by_key[(tb, "seq")]["qps"]
        ),
        "p99_batch64_ms_2413": by_key[(tb, 64)]["p99_ms_per_query"],
        "latency_budget_ms": 10.0,
        "smoke": smoke,
    }
    report = {"bench": "router_serving_path", "rows": rows, "derived": derived}
    report["retrace"] = {
        "traces": monitor.traces(),
        "expected_buckets": buckets,
        "budget_topk_dense": budget,
        "violations": retrace_violations,
        "unsupported": monitor.unsupported,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"speedup(batch64 vs sequential, {tb}): "
          f"{derived['speedup_batch64_vs_sequential_2413']:.1f}x | "
          f"p99/query at batch 64: {derived['p99_batch64_ms_2413']:.3f}ms "
          f"(budget {derived['latency_budget_ms']}ms) | "
          f"retrace: {'VIOLATED' if retrace_violations else 'ok'} -> {out}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_router.json")
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke, seed=args.seed, out=args.out)
    return 1 if report["retrace"]["violations"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
