"""Benchmark driver: one function per paper table, plus the subsystem
benches (DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.run [--smoke] [--tables table4,fig4,router]

Two kinds of benchmark live behind one registry and ONE `--smoke` flag:

  * paper tables (`benchmarks.tables.ALL_TABLES` + roofline/kernels) print
    ``name,us_per_call,derived`` CSV rows to stdout;
  * subsystem suites (`router`, `control`, `index`, `learn`) are the recorded-number
    benches — each writes its own ``BENCH_<name>[_smoke].json`` artifact and
    prints its own summary. They are the same entry points CI smoke-runs
    (`scripts/ci_check.sh`), so `--smoke` means the same reduced scale
    everywhere instead of per-file ad-hoc handling.

`--tables all` (default) runs everything; `--fast` is kept as a deprecated
alias for `--smoke`.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _suite_registry():
    """name -> run(smoke=..., seed=..., out=...) for the subsystem benches."""
    from benchmarks import (
        cache_bench,
        control_bench,
        flightrec_bench,
        index_bench,
        learn_bench,
        obs_bench,
        router_bench,
        slo_bench,
    )

    return {
        "router": router_bench.run,
        "control": control_bench.run,
        "index": index_bench.run,
        "learn": learn_bench.run,
        "cache": cache_bench.run,
        "obs": obs_bench.run,
        "slo": slo_bench.run,
        "flightrec": flightrec_bench.run,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale everywhere (tables AND suite benches)")
    ap.add_argument("--fast", action="store_true",
                    help="deprecated alias for --smoke")
    ap.add_argument("--tables", default="all",
                    help="comma list of paper tables and/or suites "
                         "(router,control,index,learn,cache,obs,slo,"
                         "flightrec)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    smoke = args.smoke or args.fast

    from benchmarks.context import BenchContext
    from benchmarks.kernel_bench import kernel_rows
    from benchmarks.roofline import roofline_rows
    from benchmarks.tables import ALL_TABLES

    suites = _suite_registry()
    want = list(ALL_TABLES) + ["roofline", "kernels"] + list(suites)
    if args.tables != "all":
        want = args.tables.split(",")
    unknown = [t for t in want
               if t not in ALL_TABLES and t not in suites
               and t not in ("roofline", "kernels")]
    if unknown:
        raise SystemExit(f"unknown benchmark(s): {unknown} "
                         f"(tables: {list(ALL_TABLES)}; suites: {list(suites)})")

    for name in want:
        if name in suites:
            out = f"BENCH_{name}{'_smoke' if smoke else ''}.json"
            print(f"# suite {name} -> {out}", flush=True)
            suites[name](smoke=smoke, seed=args.seed, out=out)

    rows = []
    needs_ctx = any(t in ALL_TABLES for t in want)
    if needs_ctx:
        t0 = time.time()
        ctx = BenchContext.build(seed=args.seed, fast=smoke)
        print(f"# context built in {time.time() - t0:.1f}s", flush=True)
        for tname in want:
            if tname in ALL_TABLES:
                rows.extend(ALL_TABLES[tname](ctx))
    if "roofline" in want:
        try:
            rows.extend(roofline_rows())
        except Exception as e:  # dry-run artifacts missing
            print(f"# roofline skipped: {e}", file=sys.stderr)
    if "kernels" in want:
        rows.extend(kernel_rows())

    if rows or needs_ctx:
        print("name,us_per_call,derived")
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{json.dumps(r['derived'])}")


if __name__ == "__main__":
    main()
