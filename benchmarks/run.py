"""Benchmark driver: one function per paper table (DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.run [--fast] [--tables table4,fig4]

Prints ``name,us_per_call,derived`` CSV. Selection tables use the full-scale
synthetic benchmarks (199/4,287 and 2,413/600); latency rows measure the real
CPU serving path including the 22M-parameter encoder forward. Roofline rows
are emitted if experiments/dryrun/*.json exist (run repro.launch.dryrun
first).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced benchmark scale")
    ap.add_argument("--tables", default="all")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from benchmarks.context import BenchContext
    from benchmarks.kernel_bench import kernel_rows
    from benchmarks.roofline import roofline_rows
    from benchmarks.tables import ALL_TABLES

    want = list(ALL_TABLES) + ["roofline", "kernels"]
    if args.tables != "all":
        want = args.tables.split(",")

    rows = []
    needs_ctx = any(t in ALL_TABLES for t in want)
    if needs_ctx:
        t0 = time.time()
        ctx = BenchContext.build(seed=args.seed, fast=args.fast)
        print(f"# context built in {time.time() - t0:.1f}s", flush=True)
        for tname in want:
            if tname in ALL_TABLES:
                rows.extend(ALL_TABLES[tname](ctx))
    if "roofline" in want:
        try:
            rows.extend(roofline_rows())
        except Exception as e:  # dry-run artifacts missing
            print(f"# roofline skipped: {e}", file=sys.stderr)
    if "kernels" in want:
        rows.extend(kernel_rows())

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{json.dumps(r['derived'])}")


if __name__ == "__main__":
    main()
