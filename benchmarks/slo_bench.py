"""SLO + quality-observability smoke: burn-rate alerts and drift detection
fire end-to-end, with correct stamps, against live serving traffic.

  PYTHONPATH=src python -m benchmarks.slo_bench [--smoke] [--out BENCH_slo.json]

Two threaded scenarios, both enforced with SystemExit (CI smoke-runs this
via scripts/ci_check.sh):

1. **Burn**: a serving thread routes batches while a second-scale latency
   SLO (10 ms threshold, the paper's budget) is evaluated on a real
   `TimeSeriesRing` cadence. Injected embed latency pushes every batch past
   the threshold: the engine must publish ``slo_burn`` (with threshold,
   live p99, and a resolvable p99 trace exemplar), ``/slo`` must report the
   SLO burning, ``/health`` must degrade — and removing the latency must
   publish ``slo_recovered`` and return ``/health`` to ok. The ring daemon
   must finish with ``last_loop_error`` clean.

2. **Drift**: a bad table (row-shuffled AND mean-shifted — a pure shuffle
   leaves the population stats the drift detector compares against
   unchanged) is swapped under live traffic. The label-free
   ``quality_drift`` event must land BEFORE the labelled `TableGuard`
   rollback (strictly smaller bus seq) with the condemned version stamped,
   and the detector must re-arm once the rollback restores a good table.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

BATCH = 16
TICK_S = 0.25  # ring cadence: every tick also evaluates the SLO engine
SLOW_EMBED_S = 0.015  # injected per-batch embed latency (> the 10 ms budget)


def _build_router(bench, enc, registry, tracer=None, bus=None, quality=None,
                  embed_batch_fn=None):
    from repro.index import ToolIndexManager
    from repro.router.gateway import SemanticRouter
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    db = ToolsDatabase(
        [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
         for i in range(bench.n_tools)],
        enc.encode(bench.desc_tokens),
    )
    if bus is not None:
        bus.watch_db(db)
    if quality is not None:
        quality.watch_db(db)
    index = ToolIndexManager(db, backend="dense", metrics=registry, bus=bus)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one,
        embed_batch_fn=embed_batch_fn or enc.encode, k=5,
        index=index, metrics=registry, tracer=tracer, bus=bus,
        quality=quality,
    )
    return db, router


def _serve_thread(router, blocks):
    """Route batches on a daemon thread until stopped; surfaces exceptions."""
    stop = threading.Event()
    errors = []

    def loop():
        i = 0
        try:
            while not stop.is_set():
                router.route_batch(blocks[i % len(blocks)])
                i += 1
        except Exception as exc:
            errors.append(exc)

    t = threading.Thread(target=loop, name="slo-smoke-serve", daemon=True)
    t.start()
    return stop, t, errors


def _wait_for(pred, timeout_s: float, what: str):
    """Poll `pred` until truthy; SystemExit with `what` on timeout."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        v = pred()
        if v:
            return v
        time.sleep(0.05)
    raise SystemExit(f"timed out after {timeout_s:.0f}s waiting for {what}")


def _fetch(url: str):
    from urllib.error import HTTPError
    from urllib.request import urlopen

    try:
        with urlopen(url, timeout=5) as resp:
            return resp.status, json.loads(resp.read())
    except HTTPError as exc:  # 503 /health still carries the snapshot
        return exc.code, json.loads(exc.fp.read())


def run_burn(bench, enc, smoke: bool, seed: int) -> dict:
    """Scenario 1: latency injection -> slo_burn -> recovery -> slo_recovered."""
    from repro.obs import (
        SLO,
        BurnWindow,
        EventBus,
        HealthMonitor,
        MetricsRegistry,
        ObsServer,
        QualityMonitor,
        RouteTracer,
        SLOEngine,
        TimeSeriesRing,
    )

    registry = MetricsRegistry()
    bus = EventBus()
    tracer = RouteTracer(sample_every=1, seed=seed)
    quality = QualityMonitor(registry=registry, bus=bus)

    delay = {"s": 0.0}  # mutable latency injection knob, read per batch

    def slow_embed(tokens):
        if delay["s"]:
            time.sleep(delay["s"])
        return enc.encode(tokens)

    db, router = _build_router(
        bench, enc, registry, tracer=tracer, bus=bus, quality=quality,
        embed_batch_fn=slow_embed,
    )
    # second-scale windows; objective 0.90 (not the production 0.99) so a
    # stray slow batch on a noisy CI host needs >10% of the window to burn
    slo = SLO(
        name="route_latency_budget",
        kind="latency",
        description="smoke-scale: 90% of batches inside the 10 ms budget",
        hist_key="route_batch_ms",
        threshold_ms=10.0,
        objective=0.90,
        windows=(BurnWindow(long_s=2.0, short_s=0.6, factor=1.0),),
    )
    ring = TimeSeriesRing(registry, bus=bus)
    engine = SLOEngine(ring, slos=(slo,), bus=bus, registry=registry)
    monitor = HealthMonitor(routers=[router], bus=bus, slo=engine)
    server = ObsServer(monitor=monitor, registry=registry, bus=bus,
                       slo=engine, tracer=tracer).start()
    base = f"http://{server.host}:{server.port}"

    blocks = [
        [bench.query_tokens[qi] for qi in bench.train_idx[lo : lo + BATCH]]
        for lo in range(0, BATCH * 4, BATCH)
    ]
    for b in blocks:  # jit warmup off the ring, so the first window is clean
        router.route_batch(b)

    ring.start(interval_s=TICK_S, on_tick=lambda r: engine.evaluate())
    stop, t, serve_errors = _serve_thread(router, blocks)
    try:
        # healthy window: enough ticks for both windows, no burn
        time.sleep(1.2)
        code, snap = _fetch(f"{base}/slo")
        if code != 200 or snap["status"] != "ok" or snap["burning"]:
            raise SystemExit(f"healthy traffic already burning: {snap['status']}"
                             f" burning={snap['burning']}")
        if bus.last("slo_burn") is not None:
            raise SystemExit("slo_burn published during the healthy window")

        # breach: every batch now pays >10 ms in embed
        delay["s"] = SLOW_EMBED_S
        burn_ev = _wait_for(lambda: bus.last("slo_burn"), 20.0,
                            "slo_burn after latency injection")
        code, snap = _fetch(f"{base}/slo")
        if snap["status"] != "burning" or "route_latency_budget" not in snap["burning"]:
            raise SystemExit(f"/slo does not report the breach: {snap['status']} "
                             f"burning={snap['burning']}")
        entry = snap["slos"]["route_latency_budget"]
        if entry.get("p99_ms") is None or entry["p99_ms"] <= 10.0:
            raise SystemExit(f"burning latency SLO without p99 evidence: {entry}")
        code, health = _fetch(f"{base}/health")
        if health["status"] != "degraded" or code != 200:
            raise SystemExit(f"burning SLO did not degrade /health: "
                             f"{health['status']} (HTTP {code})")
        if "route_latency_budget" not in health["slo"]["burning"]:
            raise SystemExit(f"/health slo section missing the burn: {health['slo']}")
        d = burn_ev.details
        if d["slo"] != "route_latency_budget" or d["threshold_ms"] != 10.0:
            raise SystemExit(f"slo_burn mis-stamped: {d}")
        exemplar = d.get("p99_exemplar")
        if exemplar is None:
            raise SystemExit(f"slo_burn carries no p99 exemplar (tracer samples "
                             f"every batch): {d}")
        code, trace = _fetch(f"{base}/traces?id={exemplar}")
        if code != 200 or "spans" not in trace:
            raise SystemExit(f"p99 exemplar trace #{exemplar} did not resolve "
                             f"over /traces?id= (HTTP {code})")

        # recovery: fast traffic refills the windows, breach must clear
        delay["s"] = 0.0
        _wait_for(lambda: bus.last("slo_recovered"), 25.0,
                  "slo_recovered after removing the latency")
        code, health = _fetch(f"{base}/health")
        if health["status"] != "ok":
            raise SystemExit(f"/health still {health['status']} after recovery")
    finally:
        stop.set()
        t.join(timeout=30)
        ring.stop()
        server.stop()

    if serve_errors:
        raise SystemExit(f"serving thread failed during the burn smoke: "
                         f"{serve_errors[0]!r}")
    if ring.last_loop_error is not None:
        raise SystemExit(f"ring daemon flapped: {ring.last_loop_error}")
    rec_ev = bus.last("slo_recovered")
    row = {
        "slo": "route_latency_budget",
        "burn_seq": burn_ev.seq,
        "recovered_seq": rec_ev.seq,
        "burn_details": dict(burn_ev.details),
        "p99_exemplar_resolved": int(exemplar),
        "ring_points": len(ring),
        "quality": quality.summary(),
    }
    print(f"burn: slo_burn seq={burn_ev.seq} "
          f"(p99={d.get('p99_ms', float('nan')):.2f}ms, exemplar trace "
          f"#{exemplar}) -> slo_recovered seq={rec_ev.seq} | "
          f"{row['ring_points']} ring points", flush=True)
    router.close()
    return row


def run_drift(bench, enc, smoke: bool, seed: int) -> dict:
    """Scenario 2: bad swap -> label-free quality_drift BEFORE the rollback."""
    from repro.control import GuardConfig, TableGuard
    from repro.obs import EventBus, MetricsRegistry, QualityMonitor

    registry = MetricsRegistry()
    bus = EventBus()
    quality = QualityMonitor(registry=registry, bus=bus)
    db, router = _build_router(bench, enc, registry, bus=bus, quality=quality)
    guard = TableGuard(db, GuardConfig(min_samples=32), bus=bus)

    blocks = [
        [bench.query_tokens[qi] for qi in bench.train_idx[lo : lo + BATCH]]
        for lo in range(0, BATCH * 4, BATCH)
    ]
    stop, t, serve_errors = _serve_thread(router, blocks)
    try:
        # healthy window: drift detector warms past min_batches, guard
        # collects a labelled baseline on v0
        v0 = db.table_version
        _wait_for(lambda: quality.summary()["n_batches"]
                  >= quality.config.drift_min_batches + 2,
                  10.0, "drift detector warmup batches")
        for _ in range(40):
            guard.observe(v0, [1, 2, 3], [1])
        if quality.drifting:
            raise SystemExit("drift latch set on healthy traffic")

        # bad swap: shuffle breaks per-tool geometry (what the *labels* will
        # catch); the mean shift moves the population stats (what the
        # label-free detector catches immediately)
        rng = np.random.default_rng(seed)
        bad = db.embeddings.copy()
        rng.shuffle(bad, axis=0)
        bad += 3.0 * bad.std()
        v_bad = db.swap_table(bad, expect_current=v0)

        drift_ev = _wait_for(lambda: bus.last("quality_drift"), 10.0,
                             "quality_drift after the bad swap")
        guard.check()  # unannounced swap: baseline frozen from v0's window
        for _ in range(40):
            guard.observe(v_bad, [1, 2, 3], [9])
        report = guard.check()
        if report.action != "rolled_back":
            raise SystemExit(f"guard did not roll back the bad table: "
                             f"{report.action}")
        v_restored = db.table_version

        # re-arm: the restored table's stats match the traffic again
        _wait_for(lambda: not quality.drifting, 10.0,
                  "drift latch re-arm after rollback")
    finally:
        stop.set()
        t.join(timeout=30)

    if serve_errors:
        raise SystemExit(f"serving thread failed during the drift smoke: "
                         f"{serve_errors[0]!r}")
    rollback_ev = bus.last("rollback")
    if rollback_ev is None:
        raise SystemExit("rollback event never reached the bus")
    if drift_ev.seq >= rollback_ev.seq:
        raise SystemExit(
            f"label-free drift (seq {drift_ev.seq}) did not precede the "
            f"labelled rollback (seq {rollback_ev.seq})"
        )
    dd = drift_ev.details
    if dd["table_version"] != v_bad or dd["score"] <= dd["threshold"]:
        raise SystemExit(f"quality_drift mis-stamped: {dd} (bad table v{v_bad})")
    rd = rollback_ev.details
    if (rd["condemned_version"] != v_bad
            or rd["restored_version"] != v_restored):
        raise SystemExit(f"rollback mis-stamped: {rd} "
                         f"(condemned v{v_bad}, restored v{v_restored})")
    row = {
        "drift_seq": drift_ev.seq,
        "rollback_seq": rollback_ev.seq,
        "lead_events": rollback_ev.seq - drift_ev.seq,
        "drift_details": dict(dd),
        "rollback_details": dict(rd),
        "rearmed": not quality.drifting,
        "quality": quality.summary(),
    }
    print(f"drift: quality_drift seq={drift_ev.seq} "
          f"(score={dd['score']:.2f} vs {dd['threshold']:.2f}) preceded "
          f"rollback seq={rollback_ev.seq} by {row['lead_events']} events | "
          f"re-armed={row['rearmed']}", flush=True)
    router.close()
    return row


def run(smoke: bool = False, seed: int = 0, out: str = "BENCH_slo.json") -> dict:
    from repro.data.benchmarks import make_metatool_like
    from repro.embedding.bag_encoder import BagEncoder

    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    bench = make_metatool_like(seed=seed, n_tools=64 if smoke else 199,
                               n_queries=256 if smoke else 600)
    enc = BagEncoder(bench.vocab)
    burn = run_burn(bench, enc, smoke, seed)
    drift = run_drift(bench, enc, smoke, seed)
    report = {
        "bench": "slo_quality",
        "burn": burn,
        "drift": drift,
        "derived": {
            "burn_to_recovery_events": burn["recovered_seq"] - burn["burn_seq"],
            "drift_lead_events": drift["lead_events"],
            "smoke": smoke,
        },
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"slo smoke: burn+recovery and drift-before-rollback verified -> {out}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced scale for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_slo.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
