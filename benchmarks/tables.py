"""One function per paper table/figure (DESIGN.md §8). Each returns CSV rows
(name, us_per_call, derived) where `derived` is a compact metrics dict."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import numpy as np

from benchmarks.context import PAPER_NDCG5, PAPER_R1, BenchContext

Row = Dict[str, object]


def _timed(fn: Callable) -> tuple:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def table1_cost_of_mechanisms(ctx: BenchContext) -> List[Row]:
    """Table 1: latency + parameters + viability at 10K rps (ToolBench scale)."""
    rows = []
    lat = ctx.latency["toolbench-like"]
    params = {
        "bm25": 0, "se": 22_000_000, "oats-s1": 22_000_000,
        "oats-s2": 22_002_625, "oats-s3": 22_199_873,
    }
    for method, stats in lat.items():
        rows.append({
            "name": f"table1/{method}",
            "us_per_call": round(stats.p50_ms * 1e3, 1),
            "derived": {
                "p50_ms": round(stats.p50_ms, 3),
                "params": params.get(method, 0),
                "gpu_required": False,
                "viable_10k_rps": stats.p50_ms < 10.0,
            },
        })
    return rows


def table2_cost_efficiency(ctx: BenchContext) -> List[Row]:
    """Table 2: NDCG@5 gain per added millisecond vs the SE baseline."""
    rows = []
    for bname, res in ctx.results.items():
        base_n = res["se"].metrics["ndcg@5"]
        base_l = ctx.latency[bname]["se"].p50_ms
        for method in ("oats-s1", "oats-s3", "se+lexical"):
            dn = res[method].metrics["ndcg@5"] - base_n
            dl = ctx.latency[bname].get(method, ctx.latency[bname]["se"]).p50_ms - base_l
            agms = "inf" if dl <= 0.05 and dn > 0 else (round(dn / dl, 4) if dl > 0 else "n/a")
            rows.append({
                "name": f"table2/{bname}/{method}",
                "us_per_call": 0,
                "derived": {"delta_ndcg5": round(dn, 4), "delta_ms": round(dl, 3),
                            "ag_per_ms": agms},
            })
    return rows


def table3_similar_choices(ctx: BenchContext) -> List[Row]:
    """Table 3: the hardest MetaTool subtask ('similar choices') — retrieval
    methods vs published LLM-based CSR numbers."""
    published = {"chatgpt": 0.691, "vicuna-7b": 0.735, "vicuna-13b": 0.582,
                 "llama2-13b": 0.441}
    rows = [
        {"name": f"table3/llm/{k}", "us_per_call": 2_000_000,  # ~2s LLM call
         "derived": {"accuracy": v, "hardware": "GPU", "source": "Huang et al. 2024"}}
        for k, v in published.items()
    ]
    res = ctx.results["metatool-like"]
    lat = ctx.latency["metatool-like"]
    for method in ("bm25", "se", "oats-s1"):
        acc = res[method].per_subtask["similar"]["recall@1"]
        rows.append({
            "name": f"table3/ours/{method}",
            "us_per_call": round(lat[method].p50_ms * 1e3, 1),
            "derived": {"recall@1_similar": round(acc, 3), "hardware": "CPU"},
        })
    return rows


def table4_selection(ctx: BenchContext) -> List[Row]:
    """Table 4: main selection results, side by side with the paper."""
    rows = []
    for bname, res in ctx.results.items():
        for method, r in res.items():
            m = r.metrics
            rows.append({
                "name": f"table4/{bname}/{method}",
                "us_per_call": 0,
                "derived": {
                    "r@1": round(m["recall@1"], 3),
                    "r@3": round(m["recall@3"], 3),
                    "r@5": round(m["recall@5"], 3),
                    "ndcg@5": round(m["ndcg@5"], 3),
                    "mrr": round(m["mrr"], 3),
                    "paper_ndcg@5": PAPER_NDCG5[bname].get(method),
                    "paper_r@1": PAPER_R1[bname].get(method),
                },
            })
    return rows


def table5_ablation(ctx: BenchContext) -> List[Row]:
    """Table 5: incremental contribution of each OATS component."""
    rows = []
    added = {"se": 0, "oats-s1": 0, "oats-s2": 2625, "oats-s3": 2625 + 197_248}
    for bname, res in ctx.results.items():
        base = res["se"].metrics["ndcg@5"]
        for method in ("se", "oats-s1", "oats-s2", "oats-s3"):
            n = res[method].metrics["ndcg@5"]
            rows.append({
                "name": f"table5/{bname}/{method}",
                "us_per_call": 0,
                "derived": {
                    "ndcg@5": round(n, 3),
                    "delta_vs_se": round(n - base, 3),
                    "added_params": added[method],
                    "paper_ndcg@5": PAPER_NDCG5[bname].get(method),
                },
            })
    return rows


def table6_latency(ctx: BenchContext) -> List[Row]:
    """Table 6: per-request p50/p99 (CPU-only), all single-digit-ms p50."""
    rows = []
    for bname, lat in ctx.latency.items():
        for method, stats in lat.items():
            rows.append({
                "name": f"table6/{bname}/{method}",
                "us_per_call": round(stats.p50_ms * 1e3, 1),
                "derived": {
                    "p50_ms": round(stats.p50_ms, 3),
                    "p99_ms": round(stats.p99_ms, 3),
                    "single_digit_ms_p50": stats.p50_ms < 10.0,
                },
            })
    return rows


def fig4_convergence(ctx: BenchContext) -> List[Row]:
    """Fig. 4: Stage-1 NDCG@5 across refinement iterations (N=0..3)."""
    import jax.numpy as jnp

    from repro.metrics.retrieval import batched_ndcg_at_k

    rows = []
    for bname, bench in ctx.benches.items():
        ev = ctx.evaluators[bname]
        pipe = ctx.results[bname]["oats-s1"].pipeline
        history = np.asarray(pipe.refine_result.history)  # [N+1, T, D]
        test = bench.test_idx
        qe = ev.query_emb[test]
        rel = ev.relevance[test]
        cm = None if ev.cand_mask is None else ev.cand_mask[test]
        for n in range(history.shape[0]):
            sims = qe @ history[n].T
            if cm is not None:
                sims = np.where(cm > 0, sims, -1e30)
            topk = np.argsort(-sims, axis=1)[:, :5]
            ndcg = float(batched_ndcg_at_k(jnp.asarray(topk), jnp.asarray(rel)))
            rows.append({
                "name": f"fig4/{bname}/iter{n}",
                "us_per_call": 0,
                "derived": {"ndcg@5": round(ndcg, 4)},
            })
    return rows


ALL_TABLES = {
    "table1": table1_cost_of_mechanisms,
    "table2": table2_cost_efficiency,
    "table3": table3_similar_choices,
    "table4": table4_selection,
    "table5": table5_ablation,
    "table6": table6_latency,
    "fig4": fig4_convergence,
}
