"""Route cache in front of the gateway, end to end, on real Zipf traffic.

  PYTHONPATH=src python examples/cached_gateway.py [--n-tools N] [--batches N]

Production traffic is not i.i.d.: a few intents dominate ("what's the
weather", "summarize this") and most arrivals are near-duplicates of
something routed seconds ago. This demo builds the same serving stack as
`launch/serve.py --route-cache`, but small and inline so every moving part
is visible:

  1. a 6k-tool corpus behind a `SemanticRouter`;
  2. a `SemanticRouteCache` attached to it (LSH probe in embedding space,
     cosine threshold 0.95, every entry stamped with the live
     `(table_version, stage_version)` pair);
  3. a seeded Zipfian near-duplicate stream (`repro.traffic`) replayed
     through a bare router and the cached one — identical queries, so the
     printed agreement is a real routing-decision comparison;
  4. a mid-stream control-plane swap, to show the version-stamp discipline:
     the swap bumps `table_version`, the whole cache goes cold (watch the
     `cache_invalidated` event), hit-rate dips and recovers, and the
     staleness gate in `repro.traffic.drive` confirms nothing was served
     from the dead snapshot.

The full measurement (25k tools, three Zipf exponents, churn leg, CI
gates) lives in `benchmarks/cache_bench.py`; this is the 30-second tour.
"""
import argparse

import numpy as np

from repro.cache import CacheConfig, SemanticRouteCache
from repro.data.benchmarks import make_metatool_like, scale_tool_corpus
from repro.embedding.bag_encoder import BagEncoder
from repro.obs import EventBus
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase
from repro.traffic import TrafficConfig, ZipfTrafficGenerator, agreement, drive

QUERY_LEN = 24  # tiled intent length: 1-token jitter keeps cosine ~0.958


def build_router(n_tools: int, cache, bus=None):
    bench = make_metatool_like(seed=0, n_queries=400)
    enc = BagEncoder(bench.vocab)
    table = scale_tool_corpus(enc.encode(bench.desc_tokens), n_tools,
                              seed=0, noise=0.2)
    records = [ToolRecord(i, f"t{i}", bench.desc_tokens[i % bench.n_tools], 0)
               for i in range(n_tools)]
    db = ToolsDatabase(records, table)
    router = SemanticRouter(db, embed_fn=enc.encode_one,
                            embed_batch_fn=enc.encode, k=5,
                            metrics=False, cache=cache)
    if cache is not None and bus is not None:
        bus.watch_db(db)  # db publishes swap/rollback lifecycle events...
        cache.watch(bus)  # ...and the cache eagerly purges on each one
    # pool of real train-split intents, token-tiled to QUERY_LEN so the
    # bag-encoder direction is preserved exactly
    pool = [np.tile(t, -(-QUERY_LEN // len(t)))
            for t in (bench.query_tokens[i] for i in bench.train_idx)]
    return router, pool


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n-tools", type=int, default=6000)
    ap.add_argument("--batches", type=int, default=60)
    args = ap.parse_args(argv)

    bus = EventBus()
    cache = SemanticRouteCache(CacheConfig(threshold=0.95), metrics=False,
                               bus=bus)
    cached, pool = build_router(args.n_tools, cache, bus=bus)
    bare, _ = build_router(args.n_tools, None)

    cfg = TrafficConfig(zipf_s=1.1, pool_size=256, query_len=QUERY_LEN,
                        batch_size=32, paraphrase_p=0.35, jitter_tokens=1,
                        seed=3)
    batches = list(ZipfTrafficGenerator(cfg, pool=pool).stream(args.batches))

    # compile every pow2 miss-bucket shape once, then forget the warmup
    for m in (1, 2, 4, 8, 16, 32):
        cached.route_batch(batches[0][:m])
        bare.route_batch(batches[0][:m])
    cache.clear()

    # fire one content-identical table swap a third of the way in: the
    # version bump MUST invalidate the cache without changing routing
    swap_at = max(1, args.batches // 3)

    def churn(i: int) -> None:
        if i == swap_at:
            version, live = cached.db.snapshot()
            cached.db.swap_table(live.copy(), expect_current=version)

    try:
        rep_c = drive(cached, batches, record=True, on_batch=churn)
        rep_b = drive(bare, batches, record=True)
    finally:
        cached.close()
        bare.close()

    agr = agreement(rep_c.results, rep_b.results)
    purges = bus.events(kind="cache_invalidated")
    print(f"tools={args.n_tools}  batches={rep_c.batches}  "
          f"queries={rep_c.queries}")
    print(f"cached: {rep_c.qps:8.0f} qps  p50={rep_c.p50_ms:5.2f}ms  "
          f"p99={rep_c.p99_ms:5.2f}ms  hit_rate={rep_c.hit_rate:.3f}")
    print(f"bare:   {rep_b.qps:8.0f} qps  p50={rep_b.p50_ms:5.2f}ms  "
          f"p99={rep_b.p99_ms:5.2f}ms")
    print(f"speedup {rep_c.qps / rep_b.qps:.2f}x at top-1 agreement {agr:.4f}")
    print(f"swap at batch {swap_at}: {len(purges)} cache_invalidated "
          f"event(s), {cache.stats['invalidated']} entries purged, "
          f"stale serves {rep_c.stale_serves} (must be 0)")
    return 1 if rep_c.stale_serves else 0


if __name__ == "__main__":
    raise SystemExit(main())
