"""Strings-in, tokens-out: the full gateway + continuous-batching pool.

  PYTHONPATH=src python examples/continuous_batching.py

Text requests -> HashTokenizer -> SemanticRouter (OATS-S1 table) selects
tools -> requests enter the backend pool's continuous batcher (fixed decode
slots, batched steps) -> responses retire as slots free up.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.data.benchmarks import make_metatool_like
from repro.embedding.bag_encoder import BagEncoder
from repro.embedding.tokenizer import HashTokenizer
from repro.launch.serve import build_router
from repro.models import model as M
from repro.models.config import reduced
from repro.router.scheduler import ContinuousBatcher, Request

bench = make_metatool_like(n_tools=120, n_queries=800)
router, _ = build_router(bench, "oats-s1")
tok = HashTokenizer(bench.vocab)
tok.register_tool_names([f"tool_{i}" for i in range(bench.n_tools)])

cfg = reduced(get_config("granite-3-8b"))
params = M.init(cfg, jax.random.PRNGKey(0))
batcher = ContinuousBatcher(cfg, params, n_slots=3, max_len=48)

requests = [
    "summarize the strategy call transcript with tool_7 please",
    "find discount codes for my hotel booking",
    "translate this paragraph to japanese",
    "what were the key points from last week's meeting",
    "convert 120 usd to eur",
]
rng = np.random.default_rng(0)
for i, text in enumerate(requests):
    toks = tok.encode(text)
    route = router.route(toks)
    prompt = rng.integers(0, cfg.vocab_size, (8 + len(toks),)).astype(np.int32)
    batcher.submit(Request(request_id=i, prompt=prompt, max_new_tokens=6, tools=route.tools))
    print(f"req {i}: route {route.latency_ms:5.2f}ms tools={route.tools[:3]}... queued")

done = batcher.run_until_drained()
print(f"\ndrained in {batcher.tick_count} ticks ({len(done)} responses):")
for r in sorted(done, key=lambda r: r.request_id):
    print(f"  req {r.request_id}: admitted@{r.admitted_at_tick} finished@{r.finished_at_tick} "
          f"tokens={r.generated}")
