"""The online control plane end-to-end (paper §7.2 as a running subsystem):

    serve -> outcome sink -> OutcomeStore -> RefinementController trigger ->
    refine_with_gate -> atomic swap -> TableGuard shadow monitoring ->
    (injected bad table) -> automatic rollback

  PYTHONPATH=src python examples/live_loop.py

Unlike examples/refine_loop.py (which wires refine_with_gate to the router
by hand, cron-style), everything here flows through `repro.control`: the
router pushes every outcome straight into the store, the controller decides
when to refine and swaps accepted tables while traffic keeps flowing, and
the guard watches rolling NDCG@5 per table version on labelled traffic.

Act 2 injects a corrupted table *bypassing the validation gate* (the
failure shadow monitoring exists for) and shows the guard condemning and
rolling it back automatically.
"""
import numpy as np

from repro.control import (
    ControllerConfig,
    GuardConfig,
    OutcomeStore,
    RefinementController,
    TableGuard,
)
from repro.data.benchmarks import make_metatool_like
from repro.embedding.bag_encoder import BagEncoder
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase

bench = make_metatool_like(n_tools=199, n_queries=2400)
enc = BagEncoder(bench.vocab)
db = ToolsDatabase(
    [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
     for i in range(bench.n_tools)],
    enc.encode(bench.desc_tokens),
)
store = OutcomeStore(n_tools=len(db), capacity=100_000)
router = SemanticRouter(
    db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
    outcome_sink=store.append,  # every outcome goes straight to the store
)
guard = TableGuard(db, GuardConfig(k=5, min_samples=64, tolerance=0.02))
controller = RefinementController(
    db, store, enc.encode, routers=[router],
    config=ControllerConfig(min_events=1500, min_queries=50),
    guard=guard,
)


def serve_window(idx, batch_size=64):
    """Route a traffic window batch-first; log outcomes + guard labels."""
    for lo in range(0, len(idx), batch_size):
        chunk = idx[lo : lo + batch_size]
        results = router.route_batch([bench.query_tokens[qi] for qi in chunk])
        for qi, res in zip(chunk, results):
            for t in res.tools:
                router.record_outcome(
                    bench.query_tokens[qi], t, int(t in bench.relevant[qi])
                )
            guard.observe(res.table_version, res.tools, bench.relevant[qi])


def heldout_ndcg(n=300):
    from repro.metrics.retrieval import ndcg_at_k

    idx = bench.test_idx[:n]
    results = router.route_batch([bench.query_tokens[qi] for qi in idx])
    return float(np.mean([
        ndcg_at_k(res.tools, bench.relevant[qi], 5) for qi, res in zip(idx, results)
    ]))


print(f"act 1 — streamed outcomes close the refinement loop "
      f"({bench.n_tools} tools, {len(bench.train_idx)} train queries)")
ndcg_static = heldout_ndcg()
print(f"  window 0 (static table v0): heldout NDCG@5 = {ndcg_static:.3f}")
windows = np.array_split(bench.train_idx, 4)
for w, idx in enumerate(windows, 1):
    serve_window(idx)
    report = controller.step()
    print(f"  window {w}: {report.n_events} events in store "
          f"({report.n_queries} unique queries), "
          f"{'SWAP' if report.swapped else 'no swap'} -> table v{report.table_version}"
          f" | {report.reason}")
    print(f"            heldout NDCG@5 = {heldout_ndcg():.3f}")

v_good = db.table_version
ndcg_good = heldout_ndcg()
assert v_good > 0, "expected at least one accepted swap in act 1"
assert ndcg_good > ndcg_static, (
    f"accepted swaps did not improve heldout NDCG@5 "
    f"({ndcg_static:.3f} -> {ndcg_good:.3f})"
)
# serve labelled traffic on the final good table so the guard has a frozen
# baseline window for it before anything replaces it
serve_window(bench.test_idx[:300])

print("\nact 2 — a corrupted table bypasses the gate; the guard rolls it back")
rng = np.random.default_rng(0)
bad = db.embeddings.copy()
rng.shuffle(bad, axis=0)  # tool vectors scrambled across tools
db.swap_table(bad)
print(f"  injected bad table: v{db.table_version} "
      f"(heldout NDCG@5 = {heldout_ndcg():.3f})")
for w, idx in enumerate(np.array_split(bench.test_idx, 3), 1):
    serve_window(idx)
    report = controller.step()
    g = report.guard
    print(f"  shadow window {w}: guard={g.action} "
          f"(ndcg={g.ndcg if g.ndcg is None else round(g.ndcg, 3)}, "
          f"baseline={g.baseline if g.baseline is None else round(g.baseline, 3)}, "
          f"n={g.n_samples}) -> table v{db.table_version}")
    if g.action == "rolled_back":
        break

assert guard.rollbacks, "guard failed to roll back the corrupted table"
restored = heldout_ndcg()
print(f"  restored table v{db.table_version}: heldout NDCG@5 = {restored:.3f} "
      f"(good table was {ndcg_good:.3f})")
assert abs(restored - ndcg_good) < 1e-6, "rollback did not restore the good table"
print("\nloop closed: outcomes -> refine -> validate -> swap -> monitor -> rollback")
