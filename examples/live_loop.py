"""The online control + learning planes end-to-end (paper §7.2-7.3 as
running subsystems).

Default mode — the §7.2 refinement loop (PR 2):

    serve -> outcome sink -> OutcomeStore -> RefinementController trigger ->
    refine_with_gate -> atomic swap -> TableGuard shadow monitoring ->
    (injected bad table) -> automatic rollback

  PYTHONPATH=src python examples/live_loop.py

`--stages` mode — the §7.3 learning plane (PR 4): density-gated training,
promotion, and demotion of the *learned* stages against the live router:

    serve (sparse window)  -> LearningController: adapter AND re-ranker
                              suppressed by the recommend_stages density plan
    serve (dense window)   -> adapter trained from the outcome window,
                              held-out NDCG@5 gate passed, activated via
                              compare-and-swap StageSet promotion (asserted
                              NDCG lift); the re-ranker stays suppressed —
                              the paper's sparse-regime negative result as
                              live behavior
    inject corrupted stage -> StageGuard shadow monitoring condemns it on
                              labelled traffic and auto-demotes back to the
                              good StageSet

  PYTHONPATH=src python examples/live_loop.py --stages

Unlike examples/refine_loop.py (which wires refine_with_gate to the router
by hand, cron-style), everything here flows through `repro.control` /
`repro.learn`: the router pushes every outcome straight into the store, the
controllers decide when to refine/train and deploy gated artifacts while
traffic keeps flowing, and the guards watch rolling NDCG@5 per version on
labelled traffic.
"""
import argparse
import dataclasses

import numpy as np

from repro.control import (
    ControllerConfig,
    GuardConfig,
    OutcomeStore,
    RefinementController,
    TableGuard,
)
from repro.data.benchmarks import make_metatool_like
from repro.embedding.bag_encoder import BagEncoder
from repro.metrics.retrieval import ndcg_at_k
from repro.obs import EventBus, HealthMonitor, QualityMonitor
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase


def build_serving_plane(bench, store_capacity=100_000, bus=None, quality=None):
    enc = BagEncoder(bench.vocab)
    db = ToolsDatabase(
        [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
         for i in range(bench.n_tools)],
        enc.encode(bench.desc_tokens),
    )
    if bus is not None:
        bus.watch_db(db)  # every swap — controller, guard, injected — lands
    if quality is not None:
        quality.watch_db(db)  # live table stats = the drift reference
    store = OutcomeStore(n_tools=len(db), capacity=store_capacity)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,  # every outcome goes straight to the store
        bus=bus,
        quality=quality,
    )
    return enc, db, store, router


def print_timeline(bus, monitor, quality=None):
    """The telemetry plane's view of what the demo just did."""
    print("\nlifecycle event bus:")
    for e in bus.events():
        detail = ", ".join(f"{k}={v}" for k, v in sorted(e.details.items()))
        print(f"  [{e.seq:3d}] {e.plane:8s} {e.kind:15s} {detail}")
    snap = monitor.snapshot()
    print(f"health: {snap['status']} (control planes: "
          f"{[c['last_loop_error'] for c in snap['control']]})")
    if quality is not None:
        q = quality.summary()
        drift = q["drift_score"]
        print(f"quality: rolling NDCG@{q['k']}="
              f"{q['ndcg'] if q['ndcg'] is None else round(q['ndcg'], 3)} "
              f"over {q['n_labelled']} labels | "
              f"drift_score={drift if drift is None else round(drift, 3)} "
              f"({q['drift_events']} drift event(s))")


def serve_window(bench, router, idx, observe=None, batch_size=64):
    """Route a traffic window batch-first; log outcomes (+ guard labels)."""
    for lo in range(0, len(idx), batch_size):
        chunk = idx[lo : lo + batch_size]
        results = router.route_batch([bench.query_tokens[qi] for qi in chunk])
        for qi, res in zip(chunk, results):
            for t in res.tools:
                router.record_outcome(
                    bench.query_tokens[qi], t, int(t in bench.relevant[qi])
                )
            if observe is not None:
                observe(res, bench.relevant[qi])


def heldout_ndcg(bench, router, n=300):
    idx = bench.test_idx[:n]
    results = router.route_batch([bench.query_tokens[qi] for qi in idx])
    return float(np.mean([
        ndcg_at_k(res.tools, bench.relevant[qi], 5) for qi, res in zip(idx, results)
    ]))


# --------------------------------------------------------------- §7.2 (PR 2)
def run_refine_demo():
    bench = make_metatool_like(n_tools=199, n_queries=2400)
    bus = EventBus()
    quality = QualityMonitor(bus=bus)
    enc, db, store, router = build_serving_plane(bench, bus=bus, quality=quality)
    guard = TableGuard(db, GuardConfig(k=5, min_samples=64, tolerance=0.02),
                       bus=bus)
    controller = RefinementController(
        db, store, enc.encode, routers=[router],
        config=ControllerConfig(min_events=1500, min_queries=50),
        guard=guard, bus=bus,
    )

    def observe(res, relevant):
        guard.observe(res.table_version, res.tools, relevant)
        quality.observe(res.tools, relevant)  # the streaming rolling view

    print(f"act 1 — streamed outcomes close the refinement loop "
          f"({bench.n_tools} tools, {len(bench.train_idx)} train queries)")
    ndcg_static = heldout_ndcg(bench, router)
    print(f"  window 0 (static table v0): heldout NDCG@5 = {ndcg_static:.3f}")
    windows = np.array_split(bench.train_idx, 4)
    for w, idx in enumerate(windows, 1):
        serve_window(bench, router, idx, observe)
        report = controller.step()
        print(f"  window {w}: {report.n_events} events in store "
              f"({report.n_queries} unique queries), "
              f"{'SWAP' if report.swapped else 'no swap'} -> table v{report.table_version}"
              f" | {report.reason}")
        print(f"            heldout NDCG@5 = {heldout_ndcg(bench, router):.3f}")

    v_good = db.table_version
    ndcg_good = heldout_ndcg(bench, router)
    assert v_good > 0, "expected at least one accepted swap in act 1"
    assert ndcg_good > ndcg_static, (
        f"accepted swaps did not improve heldout NDCG@5 "
        f"({ndcg_static:.3f} -> {ndcg_good:.3f})"
    )
    # serve labelled traffic on the final good table so the guard has a frozen
    # baseline window for it before anything replaces it
    serve_window(bench, router, bench.test_idx[:300], observe)

    print("\nact 2 — a corrupted table bypasses the gate; the drift detector "
          "flags it label-free, then the guard rolls it back")
    rng = np.random.default_rng(0)
    bad = db.embeddings.copy()
    rng.shuffle(bad, axis=0)  # tool vectors scrambled across tools
    bad += 3.0 * bad.std()  # and shifted off the query population
    db.swap_table(bad)
    print(f"  injected bad table: v{db.table_version} "
          f"(heldout NDCG@5 = {heldout_ndcg(bench, router):.3f})")
    for w, idx in enumerate(np.array_split(bench.test_idx, 3), 1):
        serve_window(bench, router, idx, observe)
        report = controller.step()
        g = report.guard
        print(f"  shadow window {w}: guard={g.action} "
              f"(ndcg={g.ndcg if g.ndcg is None else round(g.ndcg, 3)}, "
              f"baseline={g.baseline if g.baseline is None else round(g.baseline, 3)}, "
              f"n={g.n_samples}) -> table v{db.table_version}")
        if g.action == "rolled_back":
            break

    assert guard.rollbacks, "guard failed to roll back the corrupted table"
    restored = heldout_ndcg(bench, router)
    print(f"  restored table v{db.table_version}: heldout NDCG@5 = {restored:.3f} "
          f"(good table was {ndcg_good:.3f})")
    assert abs(restored - ndcg_good) < 1e-6, "rollback did not restore the good table"
    print("\nloop closed: outcomes -> refine -> validate -> swap -> monitor -> rollback")
    print_timeline(bus, HealthMonitor(
        routers=[router], controllers=[controller],
        indexes=[router.index], stores=[store], bus=bus,
    ), quality=quality)
    rollback_ev = bus.last("rollback")
    assert rollback_ev is not None, "rollback never reached the bus"
    drift_ev = bus.last("quality_drift")
    assert drift_ev is not None, "drift detector never flagged the bad table"
    assert drift_ev.seq < rollback_ev.seq, (
        "drift should fire label-free, before the guard's labelled rollback"
    )


# --------------------------------------------------------------- §7.3 (PR 4)
def run_stages_demo():
    import jax.numpy as jnp

    from repro.learn import (
        ArtifactRegistry,
        LearnConfig,
        LearningController,
        StageGuard,
        StageGuardConfig,
    )

    # 600 tools puts the adapter in-policy once logs exceed 10K (§7.3), and
    # keeps the re-ranker out-of-policy at every density (|T| > 500)
    bench = make_metatool_like(n_tools=600, n_queries=4000)
    bus = EventBus()
    enc, db, store, router = build_serving_plane(bench, bus=bus)
    stage_guard = StageGuard(router, StageGuardConfig(k=5, min_samples=64),
                             bus=bus)
    registry = ArtifactRegistry()
    learner = LearningController(
        db, store, router, enc.encode,
        registry=registry, guard=stage_guard,
        config=LearnConfig(min_new_events=1000),
        bus=bus,
    )

    def observe(res, relevant):
        stage_guard.observe(res.stage_version, res.tools, relevant)

    def show(report):
        for stage, d in sorted(report.decisions.items()):
            print(f"    {stage:8s}: {d.action:14s} {d.reason}")
        print(f"    live stages: {sorted(report.active) or '(none)'} "
              f"(stage v{report.stage_version}, density "
              f"{report.density:.1f} ev/tool)")

    print(f"act 1 — sparse window: the density plan suppresses both learned "
          f"stages ({bench.n_tools} tools)")
    sparse = bench.train_idx[:600]  # ~3K events: density ~5, logs < 10K
    serve_window(bench, router, sparse)
    report = learner.step()
    show(report)
    assert report.decisions["adapter"].action == "suppressed"
    assert report.decisions["rerank"].action == "suppressed"
    assert report.active == frozenset(), "nothing may deploy from a sparse window"

    print("\nact 2 — dense window: the adapter clears the plan AND the "
          "held-out gate; the re-ranker stays suppressed")
    ndcg_sparse = heldout_ndcg(bench, router)
    print(f"  before promotion: heldout NDCG@5 = {ndcg_sparse:.3f}")
    serve_window(bench, router, bench.train_idx[600:])  # > 10K total events
    report = learner.step()
    show(report)
    d = report.decisions["adapter"]
    assert d.action == "promoted", f"expected adapter promotion, got {d}"
    assert d.ndcg_candidate > d.ndcg_current, "gate accepted a non-improvement"
    assert report.decisions["rerank"].action == "suppressed", (
        "the re-ranker must never deploy while out of policy (§7.3)"
    )
    assert report.active == frozenset({"adapter"})
    art = registry.latest("adapter")
    print(f"  artifact adapter/v{art.version}: trained on table "
          f"v{art.table_version}, window {art.fingerprint}")
    ndcg_dense = heldout_ndcg(bench, router)
    print(f"  after promotion:  heldout NDCG@5 = {ndcg_dense:.3f}")
    assert ndcg_dense > ndcg_sparse, (
        f"promoted adapter did not lift heldout NDCG@5 "
        f"({ndcg_sparse:.3f} -> {ndcg_dense:.3f})"
    )
    # labelled traffic on the promoted stage set gives the guard a rolling
    # window to freeze as the NEXT version's baseline
    serve_window(bench, router, bench.test_idx[:300], observe)

    print("\nact 3 — a corrupted adapter bypasses the gate; the StageGuard "
          "demotes it")
    _, good = router.stage_set()
    rng = np.random.default_rng(0)
    bad_params = {
        k: jnp.asarray(rng.normal(scale=0.5, size=v.shape), jnp.float32)
        for k, v in good.adapter_params.items()
    }
    router.set_stages(dataclasses.replace(good, adapter_params=bad_params))
    print(f"  injected corrupted adapter: stage v{router.stage_version} "
          f"(heldout NDCG@5 = {heldout_ndcg(bench, router):.3f})")
    for w, idx in enumerate(np.array_split(bench.test_idx, 3), 1):
        serve_window(bench, router, idx, observe)
        report = learner.step()
        g = report.guard
        print(f"  shadow window {w}: guard={g.action} "
              f"(ndcg={g.ndcg if g.ndcg is None else round(g.ndcg, 3)}, "
              f"baseline={g.baseline if g.baseline is None else round(g.baseline, 3)}, "
              f"n={g.n_samples}) -> stage v{router.stage_version}")
        if g.action == "demoted":
            break
    assert stage_guard.demotions, "guard failed to demote the corrupted stage set"
    _, live = router.stage_set()
    assert live.adapter_artifact == art.version, (
        "demotion did not restore the gated adapter artifact"
    )
    restored = heldout_ndcg(bench, router)
    print(f"  restored stage v{router.stage_version}: heldout NDCG@5 = "
          f"{restored:.3f} (good stage set was {ndcg_dense:.3f})")
    assert abs(restored - ndcg_dense) < 1e-6, "demotion did not restore serving"
    print("\nloop closed: outcomes -> density plan -> train -> gate -> "
          "promote -> monitor -> demote")
    print_timeline(bus, HealthMonitor(
        routers=[router], controllers=[learner],
        indexes=[router.index], stores=[store], bus=bus,
    ))
    for kind in ("promotion", "stage_swap", "demotion", "cooldown"):
        assert bus.last(kind) is not None, f"{kind} never reached the bus"


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--stages", action="store_true",
                    help="run the PR 4 learning-plane demo (density-gated "
                         "promotion of adapter/re-ranker) instead of the "
                         "PR 2 refinement-loop demo")
    args = ap.parse_args()
    run_stages_demo() if args.stages else run_refine_demo()
