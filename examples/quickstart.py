"""Quickstart: OATS-S1 in ~40 lines (paper §4.1, Alg. 1).

  PYTHONPATH=src python examples/quickstart.py

Builds the MetaTool-scale synthetic benchmark, runs static-embedding
retrieval, applies outcome-guided refinement offline, and shows the NDCG@5
jump at identical serving cost.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.evaluate import BenchmarkEvaluator
from repro.data.benchmarks import make_metatool_like

bench = make_metatool_like(n_tools=199, n_queries=2000)
ev = BenchmarkEvaluator(bench)

se = ev.rankings_for("se")
s1 = ev.rankings_for("oats-s1")

print(f"benchmark: {bench.name} ({bench.n_tools} tools, {bench.n_queries} queries)")
print(f"static embedding  NDCG@5 = {se.metrics['ndcg@5']:.3f}  R@1 = {se.metrics['recall@1']:.3f}")
print(f"OATS-S1 refined   NDCG@5 = {s1.metrics['ndcg@5']:.3f}  R@1 = {s1.metrics['recall@1']:.3f}")
gate = s1.pipeline.refine_result
print(f"validation gate: accepted={bool(gate.accepted)} "
      f"(val recall {float(gate.recall_before):.3f} -> {float(gate.recall_after):.3f})")
print("serving path unchanged: embed query -> dot products -> top-K; "
      "only the stored tool vectors differ (paper §4.1).")
