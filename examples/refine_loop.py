"""The production deployment loop (paper §7.2): serve -> log outcomes ->
cron refinement -> validation gate -> atomic table swap -> serve better.

  PYTHONPATH=src python examples/refine_loop.py

Runs three refinement cycles through the actual router object, printing
held-out Recall@5 after each swap. Mirrors the cron-job architecture: the
serving path never changes; only the ToolsDatabase table is swapped.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.refine import RefineConfig, refine_with_gate
from repro.data.benchmarks import make_metatool_like
from repro.embedding.bag_encoder import BagEncoder
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase

bench = make_metatool_like(n_tools=199, n_queries=2000)
enc = BagEncoder(bench.vocab)
db = ToolsDatabase(
    [ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
     for i in range(bench.n_tools)],
    enc.encode(bench.desc_tokens),
)
router = SemanticRouter(db, embed_fn=lambda t: enc.encode_one(t), k=5)
rel = bench.relevance_matrix()
qe = enc.encode(bench.query_tokens)


def heldout_recall():
    hits = 0
    for qi in bench.test_idx[:300]:
        res = router.route(bench.query_tokens[qi])
        hits += int(bench.relevant[qi][0] in res.tools)
    return hits / 300


print(f"cycle 0 (static table): heldout R@5 = {heldout_recall():.3f}")

chunks = np.array_split(bench.train_idx, 3)
seen = []
for cycle, chunk in enumerate(chunks, 1):
    # serve this window's traffic, logging outcomes (the feedback arrows of Fig. 2)
    for qi in chunk:
        res = router.route(bench.query_tokens[qi])
        for t in res.tools:
            router.record_outcome(bench.query_tokens[qi], t, int(t in bench.relevant[qi]))
    events = router.drain_outcomes()
    seen.extend(chunk)
    idx = np.array(seen)
    n_val = max(len(idx) // 7, 1)
    tr, va = idx[n_val:], idx[:n_val]
    # offline cron job: Alg. 1 + gate, then atomic swap
    res = refine_with_gate(
        jnp.asarray(db.embeddings),
        jnp.asarray(qe[tr]), jnp.asarray(rel[tr]),
        jnp.asarray(qe[va]), jnp.asarray(rel[va]),
        RefineConfig(),
    )
    if bool(res.accepted):
        db.swap_table(np.asarray(res.embeddings))
    print(f"cycle {cycle}: {len(events)} outcome events, gate="
          f"{'ACCEPT' if bool(res.accepted) else 'REJECT'}, table v{db.table_version}, "
          f"heldout R@5 = {heldout_recall():.3f}")
