"""Serve a small backend with batched requests through the OATS gateway.

  PYTHONPATH=src python examples/serve_gateway.py [--backend {dense,ivf,pallas}]
      [--num-tools N]

Thin wrapper over the production launcher (launch/serve.py): synthetic tool
DB -> OATS-S1 refinement -> table swap -> route batched requests -> backend
prefill+decode -> outcome logging.

The flag pair demos the PR 3 index layer end to end, e.g.

  python examples/serve_gateway.py --backend ivf --num-tools 25000

tiles + perturbs the refined 199-tool table to 25k entries
(`scale_tool_corpus`) and serves it through the IVF coarse-quantized index
instead of brute force — same gateway, same outcome loop, registry scale.
"""
import argparse

from repro.launch.serve import main

ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
ap.add_argument("--backend", default="dense", choices=("dense", "ivf", "pallas"),
                help="index scorer behind route_batch (repro.index)")
ap.add_argument("--num-tools", type=int, default=0,
                help="scale the tool table to this size (0 = native 199)")
args = ap.parse_args()

main([
    "--arch", "hymba-1.5b", "--smoke",
    "--stage", "oats-s1",
    "--requests", "16",
    "--route-batch", "8",
    "--max-new-tokens", "8",
    "--n-tools", "199",
    "--n-queries", "1500",
    "--backend", args.backend,
    "--num-tools", str(args.num_tools),
])
