"""Serve a small backend with batched requests through the OATS gateway.

  PYTHONPATH=src python examples/serve_gateway.py

Thin wrapper over the production launcher (launch/serve.py): synthetic tool
DB -> OATS-S1 refinement -> table swap -> route batched requests -> backend
prefill+decode -> outcome logging.
"""
from repro.launch.serve import main

main([
    "--arch", "hymba-1.5b", "--smoke",
    "--stage", "oats-s1",
    "--requests", "16",
    "--route-batch", "8",
    "--max-new-tokens", "8",
    "--n-tools", "199",
    "--n-queries", "1500",
])
