"""Serve a small backend with batched requests through the OATS gateway.

  PYTHONPATH=src python examples/serve_gateway.py [--backend {dense,ivf,pallas}]
      [--num-tools N] [--metrics-port PORT] [--trace-export PATH]

Thin wrapper over the production launcher (launch/serve.py): synthetic tool
DB -> OATS-S1 refinement -> table swap -> route batched requests -> backend
prefill+decode -> outcome logging.

The flag pair demos the PR 3 index layer end to end, e.g.

  python examples/serve_gateway.py --backend ivf --num-tools 25000

tiles + perturbs the refined 199-tool table to 25k entries
(`scale_tool_corpus`) and serves it through the IVF coarse-quantized index
instead of brute force — same gateway, same outcome loop, registry scale.

The telemetry plane (PR 6) rides along:

  python examples/serve_gateway.py --metrics-port 9100

serves `http://127.0.0.1:9100/metrics` (Prometheus text: per-phase
route_phase_ms histograms, index served/rebuild counters),
`/health` (JSON tri-state across all planes; 503 when a daemon loop is
failing), and `/events` (the lifecycle bus: swaps, rollbacks, rebuilds).
`--trace-export traces.jsonl` writes the sampled route traces on exit —
render them with `repro-obs traces.jsonl`.
"""
import argparse

from repro.launch.serve import main

ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
ap.add_argument("--backend", default="dense", choices=("dense", "ivf", "pallas"),
                help="index scorer behind route_batch (repro.index)")
ap.add_argument("--num-tools", type=int, default=0,
                help="scale the tool table to this size (0 = native 199)")
ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                help="serve /metrics + /health + /events on 127.0.0.1:PORT "
                     "(0 = ephemeral, printed at startup)")
ap.add_argument("--trace-export", metavar="PATH", default=None,
                help="write sampled route traces as JSONL on exit "
                     "(render with `repro-obs PATH`)")
args = ap.parse_args()

argv = [
    "--arch", "hymba-1.5b", "--smoke",
    "--stage", "oats-s1",
    "--requests", "16",
    "--route-batch", "8",
    "--max-new-tokens", "8",
    "--n-tools", "199",
    "--n-queries", "1500",
    "--backend", args.backend,
    "--num-tools", str(args.num_tools),
]
if args.metrics_port is not None:
    argv += ["--metrics-port", str(args.metrics_port)]
if args.trace_export:
    # the demo routes only a couple of batches — sample every one so the
    # exported JSONL has something for `repro-obs` to render (production
    # keeps launch/serve.py's 1-in-8 default)
    argv += ["--trace-export", args.trace_export, "--trace-every", "1"]

main(argv)
