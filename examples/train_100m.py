"""End-to-end driver: train a ~100M-parameter backend for a few hundred steps.

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses the qwen2.5 family scaled to ~100M params (8 layers, d_model=512) on the
synthetic LM pipeline, with AdamW + warmup-cosine + grad clipping +
checkpointing — the full training substrate, CPU-sized.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.lm_data import LMDataConfig, synthetic_lm_batches
from repro.training.train_step import TrainConfig
from repro.training.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch-size", type=int, default=8)
ap.add_argument("--seq-len", type=int, default=256)
args = ap.parse_args()

base = get_config("qwen2.5-3b")
cfg = dataclasses.replace(
    base,
    name="qwen2.5-100m",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=2,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    dtype="float32",
)
print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

trainer = Trainer(
    cfg,
    TrainerConfig(
        steps=args.steps,
        log_every=20,
        ckpt_every=max(args.steps // 2, 1),
        ckpt_dir="checkpoints/train_100m",
        train=TrainConfig(learning_rate=3e-4, warmup_steps=30, total_steps=args.steps),
    ),
)
data = synthetic_lm_batches(
    cfg, LMDataConfig(batch_size=args.batch_size, seq_len=args.seq_len, seed=0)
)
history = trainer.fit(data)
first, last = history[0]["loss"], history[-1]["loss"]
print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
      f"({100*(first-last)/first:.1f}% drop); checkpoint at {trainer.tcfg.ckpt_dir}")
