"""Appendix-A analogue: trace one opaque-description tool through Alg. 1.

  PYTHONPATH=src python examples/walkthrough_buildbetter.py

Finds the most opaque tool in the synthetic MetaTool benchmark (the
`buildbetter` failure mode: a brand-heavy description far from the tool's
function), shows the before/after candidate ranking for one of its test
queries, and the similarity delta for the refined embedding — the geometry of
paper Fig. 3.
"""
import numpy as np

from repro.core.evaluate import BenchmarkEvaluator
from repro.data.benchmarks import make_metatool_like

bench = make_metatool_like(n_tools=199, n_queries=2000)
ev = BenchmarkEvaluator(bench)
s1 = ev.rankings_for("oats-s1")
refined = s1.pipeline.tool_table

# pick the most opaque tool with a test query that S1 actually corrects
# (SE ranks it >1, the refined table ranks it 1 — a real `buildbetter` case)
def _rank(table, qi, t):
    cands = bench.candidates[qi]
    sims = ev.query_emb[qi] @ table[cands].T
    return int(np.argsort(-sims).tolist().index(list(cands).index(t))) + 1

chosen = None
for t in np.argsort(-bench.tool_opacity):
    t = int(t)
    for j in bench.test_idx:
        if t in bench.relevant[j] and len(bench.relevant[j]) == 1:
            if _rank(ev.tool_emb, j, t) > 1 and _rank(refined, j, t) == 1:
                chosen, qi = t, j
                break
    if chosen is not None:
        break
assert chosen is not None

q = ev.query_emb[qi]
cands = bench.candidates[qi]
before = {int(c): float(q @ ev.tool_emb[c]) for c in cands}
after = {int(c): float(q @ refined[c]) for c in cands}

print(f"tool #{chosen}: opacity={bench.tool_opacity[chosen]:.2f} "
      f"(description is mostly brand/marketing tokens)")
print(f"test query #{qi} (ground truth = tool {chosen})\n")
print(f"{'tool':>6} {'before':>8} {'after':>8}  note")
for c in sorted(cands, key=lambda c: -before[c]):
    note = "<- ground truth" if c == chosen else ""
    print(f"{c:>6} {before[c]:>8.3f} {after[c]:>8.3f}  {note}")

rank_before = sorted(cands, key=lambda c: -before[c]).index(chosen) + 1
rank_after = sorted(cands, key=lambda c: -after[c]).index(chosen) + 1
print(f"\nrank: {rank_before} -> {rank_after}; "
      f"sim delta for the correct tool: {after[chosen] - before[chosen]:+.3f}")
print("The description text never changed — only the stored vector (Fig. 3).")
