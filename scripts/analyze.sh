#!/usr/bin/env bash
# Convenience wrapper for the invariant analyzer (all three legs).
#
#   bash scripts/analyze.sh            # human-readable lint + retrace + lockgraph
#   bash scripts/analyze.sh --json     # machine-readable lint output (for tooling)
#   bash scripts/analyze.sh --lint     # static lint only (fastest)
#
# Extra args after the mode flag are forwarded to the lint CLI, e.g.
#   bash scripts/analyze.sh --lint --rule cas-discipline -v
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mode="full"
case "${1:-}" in
  --json) mode="json"; shift ;;
  --lint) mode="lint"; shift ;;
esac

case "$mode" in
  json)
    exec python -m repro.analysis src --json "$@"
    ;;
  lint)
    exec python -m repro.analysis src "$@"
    ;;
  full)
    python -m repro.analysis src "$@"
    python -m repro.analysis.retrace --smoke
    python -m repro.analysis.lockgraph --smoke
    ;;
esac
