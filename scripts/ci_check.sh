#!/usr/bin/env bash
# Tier-1 CI gate: full test suite + serving-path and control-plane smoke
# benchmarks.
#
#   bash scripts/ci_check.sh [extra pytest args...]
#
# The smoke benches write BENCH_*_smoke.json (scaled-down batches/iters);
# the full recorded numbers live in BENCH_router.json / BENCH_control.json /
# BENCH_index.json via
#   PYTHONPATH=src python -m benchmarks.run            (all suites)
#   PYTHONPATH=src python -m benchmarks.<suite>_bench  (one suite)
# control_bench runs the whole outcome->refine->validate->swap loop (plus
# route_batch under concurrent swaps), so any gate/guard/controller exception
# — or a p99 past the 10 ms budget — fails CI here. index_bench smoke-runs
# the backend matrix at the 25k-tool scale and fails CI if the IVF p99/query
# exceeds the 10 ms budget (or its Recall@5 vs exact drops below 0.98).
# learn_bench runs the learning plane's density sweep + all-stages serving
# latency and fails CI on a route_batch p99 past the 10 ms budget with every
# learned stage active, or on a gated promotion that regresses held-out
# NDCG@5.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# invariant analyzer first: the three checks are seconds, the suite is
# minutes — fail fast on a broken invariant before paying for the tests.
# (1) repo-specific lint: fails on any finding not grandfathered in
#     analysis_baseline.json or suppressed with `# repro: noqa[rule-id]`
python -m repro.analysis src
# (2) runtime retrace detector: hot-path jits must compile once per
#     power-of-two bucket, never per distinct batch size
python -m repro.analysis.retrace --smoke
# (3) lock-order checker: no acquisition cycles, no JAX dispatch while a
#     plane lock is held, across a threaded serve/swap/churn scenario
python -m repro.analysis.lockgraph --smoke

python -m pytest -x -q "$@"

# router_bench also re-checks the retrace contract across its full sweep
# (exit 1 on violation)
python -m benchmarks.router_bench --smoke --out BENCH_router_smoke.json

python -m benchmarks.control_bench --smoke --out BENCH_control_smoke.json

python -m benchmarks.index_bench --smoke --out BENCH_index_smoke.json

python -m benchmarks.learn_bench --smoke --out BENCH_learn_smoke.json

# cache_bench gates the route cache on Zipfian near-duplicate traffic: any
# stale-version serve across control-plane churn (swap/rollback/stage
# promotion mid-stream), a hit-rate below the warm floor on the Zipf-1.1
# curve, or a churn-leg p99 past budget x the bare router's fails CI
# (the >=2x qps and >=0.98 agreement acceptance gates run in the full,
# non-smoke bench: BENCH_cache.json)
python -m benchmarks.cache_bench --smoke --out BENCH_cache_smoke.json

# obs_bench gates the telemetry plane: instrumented route_batch (including
# the SLO judgement layer: quality monitor, ticking TimeSeriesRing, SLO
# engine) must stay within 5% of bare qps, and the threaded lifecycle smoke
# (serve + swap + guard rollback + stage demotion) must land every
# lifecycle event on the bus with correct version stamps
python -m benchmarks.obs_bench --smoke --out BENCH_obs_smoke.json

# slo_bench gates the judgement layer end-to-end: injected latency past the
# 10 ms budget must publish slo_burn (with a resolvable p99 trace exemplar)
# and degrade /health, recovery must publish slo_recovered, and a bad table
# swap must raise the label-free quality_drift event BEFORE the labelled
# TableGuard rollback
python -m benchmarks.slo_bench --smoke --out BENCH_slo_smoke.json

# flightrec_bench gates the black-box layer: an injected SLO breach must
# produce exactly one debounced dump (follow-on triggers suppressed) with
# resolvable traces and live version stamps that `repro-obs replay`
# renders, an injected controller crash must produce exactly one crash
# dump, and an armed recorder must keep serving qps inside the 5% budget
python -m benchmarks.flightrec_bench --smoke --out BENCH_flightrec_smoke.json
