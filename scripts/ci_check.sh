#!/usr/bin/env bash
# Tier-1 CI gate: full test suite + the router serving-path smoke benchmark.
#
#   bash scripts/ci_check.sh [extra pytest args...]
#
# The smoke bench writes BENCH_router_smoke.json (scaled-down batches/iters);
# the full recorded numbers live in BENCH_router.json via
#   PYTHONPATH=src python -m benchmarks.router_bench
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

python -m benchmarks.router_bench --smoke --out BENCH_router_smoke.json
