#!/usr/bin/env bash
# Tier-1 CI gate: full test suite + serving-path and control-plane smoke
# benchmarks.
#
#   bash scripts/ci_check.sh [extra pytest args...]
#
# The smoke benches write BENCH_*_smoke.json (scaled-down batches/iters);
# the full recorded numbers live in BENCH_router.json / BENCH_control.json via
#   PYTHONPATH=src python -m benchmarks.router_bench
#   PYTHONPATH=src python -m benchmarks.control_bench
# control_bench runs the whole outcome->refine->validate->swap loop (plus
# route_batch under concurrent swaps), so any gate/guard/controller exception
# — or a p99 past the 10 ms budget — fails CI here.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

python -m benchmarks.router_bench --smoke --out BENCH_router_smoke.json

python -m benchmarks.control_bench --smoke --out BENCH_control_smoke.json
