"""Generate the §Roofline markdown table from experiments/dryrun/*.json."""
import glob, json, os, sys

rows = []
for path in sorted(glob.glob("experiments/dryrun/*.json")):
    base = os.path.basename(path)
    if base.count("__") != 2:  # skip tagged (perf-iteration) records
        continue
    r = json.load(open(path))
    if r["mesh"] != "single":
        continue
    rows.append(r)

order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
rows.sort(key=lambda r: (r["arch"], order[r["shape"]]))
from repro.configs import get_config
from repro.launch.specs import SHAPES, variant_for_shape

print("| arch | shape | compute (s) | memory (s) | collective (s) | dominant | MODEL_FLOPS | useful ratio | args GB/dev |")
print("|---|---|---|---|---|---|---|---|---|")
for r in rows:
    shape = SHAPES[r["shape"]]
    cfg = variant_for_shape(get_config(r["arch"]), shape)
    factor = 6 if shape.kind == "train" else 2
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = factor * cfg.active_param_count() * d_tokens
    ratio = mf / max(r["per_device"]["flops"] * r["chips"], 1.0)
    t = r["roofline"]
    var = "" if r["variant"] == r["arch"] else " (+swa)"
    print(f"| {r['arch']}{var} | {r['shape']} | {t['compute_s']:.4g} | {t['memory_s']:.4g} "
          f"| {t['collective_s']:.4g} | **{t['dominant']}** | {mf:.2e} | {ratio:.3f} "
          f"| {(r['per_device']['argument_bytes'] or 0)/1e9:.2f} |")
