#!/usr/bin/env python
"""Thin launcher for the telemetry-plane report CLI (repro.obs.report).

  PYTHONPATH=src python scripts/obs_report.py trace.jsonl
  PYTHONPATH=src python scripts/obs_report.py --health http://127.0.0.1:9100

Identical to the installed `repro-obs` console entry point.
"""
import sys

from repro.obs.report import main

if __name__ == "__main__":
    sys.exit(main())
