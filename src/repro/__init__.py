"""repro: OATS (Outcome-Aware Tool Selection) — production semantic-router
framework in JAX with multi-pod backend model pools."""

__version__ = "0.1.0"
