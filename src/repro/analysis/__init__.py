"""Invariant analyzer: repo-specific lint, retrace detector, lock-order checker.

The router's latency story rests on invariants generic linters cannot see:
compare-and-swap-only version swaps, atomic snapshot reads, one portable
mesh layer, one bucketing function, no device work under hot-path locks.
This package enforces them in CI.

Three legs, all run by ``scripts/ci_check.sh``:

* ``python -m repro.analysis [paths]`` — AST lint (this file's catalog below);
* ``python -m repro.analysis.retrace`` — runtime jit-retrace detector that
  builds a small router, sweeps batch sizes, and fails if hot-path entry
  points compile beyond the expected power-of-two bucket set;
* ``python -m repro.analysis.lockgraph`` — instrumented-lock run of a
  threaded swap/refine/stage-churn scenario, failing on lock-order cycles
  or JAX dispatch performed while holding a lock.

Rule catalog
============

mesh-api
    *What*: raw JAX mesh-context APIs (``jax.set_mesh``,
    ``jax.sharding.use_mesh``/``get_abstract_mesh``, ``jax.make_mesh``,
    ``shard_map`` imports, ``jax._src.mesh``, ``thread_resources``) used
    outside ``common/meshctx.py``.
    *Why*: these APIs drift across JAX releases; meshctx exists to pin the
    drift to one file so version bumps are a one-file diff.
    *Fix*: call ``repro.common.meshctx`` (``use_mesh``, ``make_mesh``,
    ``current_mesh``, ``axis_sizes_dict``, ``shard_map``).

cas-discipline
    *What*: ``swap_table``/``rollback``/``rollback_stages`` without
    ``expect_current=``, ``set_stages`` without ``expect_version=``.
    *Why*: a bare swap silently clobbers a concurrent deployment — the
    lost-update race the versioned stores exist to refuse (ConflictError).
    *Fix*: pass the expectation from the snapshot the change was derived
    from. Receivers named ``*registry*`` are exempt (ArtifactRegistry's
    rollback is bounded-history trimming, not a serving CAS).

snapshot-discipline
    *What*: touching another object's ``_table``/``_history``/``_stages``/
    ``_stage_history``/``_swap_listeners`` outside the owning router
    modules.
    *Why*: bypassing ``snapshot()``/``stage_set()`` can observe a
    half-completed swap and mis-attribute scores to the wrong version.
    *Fix*: read through the atomic accessors.

jit-in-function
    *What*: ``jax.jit`` applied inside a function body (call or decorator
    on a nested def).
    *Why*: each instance gets a fresh trace cache — compile cost paid per
    object instead of once per process; a multi-ms stall if it reaches the
    hot path.
    *Fix*: hoist to module scope, or baseline with justification when the
    closure is deliberate (offline training, per-process singletons).

jit-static-scalar
    *What*: a jitted function with an ``int``/``bool``/``str``-annotated
    parameter not in ``static_argnames``.
    *Why*: shape-controlling scalars become traced values (tracer errors
    or silent wrong shapes); hashable config belongs in the compile key.
    *Fix*: add to ``static_argnames``.

pow2-bucket
    *What*: hand-rolled ``1 << n.bit_length()`` bucket math outside
    ``common/bucketing.py``.
    *Why*: every jitted entry point must agree on one bucketing function,
    or the retrace detector's expected-bucket set is per-module luck.
    *Fix*: ``repro.common.bucketing.pow2_bucket`` / ``expected_buckets``.

lock-dispatch
    *What*: ``jnp.*``/``jax.*``/known-jitted/``device_put`` calls lexically
    inside ``with <lock>:`` in ``router/``, ``control/``, ``learn/``,
    ``index/``.
    *Why*: device work under a hot-path lock stalls every contending
    thread; a compile under a lock is a multi-ms p99 breach for all of
    them.
    *Fix*: compute outside the critical section, hold the lock only to
    publish (see ``ToolIndexManager._build``).

cache-version-stamp
    *What*: ``lookup_batch``/``insert_batch`` on a ``*cache*`` receiver
    without explicit ``table_version=`` AND ``stage_version=`` keywords;
    plus the lock-dispatch scan applied to the ``cache/`` package.
    *Why*: the route cache's exact-invalidation guarantee holds only if
    every entry is stamped with the snapshot its scores came from — an
    unstamped site can serve a decision from a dead table after a swap.
    The cache lock is taken per routed batch, so device work under it is
    the same p99 hazard lock-dispatch polices elsewhere.
    *Fix*: thread the versions from the same snapshot that produced the
    scores (the topk's returned version); keep cache critical sections
    numpy-only.

thread-discipline
    *What*: a ``daemon=True`` thread whose locally-defined loop lacks an
    ``except Exception`` handler, or has one that does not record the
    failure on an ``*error*``/``*exception*`` attribute.
    *Why*: a dead or flapping control/learning plane that no guard or
    health check can detect.
    *Fix*: record ``self.last_loop_error = exc`` (clear on success) where
    health checks look.

obs-discipline
    *What*: direct ``time.time()``/``time.perf_counter()``/
    ``time.monotonic()`` or ``print()`` calls in ``router/``, ``index/``,
    ``control/``, and ``learn/``.
    *Why*: recorded durations must share one monotonic source
    (wall-clock NTP slew corrupts latency histograms and controller
    cooldown/cadence arithmetic), and a serving process's stdout is not
    an operator surface — the telemetry plane (metrics/events/health) is.
    *Fix*: ``repro.obs.clock`` (``perf``/``monotonic``/``wall``/
    ``duration_ms``); publish operator-facing state to the
    ``MetricsRegistry``/``EventBus``.

kernel-contract (project rule)
    *What*: a ``kernels/<name>/kernel.py`` without a ``ref.py`` oracle or
    a parity test referencing ``kernels.<name>``; top-K kernels hardcoding
    a ``<= -1e29`` padding sentinel instead of importing ``NEG_INF``.
    *Why*: the gateway filters selected tools by ``score > NEG_INF / 2``;
    a drifted sentinel silently surfaces padding as results, and a kernel
    without an oracle cannot be trusted after an interpreter/backend bump.
    *Fix*: add ``ref.py`` + a parity test; import
    ``repro.core.retrieval.NEG_INF``.

Suppression and baseline
========================

``# repro: noqa[rule-id]`` on the flagged line suppresses that rule there
(``# repro: noqa`` suppresses all). ``analysis_baseline.json`` (repo root)
grandfathers deliberate exceptions, content-matched so line drift does not
invalidate entries; stale entries are warned about. Regenerate with
``python -m repro.analysis --write-baseline`` (existing justifications are
kept; new entries get ``TODO: justify``).

Adding a rule: subclass ``repro.analysis.rules.Rule``, decorate with
``@register``, add a catalog entry above, and give it true-positive /
true-negative fixtures in ``tests/test_analysis.py``.
"""
from repro.analysis.engine import run, scan
from repro.analysis.findings import Baseline, Finding
from repro.analysis.rules import REGISTRY, ModuleInfo, Rule, register

__all__ = [
    "Baseline",
    "Finding",
    "ModuleInfo",
    "REGISTRY",
    "Rule",
    "register",
    "run",
    "scan",
]
