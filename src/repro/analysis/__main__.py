"""CLI for the invariant analyzer: `python -m repro.analysis [paths...]`.

Exit status: 0 when every finding is suppressed or baselined, 1 otherwise.
See `repro.analysis` (package docstring) for the rule catalog.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.engine import exit_code, format_json, format_text, run
from repro.analysis.findings import Baseline, merge_baseline_entries
from repro.analysis.rules import REGISTRY

DEFAULT_BASELINE = "analysis_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific invariant lint for the semantic router.",
    )
    ap.add_argument("paths", nargs="*", default=None, help="files/dirs (default: src)")
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    ap.add_argument(
        "--baseline",
        default=None,
        help=f"baseline JSON (default: {DEFAULT_BASELINE} when present)",
    )
    ap.add_argument(
        "--no-baseline", action="store_true", help="ignore any baseline file"
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="write current findings to the baseline file (keeps existing "
        "justifications) instead of failing",
    )
    ap.add_argument(
        "--tests-dir", default="tests", help="tests root for kernel-contract"
    )
    ap.add_argument(
        "--rule",
        action="append",
        dest="rules",
        default=None,
        metavar="RULE-ID",
        help="run only this rule (repeatable)",
    )
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument(
        "-v", "--verbose", action="store_true", help="also print baselined/suppressed"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in sorted(REGISTRY.items()):
            kind = "project" if rule.project else "module"
            print(f"{rid} ({kind}): {rule.description}")
        return 0

    if args.rules:
        unknown = [r for r in args.rules if r not in REGISTRY]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    paths = args.paths or ["src"]
    baseline_path = args.baseline or DEFAULT_BASELINE
    baseline = None
    if not args.no_baseline and Path(baseline_path).exists():
        baseline = Baseline.load(baseline_path)

    result = run(
        paths,
        tests_dir=args.tests_dir or None,
        baseline=baseline,
        rules=args.rules,
    )

    if args.write_baseline:
        old = baseline or Baseline()
        by_rel = {m.rel: m for m in result["modules"]}
        entries = []
        seen = set()
        for f in result["active"] + result["baselined"]:
            mod = by_rel.get(f.file)
            text = mod.line(f.line) if mod else ""
            e = Baseline.entry_for(f, text)
            key = (e["rule"], e["file"], e["content"])
            if key not in seen:
                seen.add(key)
                entries.append(e)
        Baseline(merge_baseline_entries(old, entries)).save(baseline_path)
        print(f"wrote {len(entries)} entries to {baseline_path}")
        return 0

    print(format_json(result) if args.json else format_text(result, args.verbose))
    return exit_code(result)


if __name__ == "__main__":
    raise SystemExit(main())
