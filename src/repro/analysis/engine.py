"""Analyzer engine: walk files, run rules, apply suppressions and baseline.

The engine is deliberately dumb plumbing: rules (repro.analysis.rules) hold
all of the repo knowledge, findings.py holds the suppression/baseline
mechanics, and this module just wires them together and formats output.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from repro.analysis.findings import Baseline, Finding, noqa_rules_by_line
from repro.analysis.rules import REGISTRY, ModuleInfo

__all__ = ["collect_files", "scan", "run", "format_text", "format_json"]

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "build", "dist"}


def collect_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            out.extend(
                f
                for f in sorted(p.rglob("*.py"))
                if not any(part in _SKIP_DIRS for part in f.parts)
            )
    return out


def _rel(path: Path) -> str:
    """Stable posix key: path relative to cwd when possible, else as given."""
    try:
        return path.resolve().relative_to(Path.cwd()).as_posix()
    except ValueError:
        return path.as_posix()


def scan(
    paths: Sequence[str],
    tests_dir: Optional[str] = "tests",
    rules: Optional[Sequence[str]] = None,
) -> Tuple[List[Finding], List[ModuleInfo], List[str]]:
    """Parse files and run every (selected) rule.

    Returns (raw findings before suppression/baseline, parsed modules,
    parse-error strings). Unparseable files are reported, not fatal: the
    analyzer must degrade gracefully on scratch files in the tree.
    """
    modules: List[ModuleInfo] = []
    errors: List[str] = []
    for f in collect_files(paths):
        try:
            modules.append(ModuleInfo(f, _rel(f), f.read_text()))
        except (SyntaxError, UnicodeDecodeError) as exc:
            errors.append(f"{f}: parse error: {exc}")

    active = [
        r for rid, r in sorted(REGISTRY.items()) if rules is None or rid in rules
    ]
    findings: List[Finding] = []
    for rule in active:
        if rule.project:
            continue
        for m in modules:
            findings.extend(rule.check(m))
    td = Path(tests_dir) if tests_dir else None
    for rule in active:
        if rule.project:
            findings.extend(rule.check_project(modules, td))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings, modules, errors


def run(
    paths: Sequence[str],
    tests_dir: Optional[str] = "tests",
    baseline: Optional[Baseline] = None,
    rules: Optional[Sequence[str]] = None,
):
    """Scan + suppression + baseline. Returns a result dict:

    active: findings that should fail CI
    suppressed: findings silenced by `# repro: noqa[...]`
    baselined: findings matched by the baseline file
    stale_baseline: baseline entries that matched nothing (warnings)
    errors: parse failures
    """
    raw, modules, errors = scan(paths, tests_dir=tests_dir, rules=rules)
    by_rel = {m.rel: m for m in modules}
    active: List[Finding] = []
    suppressed: List[Finding] = []
    baselined: List[Finding] = []
    noqa_cache = {}
    for f in raw:
        mod = by_rel.get(f.file)
        line_text = mod.line(f.line) if mod else ""
        if mod is not None:
            if f.file not in noqa_cache:
                noqa_cache[f.file] = noqa_rules_by_line(mod.lines)
            rules_at = noqa_cache[f.file].get(f.line, ...)
            if rules_at is ... :
                pass
            elif rules_at is None or f.rule in rules_at:
                suppressed.append(f)
                continue
        if baseline is not None and baseline.matches(f, line_text):
            baselined.append(f)
            continue
        active.append(f)
    return {
        "active": active,
        "suppressed": suppressed,
        "baselined": baselined,
        "stale_baseline": baseline.stale_entries() if baseline else [],
        "errors": errors,
        "modules": modules,
    }


def format_text(result: dict, verbose: bool = False) -> str:
    lines: List[str] = []
    for f in result["active"]:
        lines.append(f"{f.location()}: [{f.rule}] {f.message}")
        if f.hint:
            lines.append(f"    fix: {f.hint}")
    for e in result["errors"]:
        lines.append(f"error: {e}")
    for e in result["stale_baseline"]:
        lines.append(
            f"warning: stale baseline entry [{e['rule']}] {e['file']}: "
            f"{e['content']!r} matches nothing — delete it"
        )
    if verbose:
        for f in result["baselined"]:
            lines.append(f"baselined: {f.location()}: [{f.rule}] {f.message}")
        for f in result["suppressed"]:
            lines.append(f"suppressed: {f.location()}: [{f.rule}] {f.message}")
    n_act = len(result["active"])
    lines.append(
        f"{n_act} finding(s), {len(result['baselined'])} baselined, "
        f"{len(result['suppressed'])} suppressed, "
        f"{len(result['errors'])} parse error(s)"
    )
    return "\n".join(lines)


def format_json(result: dict) -> str:
    return json.dumps(
        {
            "findings": [f.to_dict() for f in result["active"]],
            "baselined": [f.to_dict() for f in result["baselined"]],
            "suppressed": [f.to_dict() for f in result["suppressed"]],
            "stale_baseline": result["stale_baseline"],
            "errors": result["errors"],
        },
        indent=2,
    )


def exit_code(result: dict) -> int:
    return 1 if (result["active"] or result["errors"]) else 0
