"""Finding/suppression/baseline plumbing for the invariant analyzer.

A `Finding` is one rule violation at one source location. Two mechanisms
make adoption incremental without weakening the CI gate:

  * **inline suppression** — a ``# repro: noqa[rule-id]`` comment on the
    flagged line (or ``# repro: noqa`` to silence every rule on that line)
    suppresses the finding at the source. Use it for one-off sites where
    the exception is obvious in context.
  * **baseline file** — a checked-in JSON file grandfathering deliberate
    exceptions, each with a one-line justification. Entries match on
    (rule, file, stripped source line), NOT on line numbers, so unrelated
    edits above a grandfathered site do not invalidate the baseline.
    Stale entries (matching nothing) are reported as warnings so the
    baseline shrinks over time instead of fossilizing.

The CI contract: `python -m repro.analysis src/` exits non-zero on any
finding that is neither suppressed inline nor matched by the baseline.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Finding", "Baseline", "noqa_rules_by_line"]

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[(?P<rules>[A-Za-z0-9_,\- ]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it."""

    rule: str  # rule id, e.g. "cas-discipline"
    file: str  # posix path as scanned (stable across machines for a repo)
    line: int  # 1-based
    col: int  # 0-based
    message: str  # what is wrong at this site
    hint: str = ""  # how to fix it (rule-level fix recipe)

    def location(self) -> str:
        return f"{self.file}:{self.line}:{self.col}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def noqa_rules_by_line(source_lines: Sequence[str]) -> Dict[int, Optional[set]]:
    """{1-based line: set of suppressed rule ids, or None for 'all rules'}."""
    out: Dict[int, Optional[set]] = {}
    for i, text in enumerate(source_lines, start=1):
        m = _NOQA_RE.search(text)
        if not m:
            continue
        rules = m.group("rules")
        if rules is None:
            out[i] = None  # blanket: every rule suppressed on this line
        else:
            out[i] = {r.strip() for r in rules.split(",") if r.strip()}
    return out


class Baseline:
    """Checked-in grandfather list: (rule, file, line content) + justification.

    Content-matched, not line-number-matched: the flagged line's stripped
    text is the key, so the baseline survives edits elsewhere in the file
    but dies with the flagged code itself — exactly when it should be
    re-justified or deleted.
    """

    def __init__(self, entries: Optional[List[dict]] = None, path: str = ""):
        self.path = path
        self.entries: List[dict] = list(entries or [])
        self._matched = [False] * len(self.entries)

    @classmethod
    def load(cls, path) -> "Baseline":
        p = Path(path)
        data = json.loads(p.read_text())
        entries = data.get("entries", [])
        for e in entries:
            for key in ("rule", "file", "content"):
                if key not in e:
                    raise ValueError(
                        f"baseline entry missing {key!r} in {p}: {e}"
                    )
        return cls(entries, path=str(p))

    def save(self, path) -> None:
        Path(path).write_text(
            json.dumps({"entries": self.entries}, indent=2, sort_keys=False)
            + "\n"
        )

    def matches(self, finding: Finding, line_content: str) -> bool:
        """True (and marks the entry used) if a baseline entry covers this
        finding. Multiple identical sites may share one entry."""
        stripped = line_content.strip()
        hit = False
        for i, e in enumerate(self.entries):
            if (
                e["rule"] == finding.rule
                and e["file"] == finding.file
                and e["content"] == stripped
            ):
                self._matched[i] = True
                hit = True
        return hit

    def stale_entries(self) -> List[dict]:
        """Entries that matched no finding this run — candidates to delete."""
        return [e for e, m in zip(self.entries, self._matched) if not m]

    @staticmethod
    def entry_for(
        finding: Finding, line_content: str, justification: str = "TODO: justify"
    ) -> dict:
        return {
            "rule": finding.rule,
            "file": finding.file,
            "content": line_content.strip(),
            "justification": justification,
        }


def merge_baseline_entries(
    old: "Baseline", new_entries: List[dict]
) -> List[dict]:
    """Keep old justifications for entries that still exist; add the rest."""
    justified: Dict[Tuple[str, str, str], str] = {
        (e["rule"], e["file"], e["content"]): e.get("justification", "")
        for e in old.entries
    }
    out = []
    for e in new_entries:
        key = (e["rule"], e["file"], e["content"])
        if key in justified and justified[key]:
            e = dict(e, justification=justified[key])
        out.append(e)
    return out
