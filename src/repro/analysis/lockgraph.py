"""Lock-order checker: acquisition-graph recording + JAX-dispatch-under-lock.

The planes share a handful of locks (ToolsDatabase._lock, the router's
stage lock, the index manager's lock/condition, guard locks, the outcome
ring). Two hazards survive code review silently:

* **order cycles** — thread A takes L1 then L2 while thread B takes L2
  then L1: a deadlock that only fires under production interleavings;
* **dispatch under lock** — a jitted call or device upload inside a
  critical section: every contending thread eats the device latency (and
  a compile under a lock is a multi-ms p99 breach for all of them).

`LockGraph` records both at runtime. `patch_threading(graph)` monkeypatches
`threading.Lock` so every lock constructed inside the with-block is a
`TrackedLock` named by its allocation site — `threading.Condition` built on
a tracked lock keeps working because TrackedLock duck-types acquire/release
exactly as Condition requires. `watch_dispatch(graph)` wraps the hot-path
dispatch surfaces (`jnp.asarray`, `jax.device_put`, the jitted entry
points) to flag calls made while any tracked lock is held.

`python -m repro.analysis.lockgraph` runs a threaded smoke scenario
(serving + CAS table swaps + stage churn + guard checks under
instrumentation) and exits non-zero on a cycle or a dispatch-under-lock.
The static `lock-dispatch` lint rule covers the lexical cases; this
checker covers the dynamic ones (dispatch reached through calls).
"""
from __future__ import annotations

import argparse
import contextlib
import sys
import threading
import traceback
from typing import Dict, List, Optional, Tuple

__all__ = [
    "LockGraph",
    "TrackedLock",
    "patch_threading",
    "watch_dispatch",
    "main",
]

# captured at import: the graph's own mutex (and every TrackedLock's inner
# lock) must be real even when allocated inside a patch_threading window
_REAL_LOCK = threading.Lock


def _caller_site(skip_internal: bool = True) -> str:
    """'path/to/file.py:123' of the nearest frame outside this module and
    the threading machinery — the lock's allocation site, its name."""
    for frame in reversed(traceback.extract_stack()):
        fn = frame.filename
        if fn.endswith(("analysis/lockgraph.py", "threading.py")):
            continue
        return f"{fn.split('/src/')[-1].split('/lib/')[-1]}:{frame.lineno}"
    return "<unknown>"


class LockGraph:
    """Thread-safe record of lock acquisition order and dispatch-under-lock."""

    def __init__(self):
        self._mu = _REAL_LOCK()
        self._tls = threading.local()
        # (held_name, acquired_name) -> example thread name
        self.edges: Dict[Tuple[str, str], str] = {}
        # [{"fn": ..., "locks": [names], "thread": ...}]
        self.dispatch_events: List[dict] = []

    # ------------------------------------------------------------ recording
    def _stack(self) -> List["TrackedLock"]:
        if not hasattr(self._tls, "stack"):
            self._tls.stack = []
        return self._tls.stack

    def note_acquired(self, lock: "TrackedLock") -> None:
        stack = self._stack()
        if stack:
            with self._mu:
                for held in stack:
                    if held.name != lock.name:
                        self.edges.setdefault(
                            (held.name, lock.name), threading.current_thread().name
                        )
        stack.append(lock)

    def note_released(self, lock: "TrackedLock") -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                return

    def held_locks(self) -> List[str]:
        return [l.name for l in self._stack()]

    def note_dispatch(self, fn_name: str, held: List[str]) -> None:
        with self._mu:
            self.dispatch_events.append(
                {
                    "fn": fn_name,
                    "locks": list(held),
                    "thread": threading.current_thread().name,
                }
            )

    # ------------------------------------------------------------- analysis
    def cycles(self) -> List[List[str]]:
        """Distinct lock-order cycles (each a [n1, n2, ..., n1] name path)."""
        adj: Dict[str, List[str]] = {}
        for a, b in self.edges:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, [])
        out: List[List[str]] = []
        seen_cycles = set()
        color: Dict[str, int] = {}  # 0 unvisited / 1 on-path / 2 done

        def dfs(node: str, path: List[str]):
            color[node] = 1
            path.append(node)
            for nxt in adj[node]:
                c = color.get(nxt, 0)
                if c == 1:
                    cyc = path[path.index(nxt):] + [nxt]
                    key = frozenset(cyc)
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        out.append(cyc)
                elif c == 0:
                    dfs(nxt, path)
            path.pop()
            color[node] = 2

        for n in sorted(adj):
            if color.get(n, 0) == 0:
                dfs(n, [])
        return out

    def report(self) -> dict:
        return {
            "locks": sorted({n for e in self.edges for n in e})
            or sorted({l for ev in self.dispatch_events for l in ev["locks"]}),
            "edges": sorted(f"{a} -> {b}" for a, b in self.edges),
            "cycles": [" -> ".join(c) for c in self.cycles()],
            "dispatch_under_lock": self.dispatch_events,
        }


class TrackedLock:
    """Drop-in `threading.Lock` recording acquisition order into a LockGraph.

    Duck-typed, not subclassed (threading.Lock is a factory for an opaque
    type): exposes acquire/release/locked/__enter__/__exit__, which is the
    full surface `threading.Condition` relies on when handed a lock.
    """

    def __init__(self, graph: LockGraph, name: Optional[str] = None):
        self._graph = graph
        self.name = name or _caller_site()
        self._inner = _REAL_LOCK()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._graph.note_acquired(self)
        return ok

    def release(self) -> None:
        self._graph.note_released(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        self.acquire()
        return True

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def __repr__(self) -> str:
        return f"<TrackedLock {self.name}>"


@contextlib.contextmanager
def patch_threading(graph: LockGraph, site_filter: Optional[str] = None):
    """Within the block, `threading.Lock()` yields TrackedLocks.

    Patch only around the construction of the objects under test: stdlib or
    jax machinery allocating locks in the window would be tracked too
    (lazy backend init takes internal locks around dispatch, which would
    read as false dispatch-under-lock findings). `site_filter` narrows
    tracking to locks whose allocation site contains the substring (e.g.
    ``"repro/"``); other allocations get real locks.
    """
    orig = threading.Lock

    def make_tracked():
        if site_filter is not None and site_filter not in _caller_site():
            return orig()
        return TrackedLock(graph)

    threading.Lock = make_tracked
    try:
        yield graph
    finally:
        threading.Lock = orig


@contextlib.contextmanager
def watch_dispatch(graph: LockGraph, extra: Optional[List[Tuple[object, str]]] = None):
    """Within the block, record JAX dispatch performed with tracked locks held.

    Wraps module attributes looked up at call time (`jnp.asarray`,
    `jax.device_put`, the jitted entry points on their defining modules).
    Call sites holding direct references imported before the patch are not
    intercepted — the scenario surfaces dispatch through `jnp.asarray`,
    which every upload path goes through by module attribute.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import reranker, retrieval
    from repro.router import stages as stages_mod

    targets: List[Tuple[object, str]] = [
        (jnp, "asarray"),
        (jax, "device_put"),
        (retrieval, "topk_dense"),
        (reranker, "rerank_topk_scored"),
        (stages_mod, "_adapter_apply_j"),
    ]
    targets.extend(extra or [])
    saved = []
    for mod, attr in targets:
        orig = getattr(mod, attr)

        def wrapped(*a, __orig=orig, __name=attr, **k):
            held = graph.held_locks()
            if held:
                graph.note_dispatch(__name, held)
            return __orig(*a, **k)

        setattr(mod, attr, wrapped)
        saved.append((mod, attr, orig))
    try:
        yield graph
    finally:
        for mod, attr, orig in saved:
            setattr(mod, attr, orig)


# ----------------------------------------------------------------- CI leg


def run_scenario(
    n_tools: int = 48, dim: int = 16, seed: int = 0, iters: int = 12
) -> dict:
    """Threaded swap/serve/stage-churn/guard run under full instrumentation."""
    import numpy as np

    graph = LockGraph()
    with patch_threading(graph, site_filter="repro/"):
        # construction inside the patch window: every plane lock is tracked
        from repro.analysis.retrace import _build_router
        from repro.control.guard import GuardConfig, TableGuard
        from repro.router.tooldb import ConflictError

        router, db = _build_router(n_tools, dim, seed)
        guard = TableGuard(db, GuardConfig(min_samples=4, window=16))

    rng = np.random.default_rng(seed)
    base = db.embeddings.copy()
    errors: List[str] = []

    def serve():
        try:
            for i in range(iters):
                n = [1, 3, 8][i % 3]
                queries = [
                    rng.integers(0, 50, size=3).astype(np.int64) for _ in range(n)
                ]
                for r in router.route_batch(queries):
                    # labelled feedback keeps the guard judging real state
                    guard.observe(r.table_version, r.tools, r.tools[:1])
        except Exception as exc:  # pragma: no cover - surfaced via errors
            errors.append(f"serve: {exc!r}")

    def swap():
        try:
            for i in range(iters):
                version, _ = db.snapshot()
                jitter = base + rng.normal(scale=1e-3, size=base.shape).astype(
                    np.float32
                )
                jitter /= np.linalg.norm(jitter, axis=1, keepdims=True)
                try:
                    db.swap_table(jitter, expect_current=version)
                except ConflictError:
                    pass  # lost the race: exactly the CAS contract
                guard.check()
        except Exception as exc:  # pragma: no cover
            errors.append(f"swap: {exc!r}")

    def churn():
        try:
            stage_set = router.stage_set()[1]
            for _ in range(iters):
                version = router.stage_version
                try:
                    router.set_stages(stage_set, expect_version=version)
                except ConflictError:
                    pass
        except Exception as exc:  # pragma: no cover
            errors.append(f"churn: {exc!r}")

    with watch_dispatch(graph):
        threads = [
            threading.Thread(target=fn, name=name)
            for name, fn in (("serve", serve), ("swap", swap), ("churn", churn))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
    router.close()

    report = graph.report()
    report["errors"] = errors
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lockgraph",
        description="Fail on lock-order cycles or JAX dispatch under a lock "
        "in a threaded serve/swap/churn scenario.",
    )
    ap.add_argument("--smoke", action="store_true", help="CI-sized scenario")
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    report = run_scenario(iters=args.iters, seed=args.seed)
    print(f"tracked locks: {len(report['locks'])}")
    for e in report["edges"]:
        print(f"  order: {e}")
    ok = True
    for c in report["cycles"]:
        print(f"LOCK-ORDER CYCLE: {c}", file=sys.stderr)
        ok = False
    for ev in report["dispatch_under_lock"]:
        print(
            f"DISPATCH UNDER LOCK: {ev['fn']} while holding "
            f"{ev['locks']} on thread {ev['thread']}",
            file=sys.stderr,
        )
        ok = False
    for err in report["errors"]:
        print(f"SCENARIO ERROR: {err}", file=sys.stderr)
        ok = False
    if ok:
        print("lockgraph check OK: no cycles, no dispatch under a lock")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
