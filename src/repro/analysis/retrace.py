"""Runtime jit-retrace detector for the hot serving path.

A retrace (a fresh XLA compile) is a multi-ms stall against the 10 ms p99
budget, so `route_batch` pads every batch into a power-of-two bucket
(`repro.common.bucketing`) and the jitted entry points are supposed to
compile once per bucket, ever. This module checks that contract at
runtime: `RetraceMonitor` records each tracked jitted callable's compile
cache size (`jax.jit(f)._cache_size()`) around a workload and reports how
many NEW traces the workload caused.

Two consumers:

* `python -m repro.analysis.retrace` — CI leg: builds a small router
  (dense backend, adapter stage active), sweeps mixed batch sizes through
  `route_batch`, and fails if any hot-path entry point traced more than
  once per (power-of-two bucket x live table/stage generation);
* `benchmarks/router_bench.py` — wraps its sweep in a monitor so the
  perf numbers and the retrace contract are checked by the same run.

`_cache_size` is a private-but-stable jax API (present throughout the
0.4.x line this repo pins). When a jitted callable does not expose it the
monitor degrades to "unsupported" rather than failing: the static
`jit-in-function` / `jit-static-scalar` lint rules still cover the
construction-time hazards.
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = ["supports_cache_size", "RetraceMonitor", "hot_path_monitor", "main"]


def supports_cache_size(fn) -> bool:
    """True when `fn` exposes the jit compile-cache probe this module needs."""
    return callable(getattr(fn, "_cache_size", None))


class RetraceMonitor:
    """Counts new jit traces per tracked callable across a workload.

    Usage::

        mon = RetraceMonitor()
        mon.track("topk_dense", retrieval.topk_dense)
        with mon:
            run_workload()
        mon.check({"topk_dense": expected_max_traces})  # -> violations

    Counting deltas (not absolute cache sizes) makes the monitor
    composable with anything that already warmed the cache — a prior test,
    a warmup sweep — at the cost of missing traces that happened before
    `__enter__`. CI runs it around the FULL workload in a fresh process,
    where the delta is the absolute count.
    """

    def __init__(self):
        self._fns: Dict[str, Callable] = {}
        self._unsupported: List[str] = []
        self._before: Dict[str, int] = {}
        self._after: Optional[Dict[str, int]] = None

    def track(self, name: str, fn: Callable) -> bool:
        """Register a jitted callable; False (and skip) if unsupported."""
        if not supports_cache_size(fn):
            self._unsupported.append(name)
            return False
        self._fns[name] = fn
        return True

    @property
    def unsupported(self) -> List[str]:
        return list(self._unsupported)

    def __enter__(self):
        self._after = None
        self._before = {n: f._cache_size() for n, f in self._fns.items()}
        return self

    def __exit__(self, *exc):
        self._after = {n: f._cache_size() for n, f in self._fns.items()}
        return False

    def traces(self) -> Dict[str, int]:
        """{name: new traces during the with-block}."""
        assert self._after is not None, "traces() outside a completed with-block"
        return {n: self._after[n] - self._before[n] for n in self._fns}

    def check(self, budget: Dict[str, int]) -> List[str]:
        """Human-readable violations for every tracked fn over its budget."""
        got = self.traces()
        out = []
        for name, limit in budget.items():
            if name not in got:
                continue  # unsupported or untracked: not a failure
            if got[name] > limit:
                out.append(
                    f"{name}: {got[name]} traces > expected {limit} — a "
                    f"batch escaped the power-of-two buckets (or a new "
                    f"shape/dtype generation was introduced silently)"
                )
        return out


def hot_path_monitor() -> RetraceMonitor:
    """Monitor pre-loaded with the route_batch hot-path entry points.

    Sourced from `repro.router.gateway.hot_path_jits` — the gateway owns
    the list, so this CI leg and the live `obs.profile.JitProfiler` can
    never silently watch different program sets.
    """
    from repro.router.gateway import hot_path_jits

    mon = RetraceMonitor()
    for name, fn in hot_path_jits().items():
        mon.track(name, fn)
    return mon


# ----------------------------------------------------------------- CI leg


def _build_router(n_tools: int, dim: int, seed: int):
    """Small self-contained router: dense backend + adapter stage active."""
    import jax
    import jax.numpy as jnp

    from repro.router.gateway import SemanticRouter
    from repro.router.stages import StageSet
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n_tools, dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    records = [
        ToolRecord(i, f"tool_{i}", np.arange(1, dtype=np.int64), 0)
        for i in range(n_tools)
    ]
    db = ToolsDatabase(records, emb)

    def embed_one(tokens: np.ndarray) -> np.ndarray:
        v = np.sin((np.arange(dim) + 1.0) * (1.0 + float(np.sum(tokens))))
        return (v / np.linalg.norm(v)).astype(np.float32)

    def embed_batch(batch) -> np.ndarray:
        return np.stack([embed_one(t) for t in batch])

    # a dim-matched residual head (init_adapter is pinned to the production
    # 384-dim encoder; the scenario uses a small dim to keep CI fast). Same
    # structure as repro.core.adapter: identity at w2=0, so routing quality
    # is irrelevant — only the compile-cache behavior is under test.
    hidden = max(dim // 2, 2)
    k1 = jax.random.PRNGKey(seed + 1)
    params = {
        "w1": jax.random.normal(k1, (dim, hidden), jnp.float32) * 0.02,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jnp.zeros((hidden, dim), jnp.float32),
        "b2": jnp.zeros((dim,), jnp.float32),
    }
    router = SemanticRouter(
        db,
        embed_fn=embed_one,
        embed_batch_fn=embed_batch,
        k=4,
        stages=StageSet(adapter_params=params, adapter_scale=0.1),
    )
    return router, db


def run_scenario(
    batch_sizes, n_tools: int = 48, dim: int = 16, seed: int = 0
) -> Dict[str, object]:
    """Sweep `batch_sizes` through route_batch under the hot-path monitor.

    Returns {"traces": {...}, "violations": [...], "unsupported": [...],
    "buckets": [...]}.
    """
    from repro.common.bucketing import expected_buckets

    router, _ = _build_router(n_tools, dim, seed)
    rng = np.random.default_rng(seed + 7)
    mon = hot_path_monitor()
    try:
        with mon:
            for n in batch_sizes:
                queries = [
                    rng.integers(0, 50, size=rng.integers(1, 6)).astype(np.int64)
                    for _ in range(n)
                ]
                results = router.route_batch(queries)
                assert len(results) == n
        buckets = expected_buckets(batch_sizes)
        # one trace per bucket for every entry point on the route_batch
        # path; the reranker is not configured in this scenario so its
        # budget is zero new traces
        violations = mon.check(
            {
                "topk_dense": len(buckets),
                "adapter_apply": len(buckets),
                "rerank_topk_scored": 0,
            }
        )
    finally:
        router.close()
    return {
        "traces": mon.traces(),
        "violations": violations,
        "unsupported": mon.unsupported,
        "buckets": buckets,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.retrace",
        description="Fail if route_batch hot-path jits retrace beyond the "
        "power-of-two bucket set.",
    )
    ap.add_argument(
        "--smoke", action="store_true", help="small sweep (CI default sizes)"
    )
    ap.add_argument(
        "--batch-sizes",
        default=None,
        help="comma-separated batch sizes (overrides --smoke)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.batch_sizes:
        sizes = [int(s) for s in args.batch_sizes.split(",") if s.strip()]
    else:
        # mixed ragged sizes sharing buckets: {1,2,3,4} -> buckets {1,2,4},
        # {5,7,8} -> {8}, {9,16} -> {16} — 6 buckets, 12 calls
        sizes = [1, 2, 3, 4, 5, 7, 8, 9, 16, 3, 8, 16]

    report = run_scenario(sizes, seed=args.seed)
    print(f"batch sizes: {sizes}")
    print(f"expected buckets: {report['buckets']}")
    for name, n in sorted(report["traces"].items()):
        print(f"  {name}: {n} trace(s)")
    for name in report["unsupported"]:
        print(f"  {name}: SKIPPED (no _cache_size on this jax build)")
    if report["violations"]:
        for v in report["violations"]:
            print(f"RETRACE VIOLATION: {v}", file=sys.stderr)
        return 1
    print("retrace check OK: hot path compiled once per bucket")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
