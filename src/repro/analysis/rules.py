"""AST rules for the repo-specific invariant analyzer.

Each rule encodes one invariant the serving/control/index/learning planes
rely on but that generic linters cannot know. Rules come in two kinds:

  * **module rules** — run per parsed file (`check(module) -> findings`);
  * **project rules** — run once over the whole scanned file set plus the
    tests directory (`check_project(modules, tests_dir)`), for contracts
    that span files (kernel/ref/parity-test triples).

Registering a new rule: subclass `Rule`, set `rule_id`/`description`/
`hint`, implement `check` (or `check_project` with `project = True`), and
decorate with `@register`. The engine discovers rules through `REGISTRY`.
See `repro.analysis.__init__` for the rule catalog with rationale.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

from repro.analysis.findings import Finding

__all__ = ["ModuleInfo", "Rule", "REGISTRY", "register"]


# --------------------------------------------------------------------- model


class ModuleInfo:
    """One parsed source file handed to every module rule."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel  # posix path used in findings/baseline (stable key)
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Rule:
    rule_id = ""
    description = ""
    hint = ""
    project = False  # True: check_project(modules, tests_dir) once per run

    def finding(self, module: ModuleInfo, node: ast.AST, message: str) -> Finding:
        return Finding(
            rule=self.rule_id,
            file=module.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message,
            hint=self.hint,
        )

    def check(self, module: ModuleInfo) -> Iterator[Finding]:  # module rules
        return iter(())

    def check_project(
        self, modules: Sequence[ModuleInfo], tests_dir: Optional[Path]
    ) -> Iterator[Finding]:  # project rules
        return iter(())


REGISTRY: Dict[str, Rule] = {}


def register(cls):
    inst = cls()
    assert inst.rule_id and inst.rule_id not in REGISTRY
    REGISTRY[inst.rule_id] = inst
    return cls


# ------------------------------------------------------------------- helpers


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.sharding.use_mesh' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    """True for `jax.jit` / `jit` references and `functools.partial(jax.jit, ...)`."""
    d = dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        fd = dotted(node.func)
        if fd in ("functools.partial", "partial") and node.args:
            return _is_jax_jit(node.args[0])
        return _is_jax_jit(node.func)
    return False


def _static_names_from_jit(node: ast.AST, fn: Optional[ast.FunctionDef]) -> Set[str]:
    """Parameter names made static by a jit expression (decorator or call)."""
    static: Set[str] = set()
    if not isinstance(node, ast.Call):
        return static
    nums: List[int] = []
    for kw in node.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                static.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                        static.add(elt.value)
        elif kw.arg == "static_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                nums.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                        nums.append(elt.value)
    if fn is not None and nums:
        params = [a.arg for a in fn.args.args]
        for i in nums:
            if 0 <= i < len(params):
                static.add(params[i])
    # nested partial: functools.partial(jax.jit, static_argnames=...)
    if node.args and isinstance(node.args[0], ast.Call):
        static |= _static_names_from_jit(node.args[0], fn)
    return static


class _FuncStackWalker(ast.NodeVisitor):
    """Base visitor tracking the enclosing-function nesting depth."""

    def __init__(self):
        self.func_depth = 0

    def visit_FunctionDef(self, node):
        self.func_depth += 1
        self.generic_visit(node)
        self.func_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef


def _in_packages(rel: str, packages: Iterable[str]) -> bool:
    return any(f"/{p}/" in f"/{rel}" or rel.startswith(f"{p}/") for p in packages)


# --------------------------------------------------------------------- rules


@register
class MeshApiRule(Rule):
    rule_id = "mesh-api"
    description = (
        "Raw JAX mesh-context APIs (set_mesh/use_mesh/get_abstract_mesh/"
        "make_mesh/shard_map/thread_resources) outside common/meshctx.py — "
        "these drift across JAX releases; meshctx is the one place that "
        "papers over them."
    )
    hint = (
        "route through repro.common.meshctx "
        "(current_mesh/use_mesh/make_mesh/axis_sizes_dict/shard_map)"
    )

    BAD_EXACT = {
        "jax.set_mesh",
        "jax.sharding.use_mesh",
        "jax.sharding.get_abstract_mesh",
        "jax.make_mesh",
        "jax.shard_map",
    }
    BAD_PREFIX = ("jax._src.mesh", "jax.experimental.shard_map")
    BAD_IMPORT_FROM = {
        "jax": {"set_mesh", "make_mesh", "shard_map"},
        "jax.sharding": {"use_mesh", "get_abstract_mesh"},
        "jax.experimental.shard_map": None,  # None: any name
        "jax._src.mesh": None,
    }

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        if module.rel.endswith("common/meshctx.py"):
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Attribute):
                d = dotted(node)
                if d is None:
                    continue
                if d in self.BAD_EXACT or d.startswith(self.BAD_PREFIX):
                    yield self.finding(module, node, f"raw JAX mesh API `{d}`")
                elif node.attr == "thread_resources" and d.startswith("jax"):
                    yield self.finding(module, node, f"raw JAX mesh API `{d}`")
            elif isinstance(node, ast.ImportFrom) and node.module:
                allowed = self.BAD_IMPORT_FROM.get(node.module, ...)
                if allowed is ...:
                    if node.module.startswith(self.BAD_PREFIX):
                        yield self.finding(
                            module, node,
                            f"import from drift-prone `{node.module}`",
                        )
                    continue
                for alias in node.names:
                    if allowed is None or alias.name in allowed:
                        yield self.finding(
                            module, node,
                            f"`from {node.module} import {alias.name}` is a "
                            f"raw mesh API",
                        )


@register
class CasDisciplineRule(Rule):
    rule_id = "cas-discipline"
    description = (
        "swap_table/rollback/set_stages/rollback_stages called without the "
        "compare-and-swap expectation keyword — a bare call can silently "
        "clobber a concurrent deployment (the lost-update the versioned "
        "stores exist to refuse)."
    )
    hint = (
        "pass expect_current= (tables/stage rollback) or expect_version= "
        "(set_stages) from the snapshot the change was derived from"
    )

    REQUIRED = {
        "swap_table": "expect_current",
        "rollback": "expect_current",
        "rollback_stages": "expect_current",
        "set_stages": "expect_version",
    }
    # receivers whose `rollback` is bounded-history trimming, not a serving
    # CAS (ArtifactRegistry.rollback has no expectation parameter by design:
    # it is always called with the registry lock's owner having just read
    # the live StageSet)
    EXEMPT_RECEIVER_PARTS = ("registry", "registries")

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
                continue
            meth = node.func.attr
            kw_required = self.REQUIRED.get(meth)
            if kw_required is None:
                continue
            recv = dotted(node.func.value) or ""
            last = recv.split(".")[-1].lower()
            if any(p in last for p in self.EXEMPT_RECEIVER_PARTS):
                continue
            if any(kw.arg == kw_required for kw in node.keywords):
                continue
            if len(node.args) >= 2:  # expectation passed positionally
                continue
            yield self.finding(
                module, node,
                f"`{recv or '<expr>'}.{meth}(...)` without {kw_required}= "
                f"is not compare-and-swap",
            )


@register
class SnapshotDisciplineRule(Rule):
    rule_id = "snapshot-discipline"
    description = (
        "Direct access to another object's mutable versioned-store fields "
        "(_table/_history/_stages/_stage_history/_swap_listeners) outside "
        "the owning module — bypasses the atomic snapshot()/stage_set() "
        "read and can observe a half-completed swap."
    )
    hint = (
        "read through ToolsDatabase.snapshot() / SemanticRouter.stage_set() "
        "(atomic version+value) instead of reaching into private state"
    )

    PRIVATE = {"_table", "_history", "_stages", "_stage_history", "_swap_listeners"}
    OWNERS = ("router/tooldb.py", "router/gateway.py", "router/stages.py")

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        if module.rel.endswith(self.OWNERS):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Attribute) or node.attr not in self.PRIVATE:
                continue
            if isinstance(node.value, ast.Name) and node.value.id in ("self", "cls"):
                continue  # a class's own private state is its own business
            recv = dotted(node.value) or "<expr>"
            yield self.finding(
                module, node,
                f"direct access to versioned-store internal "
                f"`{recv}.{node.attr}`",
            )


@register
class JitInFunctionRule(Rule):
    rule_id = "jit-in-function"
    description = (
        "jax.jit applied inside a function body — every call/instance gets "
        "a fresh trace cache, so the compile cost the module-level jits pay "
        "once is paid per object (a multi-ms stall if it ever reaches the "
        "hot path)."
    )
    hint = (
        "hoist the jit to module scope; if the closure is deliberate "
        "(per-process singleton, offline training loop), baseline it with "
        "a justification"
    )

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        findings: List[Finding] = []
        seen_lines: Set[int] = set()
        rule = self

        class V(_FuncStackWalker):
            def visit_FunctionDef(self, node):
                if self.func_depth > 0:  # nested def: check jit decorators
                    for dec in node.decorator_list:
                        if _is_jax_jit(dec) and dec.lineno not in seen_lines:
                            seen_lines.add(dec.lineno)
                            findings.append(rule.finding(
                                module, dec,
                                f"`@jax.jit` on `{node.name}` defined inside "
                                f"a function",
                            ))
                super().visit_FunctionDef(node)

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Call(self, node):
                if (
                    self.func_depth > 0
                    and dotted(node.func) in ("jax.jit", "jit")
                    and node.lineno not in seen_lines
                ):
                    seen_lines.add(node.lineno)
                    findings.append(rule.finding(
                        module, node, "jax.jit(...) called inside a function"
                    ))
                self.generic_visit(node)

        V().visit(module.tree)
        yield from findings


@register
class JitStaticScalarRule(Rule):
    rule_id = "jit-static-scalar"
    description = (
        "A jitted function takes a Python-scalar parameter (int/bool/str "
        "annotation) that is not in static_argnames — shape-controlling "
        "scalars silently become traced values (wrong results or tracer "
        "errors), and hashable config scalars belong in the compile key."
    )
    hint = "add the parameter to static_argnames (or drop the jit wrapper)"

    SCALAR_ANNOTATIONS = {"int", "bool", "str"}

    def _scalar_params(self, fn: ast.FunctionDef) -> List[str]:
        out = []
        for a in list(fn.args.args) + list(fn.args.kwonlyargs):
            ann = a.annotation
            if isinstance(ann, ast.Name) and ann.id in self.SCALAR_ANNOTATIONS:
                out.append(a.arg)
        return out

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        # defs decorated with jit (any nesting level)
        defs: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defs.setdefault(node.name, node)
            for dec in node.decorator_list:
                if not _is_jax_jit(dec):
                    continue
                static = _static_names_from_jit(dec, node)
                for p in self._scalar_params(node):
                    if p not in static:
                        yield self.finding(
                            module, dec,
                            f"jitted `{node.name}` takes scalar `{p}` "
                            f"outside static_argnames",
                        )
        # assignment form: g = jax.jit(local_fn, ...)
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call) and dotted(node.func) in ("jax.jit", "jit")):
                continue
            if not node.args or not isinstance(node.args[0], ast.Name):
                continue
            target = defs.get(node.args[0].id)
            if target is None or target.decorator_list:
                continue  # unresolvable or already checked via decorator
            static = _static_names_from_jit(node, target)
            for p in self._scalar_params(target):
                if p not in static:
                    yield self.finding(
                        module, node,
                        f"jax.jit({target.name}) leaves scalar `{p}` "
                        f"outside static_argnames",
                    )


@register
class Pow2BucketRule(Rule):
    rule_id = "pow2-bucket"
    description = (
        "Hand-rolled power-of-two bucket arithmetic (`1 << n.bit_length()`) "
        "outside common/bucketing.py — every jitted entry point must agree "
        "on ONE bucketing function or the retrace budget is per-module "
        "luck, and the retrace detector's expected-bucket set goes stale."
    )
    hint = "use repro.common.bucketing.pow2_bucket / expected_buckets"

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        if module.rel.endswith("common/bucketing.py"):
            return
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.LShift)):
                continue
            if not (isinstance(node.left, ast.Constant) and node.left.value == 1):
                continue
            uses_bit_length = any(
                isinstance(sub, ast.Attribute) and sub.attr == "bit_length"
                for sub in ast.walk(node.right)
            )
            if uses_bit_length:
                yield self.finding(
                    module, node, "manual power-of-two bucket computation"
                )


@register
class LockDispatchRule(Rule):
    rule_id = "lock-dispatch"
    description = (
        "JAX dispatch (jnp.*/jax.*/known jitted entry points/device_put) "
        "lexically inside a `with <lock>:` block in the serving-adjacent "
        "packages — device work under a hot-path lock stalls every thread "
        "contending for it (a compile is a multi-ms budget breach for all "
        "of them)."
    )
    hint = (
        "compute device work outside the critical section; hold the lock "
        "only to publish the result (see ToolIndexManager._build)"
    )

    PACKAGES = ("router", "control", "learn", "index")
    KNOWN_JITTED = {
        "topk_dense",
        "rerank_topk_scored",
        "topk_sim",
        "topk_sim_pallas",
        "adapter_apply",
        "refine_embeddings",
        "batched_recall_at_k",
        "batched_ndcg_at_k",
    }
    LOCKISH = ("lock", "cond", "mutex")

    def _is_lockish(self, expr: ast.AST) -> bool:
        d = dotted(expr if not isinstance(expr, ast.Call) else expr.func)
        if d is None:
            return False
        return any(p in d.split(".")[-1].lower() for p in self.LOCKISH)

    def _dispatchy(self, call: ast.Call, jitted: Set[str]) -> Optional[str]:
        d = dotted(call.func)
        if d is None:
            return None
        if d.startswith(("jnp.", "jax.")):
            return d
        parts = d.split(".")
        if parts[-1] == "device_put" or parts[-1] in jitted or d in jitted:
            return d
        return None

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        if not _in_packages(module.rel, self.PACKAGES):
            return
        # names jitted in this module (assignments + decorated defs) extend
        # the cross-module known set
        jitted = set(self.KNOWN_JITTED)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                if _is_jax_jit(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            jitted.add(t.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_jax_jit(dec) for dec in node.decorator_list):
                    jitted.add(node.name)

        findings: List[Finding] = []
        rule = self

        def scan_node(sub, lock_name: str):
            # a def/lambda nested under the with does not run there — do
            # not descend (ast.walk would; recurse by hand instead)
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return
            if isinstance(sub, ast.Call):
                d = rule._dispatchy(sub, jitted)
                if d is not None:
                    findings.append(rule.finding(
                        module, sub,
                        f"JAX dispatch `{d}(...)` inside `with {lock_name}:`",
                    ))
            for child in ast.iter_child_nodes(sub):
                scan_node(child, lock_name)

        def scan_body(stmts, lock_name: str):
            for stmt in stmts:
                scan_node(stmt, lock_name)

        for node in ast.walk(module.tree):
            if not isinstance(node, ast.With):
                continue
            for item in node.items:
                if self._is_lockish(item.context_expr):
                    name = dotted(item.context_expr) or "<lock>"
                    scan_body(node.body, name)
                    break
        yield from findings


@register
class CacheVersionStampRule(Rule):
    rule_id = "cache-version-stamp"
    description = (
        "A route-cache lookup/insert site missing an explicit "
        "table_version=/stage_version= stamp, or JAX dispatch (jnp.*/jax.*/"
        "known jitted entry points) lexically under a lock in the `cache/` "
        "package — the cache's exact-invalidation story holds only if every "
        "entry is stamped with the snapshot its scores came from, and the "
        "cache lock is a hot-path lock the gateway takes per batch."
    )
    hint = (
        "pass table_version=/stage_version= from the same snapshot that "
        "produced the scores (the topk's returned version, not a racy live "
        "read); keep cache critical sections numpy-only — dispatch before "
        "taking the lock"
    )

    STAMPED_METHODS = ("lookup_batch", "insert_batch")
    STAMPS = ("table_version", "stage_version")

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
                continue
            if node.func.attr not in self.STAMPED_METHODS:
                continue
            recv = dotted(node.func.value) or ""
            if "cache" not in recv.split(".")[-1].lower():
                continue
            kws = {kw.arg for kw in node.keywords}
            missing = [s for s in self.STAMPS if s not in kws]
            if missing:
                yield self.finding(
                    module, node,
                    f"`{recv}.{node.func.attr}(...)` without "
                    f"{'/'.join(s + '=' for s in missing)} — unstamped cache "
                    f"traffic defeats exact invalidation",
                )
        # the lock-dispatch scan, scoped to the cache package (which the
        # lock-dispatch rule's serving-package list predates)
        if _in_packages(module.rel, ("cache",)):
            scoped = LockDispatchRule()
            scoped.PACKAGES = ("cache",)
            for f in scoped.check(module):
                yield Finding(
                    self.rule_id, f.file, f.line, f.col,
                    f.message + " (route-cache critical section)", self.hint,
                )


@register
class ThreadDisciplineRule(Rule):
    rule_id = "thread-discipline"
    description = (
        "A daemon thread's locally-defined loop either lets exceptions kill "
        "it silently or catches them without recording the failure — a dead "
        "or flapping control/learning plane that no guard or health check "
        "can detect."
    )
    hint = (
        "wrap the loop body in try/except Exception and record the failure "
        "on an attribute a health check reads (e.g. self.last_loop_error = "
        "exc; clear it on success)"
    )

    def _local_def(self, enclosing: ast.FunctionDef, name: str):
        for stmt in ast.walk(enclosing):
            if isinstance(stmt, ast.FunctionDef) and stmt.name == name:
                return stmt
        return None

    def _handler_records_error(self, handler: ast.ExceptHandler) -> bool:
        for sub in ast.walk(handler):
            if isinstance(sub, ast.Assign):
                for t in sub.targets:
                    if isinstance(t, ast.Attribute) and (
                        "error" in t.attr.lower() or "exception" in t.attr.lower()
                    ):
                        return True
            if isinstance(sub, ast.Call):
                d = dotted(sub.func) or ""
                leaf = d.split(".")[-1].lower()
                if "error" in leaf or "exception" in leaf:
                    return True
        return False

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        for fn in ast.walk(module.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func) or ""
                if d.split(".")[-1] != "Thread":
                    continue
                daemon = any(
                    kw.arg == "daemon"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    for kw in node.keywords
                )
                if not daemon:
                    continue
                target = next(
                    (kw.value for kw in node.keywords if kw.arg == "target"), None
                )
                if not isinstance(target, ast.Name):
                    continue  # bound method target: judged where defined
                loop = self._local_def(fn, target.id)
                if loop is None:
                    continue
                handlers = [
                    h
                    for t in ast.walk(loop)
                    if isinstance(t, ast.Try)
                    for h in t.handlers
                    if h.type is None
                    or (isinstance(h.type, ast.Name)
                        and h.type.id in ("Exception", "BaseException"))
                ]
                if not handlers:
                    yield self.finding(
                        module, node,
                        f"daemon loop `{target.id}` has no except Exception: "
                        f"the first transient failure kills the thread "
                        f"silently",
                    )
                elif not any(self._handler_records_error(h) for h in handlers):
                    yield self.finding(
                        module, node,
                        f"daemon loop `{target.id}` swallows exceptions "
                        f"without recording them where a health check can "
                        f"see the failure",
                    )


@register
class KernelContractRule(Rule):
    rule_id = "kernel-contract"
    description = (
        "Every kernels/<name>/kernel.py must ship a ref.py oracle sibling "
        "and a parity test referencing the kernel; top-K kernels must pad "
        "with the canonical NEG_INF sentinel (the gateway filters selected "
        "tools by `score > NEG_INF / 2` — a drifted sentinel silently "
        "surfaces padding as results)."
    )
    hint = (
        "add ref.py + a tests/ parity test importing repro.kernels.<name>; "
        "import NEG_INF from repro.core.retrieval instead of hardcoding"
    )
    project = True

    def check_project(
        self, modules: Sequence[ModuleInfo], tests_dir: Optional[Path]
    ) -> Iterator[Finding]:
        kernels: Dict[str, ModuleInfo] = {}
        by_rel = {m.rel: m for m in modules}
        for m in modules:
            parts = m.rel.split("/")
            if len(parts) >= 3 and parts[-3] == "kernels" and parts[-1] == "kernel.py":
                kernels[parts[-2]] = m
        test_text = ""
        if tests_dir is not None and tests_dir.is_dir():
            test_text = "\n".join(
                p.read_text() for p in sorted(tests_dir.rglob("*.py"))
            )
        for name, kmod in sorted(kernels.items()):
            if not (kmod.path.parent / "ref.py").exists():
                yield self.finding(
                    kmod, kmod.tree,
                    f"kernel `{name}` has no ref.py oracle sibling",
                )
            if tests_dir is not None and f"kernels.{name}" not in test_text:
                yield self.finding(
                    kmod, kmod.tree,
                    f"no parity test references repro.kernels.{name}",
                )
            if "topk" not in name:
                continue  # the NEG_INF padding contract is a top-K contract
            for sibling in ("kernel.py", "ref.py", "ops.py"):
                rel = kmod.rel.rsplit("/", 1)[0] + "/" + sibling
                smod = by_rel.get(rel)
                if smod is None:
                    continue
                if "NEG_INF" in smod.text:
                    imported = any(
                        isinstance(n, ast.ImportFrom)
                        and any(a.name == "NEG_INF" for a in n.names)
                        for n in ast.walk(smod.tree)
                    )
                    if not imported:
                        yield Finding(
                            self.rule_id, smod.rel, 1, 0,
                            f"`{sibling}` names NEG_INF without importing "
                            f"the canonical constant", self.hint,
                        )
                for node in ast.walk(smod.tree):
                    val = None
                    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
                        if isinstance(node.operand, ast.Constant) and isinstance(
                            node.operand.value, (int, float)
                        ):
                            val = -float(node.operand.value)
                    elif isinstance(node, ast.Constant) and isinstance(
                        node.value, (int, float)
                    ):
                        val = float(node.value)
                    if val is not None and val <= -1e29:
                        yield Finding(
                            self.rule_id, smod.rel, node.lineno,
                            node.col_offset,
                            f"hardcoded top-K padding sentinel {val:g} in "
                            f"`{sibling}`", self.hint,
                        )


@register
class ObsDisciplineRule(Rule):
    rule_id = "obs-discipline"
    description = (
        "Direct `time.time()`/`time.perf_counter()`/`time.monotonic()` or "
        "`print()` in the serving-path packages (`router/`, `index/`) and "
        "the daemon planes (`control/`, `learn/`) — timing there must flow "
        "through `repro.obs.clock` (one monotonic source per recorded "
        "duration; wall-clock steps from NTP slew corrupt latency "
        "histograms and controller cooldown/cadence arithmetic) and "
        "operator output through the telemetry plane (metrics/events), not "
        "stdout a serving process never reads."
    )
    hint = (
        "use repro.obs.clock (perf/monotonic/wall/duration_ms) for timing "
        "and the MetricsRegistry/EventBus for operator-facing output"
    )

    PACKAGES = ("router", "index", "control", "learn")
    FORBIDDEN_TIME = {"time.time", "time.perf_counter", "time.monotonic"}

    def check(self, module: ModuleInfo) -> Iterator[Finding]:
        if not _in_packages(module.rel, self.PACKAGES):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d in self.FORBIDDEN_TIME:
                yield self.finding(
                    module, node,
                    f"`{d}()` in a serving-path package; use the "
                    f"repro.obs.clock equivalent",
                )
            elif d == "print":
                yield self.finding(
                    module, node,
                    "`print()` in a serving-path package; publish to the "
                    "telemetry plane instead",
                )
