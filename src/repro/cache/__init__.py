"""Serving-plane route cache: near-duplicate reuse with exact invalidation.

`SemanticRouteCache` sits between `SemanticRouter.route_batch`'s embed step
and the index backend: queries whose embeddings land within ``threshold``
cosine of a cached one are served the cached top-K (tools + scores) without
touching `ToolIndexManager.topk` or the Stage-2 re-ranker. On Zipfian
near-duplicate traffic this converts the dominant score+re-rank cost into a
dict probe plus one 384-float dot product.

Choosing a config (mirrors the backend-selection guides in `repro.index` /
`repro.learn`):

``threshold`` — the correctness knob. A hit is served only when
    cosine(stored query, new query) >= threshold; everything else about the
    cache (LSH tables, LRU) only affects *where* it looks, never *whether*
    a far query can be served. 0.95 (default) keeps routing agreement with
    the uncached path >= 0.98 on paraphrase-jittered streams
    (BENCH_cache.json); raise toward 0.99 for registries with many
    near-synonym tools, lower toward 0.9 only when the tool corpus is
    coarse and hit-rate matters more than tail agreement. ``threshold=2.0``
    is the supported "never hit" setting used to measure pure cache
    overhead (see `benchmarks/obs_bench.py`).

``min_gap`` — conservative serving guard: a hit is additionally required
    to have had a stored top-1 minus top-2 score gap >= min_gap, since a
    near-duplicate can only flip the top-1 decision when the stored gap is
    small relative to the query perturbation. Default 0.0 (off) — on the
    benched Zipf streams it cost hit-rate without buying agreement — but
    raise it (~0.05) for registries where serving a flipped top-1 is much
    worse than a cache miss.

``n_bits`` / ``n_tables`` — the recall/collision tradeoff of the LSH
    keys. A near-duplicate at angle theta flips each sign bit with
    probability theta/pi, so one table of many bits misses most
    paraphrases; the defaults (8 tables x 12 bits, eight dict probes per
    query) catch ~93% of cosine-0.95 pairs. More bits per table = fewer
    cross-intent collisions (hot intents overwriting each other); more
    tables = higher near-duplicate recall at proportionally more probes
    and key slots per entry.

``capacity`` — bound on retained key slots; beyond it the
    least-recently-used slot is evicted. One decision occupies n_tables
    slots (the entry itself is shared), so the default 65536 holds ~8k
    distinct decisions at ~2 KB each (dim=384, K=5) — ~16 MB.

``seed`` — hyperplane RNG seed. Keys are deterministic per (seed, dim), so
    replayed traffic buckets identically across runs.

Invalidation is exact, never TTL-based: entries are stamped with the
``(table_version, stage_version)`` under which their scores were computed,
lookups require the stamp to equal the live pair, and version counters are
monotone — so a control-plane swap or rollback can never leave a servable
stale entry, even if no event is delivered. Wire `cache.watch(bus)` next to
`EventBus.watch_db(db)` to also purge eagerly on ``swap``/``stage_swap``
and emit the ``cache_invalidated`` event + counters the ROADMAP runbook
watches.

Pass the cache to `SemanticRouter(..., cache=...)` — the gateway probes it
after embedding (keys are embedding-space), scores only the miss subset,
inserts fresh results, and re-checks every served entry's stamps against
the live snapshot (`route_cache_stale_served_total` must stay 0; the
``cache_staleness`` SLO and `benchmarks/cache_bench.py`'s churn gate hold
it there). Traffic realism lives in `repro.traffic`; the recorded
hit-rate × qps × p99 curves in BENCH_cache.json.
"""
from repro.cache.route_cache import CacheConfig, CachedRoute, SemanticRouteCache

__all__ = ["CacheConfig", "CachedRoute", "SemanticRouteCache"]
