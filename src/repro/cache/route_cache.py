"""SemanticRouteCache: embedding-space near-duplicate cache for route results.

Production gateway traffic is Zipfian — most requests are near-duplicates of
a small hot set — yet every `route_batch` call pays the full score+top-K
(+re-rank) path. This cache serves a previously-computed routing decision
when a new query lands close enough (cosine) to a cached one, skipping the
index backend and the Stage-2 MLP entirely for the hit subset of a batch.

Keying: multi-table LSH over the query embedding's sign bits. Each of
`n_tables` independent tables projects the unit-normalized query onto its
own `n_bits` random hyperplanes (seeded, lazily sized to the embedding
dim); the packed sign pattern is that table's bucket key. A single table
is useless for *near*-duplicates — a cosine-0.95 paraphrase flips any one
sign bit with probability acos(0.95)/pi ~ 0.10, so at 16 bits it lands in
a sibling bucket ~80% of the time. With L tables of b bits the miss
probability is (1 - (1 - theta/pi)^b)^L: the defaults (8 x 12) catch
~93% of cosine-0.95 pairs for eight dict probes per query. Bucket
collisions between genuinely different queries are harmless because a hit
additionally requires cosine similarity to the *stored* query above
``threshold`` — the keys only decide where to look, the cosine check
decides whether to trust.

Staleness is exact, not heuristic. Every entry is stamped with the
``(table_version, stage_version)`` pair its routing decision was computed
under, and `lookup_batch` requires the stamp to equal the live pair the
gateway read at batch entry. Both version counters are monotone (a rollback
is itself a version bump — see `ToolsDatabase.rollback` /
`SemanticRouter.rollback_stages`), so an entry stamped under a superseded
snapshot can never become servable again; stamp-dead entries found during
lookup are reclaimed on the spot. A bus subscription (`watch`) additionally
purges dead entries *eagerly* on every ``swap``/``stage_swap`` event and
publishes ``cache_invalidated`` — that wiring reclaims capacity and feeds
telemetry, but exactness never depends on event delivery.

Concurrency discipline: one lock guards the entry map. Everything under it
is dict traffic plus a 384-float `np.dot` — plain numpy, never `jnp.`/jitted
dispatch (the `cache-version-stamp` analyzer rule enforces this lexically),
so a lookup can never stall a concurrent batch behind device work. Key
computation (the one per-batch allocation on the miss path) happens outside
the lock. Capacity is bounded with LRU eviction: hits refresh recency,
inserts evict the coldest bucket first.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.obs.metrics import MetricsRegistry, get_registry

__all__ = ["CacheConfig", "CachedRoute", "SemanticRouteCache"]


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Knobs, in the order they matter (guidance: package docstring)."""

    threshold: float = 0.95  # min cosine(stored query, new query) for a hit
    min_gap: float = 0.0  # min stored top-1/top-2 margin to serve a hit
    n_bits: int = 12  # hyperplanes per LSH table -> 2^n_bits buckets each
    n_tables: int = 8  # independent LSH tables probed per query
    capacity: int = 65536  # max retained key slots (LRU beyond this); one
    # entry occupies n_tables slots, so distinct cached decisions are
    # bounded by ~capacity / n_tables
    seed: int = 0  # hyperplane RNG seed (deterministic keys per seed)

    def __post_init__(self):
        # threshold > 1 is the supported "never hit" setting for measuring
        # pure cache overhead (benchmarks/obs_bench.py)
        assert 0.0 < self.threshold, self.threshold
        assert 1 <= self.n_bits <= 48, self.n_bits  # packed into one int64
        assert 1 <= self.n_tables <= 64, self.n_tables
        assert self.capacity >= self.n_tables, self.capacity


@dataclasses.dataclass(frozen=True)
class CachedRoute:
    """One cached routing decision + the snapshot stamps it was made under."""

    query: np.ndarray  # unit-norm embedding of the query that was scored
    tools: Tuple[int, ...]
    scores: Tuple[float, ...]
    table_version: int
    stage_version: int
    # top-1/top-2 margin of the stored decision (inf when < 2 candidates):
    # a unit-norm perturbation ||q - q'|| can only flip the top-1 when the
    # gap is < 2*||q - q'||, so low-gap decisions are the ones a paraphrase
    # legitimately re-routes — CacheConfig.min_gap refuses to serve them
    gap: float = float("inf")


class _CacheInstruments:
    """Preresolved metric handles (catalog: `repro.obs` docstring)."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.hits = registry.counter("route_cache_hits_total")
        self.misses = registry.counter("route_cache_misses_total")
        self.evictions = registry.counter("route_cache_evictions_total")
        self.invalidated = registry.counter("route_cache_invalidated_total")
        self.hit_ratio = registry.gauge("route_cache_hit_ratio")
        self.size = registry.gauge("route_cache_size")


class SemanticRouteCache:
    def __init__(
        self,
        config: Optional[CacheConfig] = None,
        metrics: Union[MetricsRegistry, bool, None] = None,
        bus: Optional["EventBus"] = None,  # repro.obs.events
    ):
        self.config = config or CacheConfig()
        self._entries: "OrderedDict[int, CachedRoute]" = OrderedDict()
        self._lock = threading.Lock()
        # hyperplanes sized lazily to the first batch's embedding dim; the
        # init is deterministic in (seed, dim), so a benign double-init race
        # produces identical planes
        self._planes: Optional[np.ndarray] = None
        # per-table bit weights plus a table tag in the high bits, so all
        # n_tables keys live in one dict under disjoint namespaces
        b, L = self.config.n_bits, self.config.n_tables
        self._pows = (1 << np.arange(b, dtype=np.int64))
        self._table_tag = (np.arange(L, dtype=np.int64) << np.int64(b))
        self.stats = {
            "hits": 0,
            "misses": 0,
            "evictions": 0,
            "invalidated": 0,  # version-dead entries purged (eager or lazy)
        }
        if metrics is False:
            self._obs: Optional[_CacheInstruments] = None
        else:
            registry = metrics if isinstance(metrics, MetricsRegistry) else get_registry()
            self._obs = _CacheInstruments(registry)
        self._bus = bus

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # ------------------------------------------------------------------ keys
    def _keys(self, q: np.ndarray) -> np.ndarray:
        """[Q, n_tables] packed LSH sign-bit bucket keys for a query block.

        Pure numpy, computed outside the cache lock — this array is the only
        allocation a miss pays beyond the dict probes.
        """
        b, L = self.config.n_bits, self.config.n_tables
        planes = self._planes
        if planes is None or planes.shape[0] != q.shape[1]:
            rng = np.random.default_rng(self.config.seed)
            planes = rng.standard_normal((q.shape[1], L * b)).astype(np.float32)
            self._planes = planes
        signs = (q @ planes) > 0.0  # [Q, L*b]
        bits = signs.reshape(len(q), L, b).astype(np.int64) @ self._pows  # [Q, L]
        return bits | self._table_tag

    # ---------------------------------------------------------------- serving
    def lookup_batch(
        self,
        q: np.ndarray,
        *,
        table_version: int,
        stage_version: int,
    ) -> List[Optional[CachedRoute]]:
        """Probe the cache for a [Q, D] query block; None per miss.

        A hit requires all three: same bucket key, entry stamped with
        exactly the live ``(table_version, stage_version)`` the caller read
        at batch entry, and cosine(stored query, new query) >= threshold.
        Entries whose stamps are dead (either version moved) are purged on
        sight — monotone version counters mean they can never serve again.
        """
        q = np.asarray(q, dtype=np.float32)
        keys = self._keys(q)  # [Q, n_tables]
        out: List[Optional[CachedRoute]] = [None] * len(keys)
        thr = self.config.threshold
        min_gap = self.config.min_gap
        hits = misses = purged = 0
        with self._lock:
            entries = self._entries
            for j, qkeys in enumerate(keys):
                for key in qkeys:
                    k = int(key)
                    e = entries.get(k)
                    if e is None:
                        continue
                    if (
                        e.table_version != table_version
                        or e.stage_version != stage_version
                    ):
                        del entries[k]  # dead lineage: reclaim the slot
                        purged += 1
                        continue
                    if e.gap < min_gap:
                        continue  # near-tie decision: paraphrases can
                        # legitimately flip it, so score it fresh
                    # numpy scalar dot only — never jnp/jitted work under
                    # this lock (cache-version-stamp analyzer rule)
                    if float(e.query @ q[j]) < thr:
                        continue  # bucket collision or too-far paraphrase
                    entries.move_to_end(k)  # LRU: a hit refreshes recency
                    out[j] = e
                    hits += 1
                    break
                else:
                    misses += 1
            self.stats["hits"] += hits
            self.stats["misses"] += misses
            self.stats["invalidated"] += purged
            total_hits, total_misses = self.stats["hits"], self.stats["misses"]
            size = len(entries)
        obs = self._obs
        if obs is not None:  # telemetry outside the lock
            if hits:
                obs.hits.inc(hits)
            if misses:
                obs.misses.inc(misses)
            if purged:
                obs.invalidated.inc(purged)
            looked = total_hits + total_misses
            if looked:
                obs.hit_ratio.set(total_hits / looked)
            obs.size.set(size)
        return out

    def insert_batch(
        self,
        q: np.ndarray,
        tools: Sequence[Sequence[int]],
        scores: Sequence[Sequence[float]],
        *,
        table_version: int,
        stage_version: int,
    ) -> None:
        """Insert freshly-scored routing decisions for a [Q, D] query block.

        `q` must be the same (raw, pre-adapter) embeddings lookups probe
        with. Each decision is ONE shared CachedRoute registered under its
        key in every LSH table; a same-bucket insert overwrites (last write
        wins), and the coldest key slots are evicted past capacity (a
        partially-evicted entry stays servable through its other tables).
        """
        q = np.asarray(q, dtype=np.float32)
        keys = self._keys(q)  # [Q, n_tables]
        capacity = self.config.capacity
        evicted = 0
        with self._lock:
            entries = self._entries
            for j, qkeys in enumerate(keys):
                ss = tuple(float(s) for s in scores[j])
                e = CachedRoute(
                    query=q[j].copy(),
                    tools=tuple(int(t) for t in tools[j]),
                    scores=ss,
                    table_version=int(table_version),
                    stage_version=int(stage_version),
                    gap=(ss[0] - ss[1]) if len(ss) >= 2 else float("inf"),
                )
                for key in qkeys:
                    k = int(key)
                    entries[k] = e
                    entries.move_to_end(k)
            while len(entries) > capacity:
                entries.popitem(last=False)
                evicted += 1
            self.stats["evictions"] += evicted
            size = len(entries)
        obs = self._obs
        if obs is not None:
            if evicted:
                obs.evictions.inc(evicted)
            obs.size.set(size)

    # ----------------------------------------------------------- invalidation
    def invalidate(
        self,
        table_version: Optional[int] = None,
        stage_version: Optional[int] = None,
        reason: str = "swap",
    ) -> int:
        """Purge entries whose stamp differs from the given live version(s).

        Called by the `watch` bus subscription on every ``swap`` /
        ``stage_swap`` event (and usable directly by launchers that wire no
        bus). Returns the number of entries purged; publishes one
        ``cache_invalidated`` event when anything was.
        """
        with self._lock:
            dead = [
                k
                for k, e in self._entries.items()
                if (table_version is not None and e.table_version != table_version)
                or (stage_version is not None and e.stage_version != stage_version)
            ]
            for k in dead:
                del self._entries[k]
            self.stats["invalidated"] += len(dead)
            size = len(self._entries)
        purged = len(dead)
        obs = self._obs
        if obs is not None:
            if purged:
                obs.invalidated.inc(purged)
            obs.size.set(size)
        if self._bus is not None and purged:
            self._bus.publish(
                "cache_invalidated",
                plane="serve",
                reason=reason,
                table_version=table_version,
                stage_version=stage_version,
                purged=purged,
            )
        return purged

    def watch(self, bus: "EventBus") -> Callable[[], None]:
        """Purge eagerly on every ``swap``/``stage_swap`` bus event.

        Exactness never depends on this — `lookup_batch`'s stamp check is
        the authority — but eager purging reclaims capacity the moment a
        deployment lands and surfaces the ``cache_invalidated`` event +
        counters the runbook watches. Returns a detach handle (idempotent),
        mirroring `EventBus.watch_db`.
        """
        if self._bus is None:
            self._bus = bus

        def on_event(event) -> None:
            if event.kind == "swap":
                self.invalidate(
                    table_version=event.details.get("version"), reason="swap"
                )
            elif event.kind == "stage_swap":
                self.invalidate(
                    stage_version=event.details.get("version"),
                    reason="stage_swap",
                )

        bus.subscribe(on_event)
        return lambda: bus.unsubscribe(on_event)

    # ---------------------------------------------------------------- reading
    def hit_rate(self) -> float:
        """Lifetime hit fraction over all lookups (0.0 before any)."""
        with self._lock:
            hits, misses = self.stats["hits"], self.stats["misses"]
        total = hits + misses
        return hits / total if total else 0.0

    def clear(self) -> int:
        """Drop every entry (returns how many); counters are untouched."""
        with self._lock:
            n = len(self._entries)
            self._entries.clear()
        if self._obs is not None:
            self._obs.size.set(0)
        return n
