"""Checkpointing: msgpack + compressed pytrees (see msgpack_ckpt).

Re-exported at package level so stateful subsystems (trainer, the control
plane's OutcomeStore) can depend on `repro.checkpoint` without naming the
backend module.
"""
from repro.checkpoint.msgpack_ckpt import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint"]
