"""Msgpack + compressed pytree checkpointing (no orbax in the offline
container).

Layout: a single `.ckpt` file = a 5-byte codec header (`b"CKPT" + codec id`)
followed by the compressed msgpack of
  {"meta": {...}, "tree": <nested dicts>, "arrays": [raw buffers]}
Arrays are stored as (dtype, shape, index) leaves referencing the buffer
list, so restore is zero-copy into numpy and device_put'able with any
sharding. Step-numbered files + a LATEST pointer give atomic-ish rotation.

Compression codec: `zstandard` when importable, else stdlib `zlib`. The
codec id in the header makes files self-describing, so checkpoints written
with zstd restore on zlib-only containers *if* zstandard is present there —
otherwise a clear error names the missing codec. Headerless legacy files
(pre-header zstd blobs) are detected by the zstd magic and still restore.
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib
    zstandard = None

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_MARKER = "__array__"
_MAGIC = b"CKPT"
_CODEC_ZSTD = b"\x01"
_CODEC_ZLIB = b"\x02"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"  # legacy headerless files


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        return _MAGIC + _CODEC_ZSTD + zstandard.ZstdCompressor(level=3).compress(payload)
    return _MAGIC + _CODEC_ZLIB + zlib.compress(payload, 3)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _MAGIC:
        codec, body = blob[4:5], blob[5:]
        if codec == _CODEC_ZLIB:
            return zlib.decompress(body)
        if codec == _CODEC_ZSTD:
            if zstandard is None:
                raise RuntimeError(
                    "checkpoint was written with zstd but zstandard is not "
                    "installed in this container"
                )
            return zstandard.ZstdDecompressor().decompress(body, max_output_size=1 << 34)
        raise ValueError(f"unknown checkpoint codec id {codec!r}")
    if blob[:4] == _ZSTD_FRAME_MAGIC:  # legacy headerless zstd checkpoint
        if zstandard is None:
            raise RuntimeError(
                "legacy zstd checkpoint requires the zstandard package"
            )
        return zstandard.ZstdDecompressor().decompress(blob, max_output_size=1 << 34)
    raise ValueError("not a recognized checkpoint file (bad magic)")


def _encode(tree: Any, buffers: list) -> Any:
    if isinstance(tree, dict):
        return {k: _encode(v, buffers) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_encode(v, buffers) for v in tree]
    arr = np.asarray(tree)
    buffers.append(arr.tobytes())
    return {_MARKER: [str(arr.dtype), list(arr.shape), len(buffers) - 1]}


def _decode(tree: Any, buffers: list) -> Any:
    if isinstance(tree, dict):
        if _MARKER in tree:
            dtype, shape, idx = tree[_MARKER]
            return np.frombuffer(buffers[idx], dtype=dtype).reshape(shape).copy()
        return {k: _decode(v, buffers) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_decode(v, buffers) for v in tree]
    return tree


def save_checkpoint(
    directory: str, step: int, tree: Any, meta: Optional[Dict] = None
) -> str:
    os.makedirs(directory, exist_ok=True)
    buffers: list = []
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    enc = _encode(host_tree, buffers)
    meta = dict(meta or {})
    meta.setdefault("codec", "zstd" if zstandard is not None else "zlib")
    payload = msgpack.packb(
        {"meta": meta, "step": step, "tree": enc, "arrays": buffers},
        use_bin_type=True,
    )
    path = os.path.join(directory, f"step_{step:08d}.ckpt")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_compress(payload))
    os.replace(tmp, path)  # atomic rotate
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(str(step))
    return path


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(
    directory: str, step: Optional[int] = None
) -> Tuple[int, Any, Dict]:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.ckpt")
    raw = _decompress(open(path, "rb").read())
    obj = msgpack.unpackb(raw, raw=False)
    return obj["step"], _decode(obj["tree"], obj["arrays"]), obj["meta"]
