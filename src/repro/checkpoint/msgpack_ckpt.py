"""Msgpack + zstd pytree checkpointing (no orbax in the offline container).

Layout: a single `.ckpt` file = zstd-compressed msgpack of
  {"meta": {...}, "tree": <nested dicts>, "arrays": [raw buffers]}
Arrays are stored as (dtype, shape, index) leaves referencing the buffer
list, so restore is zero-copy into numpy and device_put'able with any
sharding. Step-numbered files + a LATEST pointer give atomic-ish rotation.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np
import zstandard

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_MARKER = "__array__"


def _encode(tree: Any, buffers: list) -> Any:
    if isinstance(tree, dict):
        return {k: _encode(v, buffers) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_encode(v, buffers) for v in tree]
    arr = np.asarray(tree)
    buffers.append(arr.tobytes())
    return {_MARKER: [str(arr.dtype), list(arr.shape), len(buffers) - 1]}


def _decode(tree: Any, buffers: list) -> Any:
    if isinstance(tree, dict):
        if _MARKER in tree:
            dtype, shape, idx = tree[_MARKER]
            return np.frombuffer(buffers[idx], dtype=dtype).reshape(shape).copy()
        return {k: _decode(v, buffers) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_decode(v, buffers) for v in tree]
    return tree


def save_checkpoint(
    directory: str, step: int, tree: Any, meta: Optional[Dict] = None
) -> str:
    os.makedirs(directory, exist_ok=True)
    buffers: list = []
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    enc = _encode(host_tree, buffers)
    payload = msgpack.packb(
        {"meta": meta or {}, "step": step, "tree": enc, "arrays": buffers},
        use_bin_type=True,
    )
    path = os.path.join(directory, f"step_{step:08d}.ckpt")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(zstandard.ZstdCompressor(level=3).compress(payload))
    os.replace(tmp, path)  # atomic rotate
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(str(step))
    return path


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(
    directory: str, step: Optional[int] = None
) -> Tuple[int, Any, Dict]:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.ckpt")
    raw = zstandard.ZstdDecompressor().decompress(
        open(path, "rb").read(), max_output_size=1 << 34
    )
    obj = msgpack.unpackb(raw, raw=False)
    return obj["step"], _decode(obj["tree"], obj["arrays"]), obj["meta"]
