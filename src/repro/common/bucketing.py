"""Canonical power-of-two batch bucketing.

Every jitted hot-path entry point pads its leading batch dimension to the
next power of two so the set of compiled shapes stays logarithmic in the
largest batch ever seen. This module is the ONE place that arithmetic
lives — the `pow2-bucket` lint rule flags hand-rolled copies, and the
retrace detector (`repro.analysis.retrace`) derives its expected-bucket
set from `expected_buckets`, so a drift here would be caught twice.
"""
from __future__ import annotations

from typing import Iterable, List

__all__ = ["pow2_bucket", "pad_amount", "expected_buckets"]


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (bucket for a batch of n; n >= 1 -> >= 1)."""
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()  # repro: noqa[pow2-bucket]


def pad_amount(n: int) -> int:
    """Rows of padding needed to lift a batch of n into its bucket."""
    return pow2_bucket(n) - n


def expected_buckets(batch_sizes: Iterable[int]) -> List[int]:
    """Sorted distinct buckets a sweep over `batch_sizes` may compile."""
    return sorted({pow2_bucket(n) for n in batch_sizes})
