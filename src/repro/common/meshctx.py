"""Version-portable mesh context: one place that knows how to ask JAX
"which mesh is active?" and "make this mesh active".

The mesh-context API has drifted across JAX releases:

  * >= 0.5.x exposes ``jax.sharding.get_abstract_mesh`` / ``jax.set_mesh``
    (earlier spelled ``jax.sharding.use_mesh``) and
    ``jax.make_mesh(..., axis_types=...)`` with ``jax.sharding.AxisType``;
  * 0.4.x keeps the same machinery under ``jax._src.mesh``
    (``get_abstract_mesh``, ``thread_resources``) with activation via the
    classic ``with mesh:`` resource-env context, ``jax.make_mesh`` without
    ``axis_types``, and ``shard_map`` under ``jax.experimental.shard_map``;
  * anything older still accepts a raw ``jax.sharding.Mesh`` context.

Model/serving code must not care. The portability contract is:

  * ``current_mesh()`` returns the active mesh (concrete or abstract) or
    ``None``; never raises, never returns an *empty* mesh.
  * ``use_mesh(mesh)`` is a context manager activating ``mesh`` so that
    (a) ``current_mesh()`` sees it from any thread-locally nested code,
    (b) bare-``PartitionSpec`` sharding constraints resolve inside ``jit``,
    (c) ``shard_map`` collectives can bind its axis names.
  * ``make_mesh(shape, names)`` builds a mesh on every supported version.
  * ``axis_sizes_dict(mesh)`` maps axis name -> size for concrete *and*
    abstract meshes.
  * ``shard_map(...)`` resolves to the native implementation.

Resolution order for ``current_mesh()``:

  1. ``jax.sharding.get_abstract_mesh()`` (new-style sharding-in-types);
  2. ``jax._src.mesh.get_abstract_mesh()`` (0.4.x internal spelling);
  3. ``jax._src.mesh.thread_resources.env.physical_mesh`` (the classic
     ``with mesh:`` resource env — what ``use_mesh`` sets on 0.4.x);
  4. the thread-local registry maintained by ``use_mesh`` itself, which
     works even on a hypothetical JAX with none of the above.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = [
    "current_mesh",
    "use_mesh",
    "make_mesh",
    "axis_sizes_dict",
    "shard_map",
    "cost_analysis_dict",
]

# ---------------------------------------------------------------- resolution

_LOCAL = threading.local()  # .stack: list of meshes activated by use_mesh


def _registry_stack() -> list:
    stack = getattr(_LOCAL, "stack", None)
    if stack is None:
        stack = _LOCAL.stack = []
    return stack


def _nonempty(mesh) -> Optional[Mesh]:
    """Normalize: an empty / axis-less mesh counts as 'no mesh'."""
    if mesh is None:
        return None
    if getattr(mesh, "empty", False):
        return None
    if not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def current_mesh() -> Optional[Mesh]:
    """The active (concrete or abstract) mesh, or None outside any context."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = _nonempty(getter())
        if mesh is not None:
            return mesh
    try:  # 0.4.x internal spelling of the same thing
        from jax._src import mesh as _mesh_src

        getter = getattr(_mesh_src, "get_abstract_mesh", None)
        if getter is not None:
            mesh = _nonempty(getter())
            if mesh is not None:
                return mesh
        tr = getattr(_mesh_src, "thread_resources", None)
        if tr is not None:
            mesh = _nonempty(tr.env.physical_mesh)
            if mesh is not None:
                return mesh
    except Exception:  # pragma: no cover - exotic JAX builds
        pass
    stack = _registry_stack()
    return _nonempty(stack[-1]) if stack else None


# ---------------------------------------------------------------- activation


@contextlib.contextmanager
def use_mesh(mesh: Mesh) -> Iterator[Mesh]:
    """Activate `mesh` for the calling thread (portable jax.set_mesh).

    Prefers the newest native activation available so jit/GSPMD resolve
    bare PartitionSpecs, then falls back to the classic ``with mesh:``
    resource env, and always mirrors into the thread-local registry so
    ``current_mesh()`` works regardless of JAX version.
    """
    stack = _registry_stack()
    stack.append(mesh)
    try:
        setter = getattr(jax, "set_mesh", None) or getattr(
            jax.sharding, "use_mesh", None
        )
        if setter is not None:
            with setter(mesh):
                yield mesh
        elif isinstance(mesh, Mesh):
            with mesh:  # classic resource-env context (<= 0.4.x)
                yield mesh
        else:  # abstract mesh on a JAX without a native setter
            yield mesh
    finally:
        stack.pop()


# -------------------------------------------------------------- construction


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    explicit: bool = False,
) -> Mesh:
    """``jax.make_mesh`` across versions (``axis_types`` appeared later).

    `explicit=True` asks for sharding-in-types Explicit axes where the
    running JAX supports them; otherwise Auto/classic semantics apply.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    factory = getattr(jax, "make_mesh", None)
    if factory is not None and axis_type is not None:
        kind = axis_type.Explicit if explicit else axis_type.Auto
        try:
            return factory(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(kind,) * len(tuple(axis_names)),
            )
        except TypeError:  # axis_types kwarg not in this signature
            pass
    if factory is not None:
        return factory(tuple(axis_shapes), tuple(axis_names))
    devices = np.array(jax.devices()[: int(np.prod(axis_shapes))]).reshape(
        tuple(axis_shapes)
    )
    return Mesh(devices, tuple(axis_names))


# ------------------------------------------------------------------- queries


def axis_sizes_dict(mesh) -> dict:
    """{axis name: size} for concrete Mesh and AbstractMesh alike."""
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None and not callable(sizes):
        return dict(zip(mesh.axis_names, sizes))
    shape = getattr(mesh, "shape", None)
    if shape is not None:
        return dict(shape)
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` normalized across JAX versions.

    0.4.x returns a one-dict-per-program list; newer releases return the
    dict directly (and may return None when analysis is unavailable).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


# ------------------------------------------------------------------ shard_map

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # <= 0.4.x: experimental namespace, same semantics
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f=None, /, *, mesh, in_specs, out_specs, **kw):
        if f is None:
            return lambda g: _sm(g, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
