"""Logical-axis sharding rules (MaxText-style) for the backend pools.

Params and activations are annotated with *logical* axes; `spec_for` resolves
them against whatever mesh is active (single-pod ("data","model") or
multi-pod ("pod","data","model")), so the same model code lowers on both.
The active mesh is discovered through `repro.common.meshctx` — the
JAX-version-portability layer — so these helpers behave identically across
JAX releases with different mesh-context APIs.

Rules (DESIGN.md §6):
  batch    -> ("pod", "data")   data parallel
  embed    -> ("data",)         FSDP: shard the d_model dim of weights
  heads    -> ("model",)        tensor parallel attention
  kv_heads -> ("model",)
  ff       -> ("model",)        tensor parallel MLP
  experts  -> ("model",)        expert parallel MoE
  vocab    -> ("model",)        sharded logits/embedding table
  ssm_heads-> ("model",)        sharded SSD heads
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import meshctx

__all__ = [
    "RULES",
    "POLICIES",
    "set_policy",
    "get_policy",
    "spec_for",
    "named_sharding",
    "logical_constraint",
]

_COMMON: dict[str, Tuple[str, ...]] = {
    "seq": (),
    "layers": (),
    "stack": (),
    "capacity": (),
    "state": (),
    "conv": (),
    "image": (),
    "codebooks": (),
    "act_seq": (),  # sequence dim of the residual stream (SP shards it)
    "kv_seq": (),  # sequence dim of the decode KV cache
    None: (),
}

# Sharding policies (the §Perf hillclimb lever — DESIGN.md §6):
#   tp      baseline: Megatron TP on heads/ff/experts + FSDP on d_model
#   tp_sp   + sequence-parallel residual stream (all-reduce -> RS+AG)
#   tp_kvs  + decode KV cache sharded over "model" on the SEQ dim (for archs
#           whose kv_heads don't divide the model axis and would replicate)
#   fsdp    ZeRO-3 only: batch over every axis, weights sharded on d_model,
#           no tensor parallelism (small models: kills the TP all-reduces)
#   tp_serve[_kvs]  decode/serving: weights resident (no FSDP gathers/token)
POLICIES: dict[str, dict] = {
    "tp": {
        **_COMMON,
        "batch": ("pod", "data"),
        "embed": ("data",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "ssm_heads": ("model",),
        # uneven activation sharding (GSPMD pads) is an opt-in (§Perf): it
        # shards batched attention for head counts like 56/25/24, but HURTS
        # single-token decode against replicated caches (measured: musicgen
        # decode collective 4.6 -> 292 ms when applied blindly)
        "_relax_uneven": False,
    },
}
POLICIES["tp_relaxed"] = {**POLICIES["tp"], "_relax_uneven": True}
POLICIES["tp_sp"] = {**POLICIES["tp"], "act_seq": ("model",)}
# serving: weights resident (TP-sharded only, NO data-axis FSDP) — decode
# must not all-gather the weight shards every token
POLICIES["tp_serve"] = {**POLICIES["tp"], "embed": ()}
POLICIES["tp_serve_kvs"] = {**POLICIES["tp_serve"], "kv_seq": ("model",)}
POLICIES["tp_kvs"] = {**POLICIES["tp"], "kv_seq": ("model",)}
POLICIES["fsdp"] = {
    **_COMMON,
    "batch": ("pod", "data", "model"),
    "embed": ("data", "model"),
    "heads": (),
    "kv_heads": (),
    "ff": (),
    "experts": (),
    "vocab": (),
    "ssm_heads": (),
}

RULES: dict[str, Tuple[str, ...]] = POLICIES["tp"]  # active policy (mutable)
_ACTIVE = "tp"


class set_policy:
    """Context manager / setter switching the active sharding policy."""

    def __init__(self, name: str):
        global RULES, _ACTIVE
        if name not in POLICIES:
            raise KeyError(f"unknown sharding policy {name!r}; have {sorted(POLICIES)}")
        self._prev = _ACTIVE
        RULES = POLICIES[name]
        _ACTIVE = name

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        global RULES, _ACTIVE
        RULES = POLICIES[self._prev]
        _ACTIVE = self._prev
        return False


def get_policy() -> str:
    return _ACTIVE


def spec_for(
    axes: Sequence[Optional[str]],
    mesh_axis_names: Sequence[str],
    shape: Optional[Sequence[int]] = None,
    mesh_axis_sizes: Optional[dict] = None,
    relax_uneven: bool = False,
) -> P:
    """Resolve logical axes -> PartitionSpec for the given mesh.

    When `shape` and `mesh_axis_sizes` are given, a mesh axis is dropped
    (dimension replicated) if the dimension is not divisible by it — e.g.
    kv_heads=8 cannot shard 16-way, so the KV projection replicates over
    "model" while the q projection still shards. This keeps every assigned
    architecture lowerable on the fixed production mesh without per-arch
    sharding tables.
    """
    parts = []
    used: set[str] = set()
    for i, ax in enumerate(axes):
        mesh_axes = []
        dim = shape[i] if shape is not None else None
        for a in RULES.get(ax, ()):
            if a not in mesh_axis_names or a in used:
                continue
            if dim is not None and mesh_axis_sizes is not None:
                size = mesh_axis_sizes[a]
                divisor = size * int(np.prod([mesh_axis_sizes[m] for m in mesh_axes]) if mesh_axes else 1)
                if dim % divisor != 0:
                    # activations may shard unevenly (GSPMD pads, waste <=2x)
                    # as long as every shard gets at least one row; params and
                    # inputs stay strictly divisible (jit requirement)
                    if not (relax_uneven and dim >= divisor):
                        continue
            mesh_axes.append(a)
        used.update(mesh_axes)
        if not mesh_axes:
            parts.append(None)
        elif len(mesh_axes) == 1:
            parts.append(mesh_axes[0])
        else:
            parts.append(tuple(mesh_axes))
    return P(*parts)


def named_sharding(
    mesh: Mesh, axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None
) -> NamedSharding:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return NamedSharding(mesh, spec_for(axes, mesh.axis_names, shape, sizes))


def logical_constraint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """`with_sharding_constraint` by logical axes; no-op outside a mesh ctx.

    Mesh discovery goes through `repro.common.meshctx.current_mesh` (the
    version-portability layer) — activate a mesh with `meshctx.use_mesh`.
    """
    mesh = meshctx.current_mesh()
    if mesh is None:
        return x
    sizes = meshctx.axis_sizes_dict(mesh)
    return jax.lax.with_sharding_constraint(
        x,
        spec_for(
            axes, mesh.axis_names, x.shape, sizes,
            relax_uneven=bool(RULES.get("_relax_uneven", False)),
        ),
    )
