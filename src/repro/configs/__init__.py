"""Assigned-architecture registry: --arch <id> resolves here.

Every config cites its public source; reduced smoke variants come from
`repro.models.config.reduced`. The paper's own router configs live in
`router_paper.py`.
"""
from repro.configs.stablelm_3b import CONFIG as STABLELM_3B
from repro.configs.llama_3_2_vision_90b import CONFIG as LLAMA_32_VISION_90B
from repro.configs.mamba2_2_7b import CONFIG as MAMBA2_27B
from repro.configs.command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.granite_3_8b import CONFIG as GRANITE_3_8B
from repro.configs.hymba_1_5b import CONFIG as HYMBA_15B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.qwen2_5_3b import CONFIG as QWEN25_3B

ARCHITECTURES = {
    c.name: c
    for c in [
        STABLELM_3B,
        LLAMA_32_VISION_90B,
        MAMBA2_27B,
        COMMAND_R_PLUS_104B,
        ARCTIC_480B,
        GRANITE_3_8B,
        HYMBA_15B,
        MUSICGEN_MEDIUM,
        DBRX_132B,
        QWEN25_3B,
    ]
}


def get_config(name: str):
    if name not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
        )
    return ARCHITECTURES[name]
