"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual MLP.
Source: [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,  # dense residual branch
    vocab_size=32000,
    n_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,  # arctic: dense MLP in parallel with the MoE
)
