"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no biases. Source: [hf:CohereForAI/c4ai-command-r-v01]
scaled per the assignment table."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    qkv_bias=False,  # no-bias per model card
    rope_theta=75000000.0,
)
