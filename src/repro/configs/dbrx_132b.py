"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) expert d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).
Source: [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_token=4,
    moe_d_ff=10752,
    rope_theta=500000.0,
)
