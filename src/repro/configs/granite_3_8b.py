"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. Source: [hf:ibm-granite/granite-3.0-2b-base] scaled per the
assignment table."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    arch_type="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    tie_embeddings=True,  # granite ties embeddings
)
