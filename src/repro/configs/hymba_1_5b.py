"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + Mamba heads in every layer
per [arXiv:2411.13676]. Hymba uses sliding-window attention in most layers;
we window all attention heads (1024) — the SSM path carries global context."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="dense",
    hybrid=True,
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,  # d_inner=3200 => 50 SSD heads
    ssm_chunk=256,
    sliding_window=1024,
    tie_embeddings=True,
)
