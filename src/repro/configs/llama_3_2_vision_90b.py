"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, gated cross-attention image layers every 5th layer.
Source: [hf:meta-llama/Llama-3.2-11B-Vision] scaled per the assignment table.
The vision tower (ViT + projector) is stubbed: `input_specs` provides
precomputed patch embeddings [B, n_image_tokens, d_model] (DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100,  # 80 self-attention + 20 cross-attention (every 5th)
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    n_image_tokens=1600,  # ~1601 patches per image tile; rounded for tiling
    rope_theta=500000.0,
)
