"""mamba2-2.7b [ssm] — 64L d_model=2560, attention-free, vocab=50280,
ssm_state=128. SSD (state-space duality) per [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,  # no FFN: the Mamba-2 block is the layer
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,  # d_inner=5120 => 80 SSD heads
    ssm_chunk=256,
    ssm_n_groups=1,
    tie_embeddings=True,
)
