"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 over EnCodec tokens, 4 codebooks summed at the input and predicted
by 4 parallel heads. Source: [arXiv:2306.05284]. The EnCodec frontend
(mel/conv codec) is stubbed: tokens arrive as [B, S, 4] codebook ids
(DESIGN.md §5); the delay-pattern interleaver is part of the stubbed codec."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
)
