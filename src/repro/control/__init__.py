"""Online refinement control plane (paper §7.2 as a running subsystem).

Closes the outcome -> refine -> validate -> swap loop against the live
router, with no changes to the serving path:

  * `OutcomeStore` — bounded, thread-safe event store routers drain into;
    builds the dense masks Alg. 1 consumes; persists via repro.checkpoint.
  * `RefinementController` — step-driven (or daemon-thread) loop:
    trigger -> density gate -> refine_with_gate -> atomic swap.
  * `TableGuard` — post-swap shadow monitoring on labelled traffic;
    auto-rolls-back a regressing table through the ToolsDatabase version
    history.

The learned stages (adapter/re-ranker) are owned by the sibling learning
plane (`repro.learn`), which consumes this package's OutcomeStore window
and the `recommend_stages` density plan recorded on controller reports.
"""
from repro.control.controller import (
    ControllerConfig,
    ControllerReport,
    RefinementController,
)
from repro.control.guard import GuardConfig, GuardReport, TableGuard
from repro.control.outcome_store import OutcomeStore, RefinementBatch

__all__ = [
    "ControllerConfig",
    "ControllerReport",
    "RefinementController",
    "GuardConfig",
    "GuardReport",
    "TableGuard",
    "OutcomeStore",
    "RefinementBatch",
]
