"""RefinementController: the loop that closes §7.2 against the live router.

One `step()` = one pass of the paper's operational loop:

    drain routers -> guard check -> trigger? -> density gate ->
    build masks from the event window -> refine_with_gate on a held-out
    validation slice -> accepted? atomic swap_table -> register with guard

Step-driven so tests (and cron-style deployments) control the cadence
exactly; `start(interval_s)` wraps the same `step()` in a daemon thread for
serving processes that want the loop in-process beside the gateway. Serving
traffic continues throughout: `swap_table` is atomic w.r.t.
`ToolsDatabase.snapshot()`, so in-flight `route_batch` calls finish on the
table they started with and the next batch picks up the new version.

Triggering is `core.deployment.refine_trigger` (event-count OR staleness).
Each triggered step also computes `core.deployment.recommend_stages` over
the store's live per-tool counters and records the plan on its report:
refinement itself is always-on in that policy (zero serving cost,
gate-protected, §7.2), while the plan's density thresholds gate training of
the learned stages (rerank/adapter) — acted on by the learning plane
(`repro.learn.LearningController`), which runs beside this controller over
the same OutcomeStore and deploys gated StageSets to the router. This
controller itself never trains serving-path models mid-flight.

The validation slice is a deterministic per-refinement split of the *unique
queries* in the window (not of raw events: a query's K outcome events must
land on one side of the split, or the gate validates on its own train set).

Index layer (PR 3): when routers serve through a non-dense
`repro.index.ToolIndexManager`, every swap/rollback this loop performs
invalidates the built index. The managers' own `ToolsDatabase` swap
listeners kick the async rebuild the moment the table moves; the controller
additionally refreshes any managers passed via `indexes=` at the end of
each step and records `ControllerReport.index_fresh`, so operators can see
fallback-serving windows (exact dense scoring while a rebuild is in
flight) in the step log.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.deployment import DeploymentPlan, recommend_stages, refine_trigger
from repro.core.refine import RefineConfig, refine_with_gate
from repro.control.guard import GuardReport, TableGuard
from repro.control.outcome_store import OutcomeStore
from repro.obs import clock as obs_clock
from repro.router.tooldb import ConflictError, ToolsDatabase

__all__ = ["ControllerConfig", "ControllerReport", "RefinementController"]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    min_events: int = 256  # event-count trigger (refine_trigger)
    max_interval_s: float = 300.0  # staleness trigger (refine_trigger)
    val_fraction: float = 0.15  # held-out slice of unique queries
    min_queries: int = 20  # don't refine off a handful of queries
    # keep_history=False: the controller re-refines the same large table over
    # and over; the [N+1, T, D] convergence buffer is pure overhead here.
    # gate_metric="ndcg": with streamed-outcome relevance every logged
    # positive was in the serving top-K by construction, so Recall@K starts
    # at its 1.0 ceiling and could only tie or reject; NDCG still measures
    # rank improvement within the top-K.
    refine: RefineConfig = RefineConfig(keep_history=False, gate_metric="ndcg")
    seed: int = 0


@dataclasses.dataclass
class ControllerReport:
    """What one `step()` did, for logs/tests/benchmarks."""

    triggered: bool
    reason: str
    n_events: int = 0  # events in the store window at step time
    n_new_events: int = 0  # ingested since the last refinement
    n_queries: int = 0  # unique queries folded into the masks
    plan: Optional[DeploymentPlan] = None
    accepted: Optional[bool] = None
    recall_before: Optional[float] = None
    recall_after: Optional[float] = None
    swapped: bool = False
    table_version: int = -1  # live version when the step finished
    guard: Optional[GuardReport] = None
    # index-layer freshness at step end (None when no managers attached):
    # False means a swap/rollback this step left at least one ToolIndexManager
    # rebuilding, i.e. its router is serving the exact dense fallback
    index_fresh: Optional[bool] = None


class RefinementController:
    def __init__(
        self,
        db: ToolsDatabase,
        store: OutcomeStore,
        embed_batch_fn: Callable[[Sequence[np.ndarray]], np.ndarray],
        routers: Sequence = (),
        config: ControllerConfig = ControllerConfig(),
        guard: Optional[TableGuard] = None,
        clock: Callable[[], float] = obs_clock.monotonic,
        refine_fn: Callable = refine_with_gate,  # injectable for tests
        indexes: Sequence = (),  # ToolIndexManagers to keep fresh across swaps
        bus: Optional["EventBus"] = None,  # repro.obs.events lifecycle surface
        flight_recorder=None,  # repro.obs.flightrec — daemon crash dumps
    ):
        self.db = db
        self.store = store
        self.embed_batch_fn = embed_batch_fn
        self.routers = list(routers)
        self.config = config
        self.guard = guard
        # rebuild-on-swap: managers already watch the db through their swap
        # listener; the controller's job is (a) belt-and-braces refresh after
        # its own swaps/rollbacks and (b) reporting fallback-serving windows
        self.indexes = list(indexes)
        self.clock = clock
        self.refine_fn = refine_fn
        # lifecycle events (cooldown, gate_reject, loop_error transitions) go
        # to the bus; successful swaps reach it via `EventBus.watch_db`
        self.bus = bus
        # black-box hook: a daemon-step crash dumps the full telemetry state
        # (works without a bus; the recorder's debounce dedupes against the
        # loop_error event when both paths are wired)
        self.flight_recorder = flight_recorder
        self.reports: List[ControllerReport] = []
        # the daemon loop's health surface: the most recent step() exception,
        # cleared by the next successful step — a dashboard/health check polls
        # this (a failing control plane is otherwise invisible: the thread
        # survives and reports are easy to miss)
        self.last_loop_error: Optional[BaseException] = None
        self.n_refinements = 0
        self._seen_events = store.total_ingested  # trigger watermark
        self._last_refine_t = clock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------ step
    def step(self) -> ControllerReport:
        for router in self.routers:
            self.store.drain_router(router)
        guard_report = self.guard.check() if self.guard is not None else None
        if guard_report is not None and guard_report.action == "rolled_back":
            # cooldown: the window is dominated by outcomes the condemned
            # table generated — refining from it (now or at the next
            # trigger) would rebuild and re-swap essentially the same bad
            # table in a flap loop. Purge the window and consume the
            # trigger watermark: refinement restarts from fresh evidence
            # served by the restored table.
            n_purged = self.store.clear()
            self._seen_events = self.store.total_ingested
            self._last_refine_t = self.clock()
            report = ControllerReport(
                triggered=False,
                reason=(
                    f"cooldown after guard rollback "
                    f"({n_purged} condemned-era events purged)"
                ),
            )
            if self.bus is not None:
                self.bus.publish("cooldown", plane="control", purged=n_purged)
        else:
            report = self._refine_step()
        report.guard = guard_report
        report.table_version = self.db.table_version
        if self.indexes:
            for manager in self.indexes:
                # honor each manager's build mode: a synchronous manager
                # (async_rebuild=False, the deterministic/test mode) must be
                # fresh when the step returns; async managers get a no-op
                # poke when already fresh/building
                manager.refresh(block=not getattr(manager, "async_rebuild", True))
            report.index_fresh = all(m.is_fresh() for m in self.indexes)
        self.reports.append(report)
        return report

    def _refine_step(self) -> ControllerReport:
        cfg = self.config
        n_new = self.store.total_ingested - self._seen_events
        elapsed = self.clock() - self._last_refine_t
        if not refine_trigger(n_new, elapsed, cfg.min_events, cfg.max_interval_s):
            return ControllerReport(
                triggered=False,
                reason=f"below trigger ({n_new} new events, {elapsed:.1f}s elapsed)",
                n_events=len(self.store),
                n_new_events=n_new,
            )
        batch = self.store.build_refinement_batch(self.embed_batch_fn)
        # triggering consumes the watermark whatever happens next — a window
        # too sparse to refine should not re-trigger every step until traffic
        # doubles it, just fold into the next trigger cycle
        self._seen_events = self.store.total_ingested
        self._last_refine_t = self.clock()
        pos_counts, neg_counts = self.store.tool_counts()
        n_examples = int(pos_counts.sum() + neg_counts.sum())
        # §7.2/§7.3 stage plan over the live counters. Refinement itself is
        # always-on in that policy (zero serving cost, gate-protected), so
        # the plan doesn't veto this step; it is recorded on the report, and
        # the same policy gates learned-stage training in the learning plane
        # (repro.learn reads these thresholds over the same counters).
        plan = recommend_stages(len(self.db), n_examples)
        base = ControllerReport(
            triggered=True,
            reason="",
            n_events=batch.n_events,
            n_new_events=n_new,
            n_queries=batch.n_queries,
            plan=plan,
        )
        if batch.n_queries < cfg.min_queries:
            base.reason = (
                f"too few unique queries ({batch.n_queries} < {cfg.min_queries})"
            )
            return base
        # deterministic held-out slice, reseeded per refinement so repeated
        # runs on an evolving window rotate the slice. The val slice is
        # drawn ONLY from queries with >= 1 logged success: all-zero
        # relevance rows are excluded from batched_recall_at_k, so a val
        # slice of failure-only queries would make the gate vacuous
        # (0 >= 0 accepts with zero validation signal)
        pos_rows = np.flatnonzero(batch.pos_mask.sum(axis=1) > 0)
        n_val = max(int(round(cfg.val_fraction * len(pos_rows))), 2)
        if len(pos_rows) < 2 * n_val:
            base.reason = (
                f"too few positive queries for a held-out gate "
                f"({len(pos_rows)} with successes, need >= {2 * n_val})"
            )
            return base
        rng = np.random.default_rng(cfg.seed + self.n_refinements)
        val_idx = rng.permutation(pos_rows)[:n_val]
        train_idx = np.setdiff1d(np.arange(batch.n_queries), val_idx)
        version_before, table = self.db.snapshot()
        result = self.refine_fn(
            jnp.asarray(table),
            jnp.asarray(batch.query_emb[train_idx]),
            jnp.asarray(batch.pos_mask[train_idx]),
            jnp.asarray(batch.query_emb[val_idx]),
            jnp.asarray(batch.pos_mask[val_idx]),
            cfg.refine,
        )
        self.n_refinements += 1
        accepted = bool(result.accepted)
        base.accepted = accepted
        base.recall_before = float(result.recall_before)
        base.recall_after = float(result.recall_after)
        metric = f"{cfg.refine.gate_metric}@{cfg.refine.k}"
        if not accepted:
            base.reason = f"gate rejected: held-out {metric} did not improve"
            if self.bus is not None:
                self.bus.publish("gate_reject", plane="control",
                                 reason=base.reason)
            return base
        try:
            # compare-and-swap: this table was refined FROM version_before;
            # if another deployment landed mid-refinement, stand down rather
            # than clobber a table the gate never saw
            new_version = self.db.swap_table(
                np.asarray(result.embeddings), expect_current=version_before
            )
        except ConflictError as exc:
            base.reason = f"swap refused: {exc}"
            return base
        if self.guard is not None:
            self.guard.note_swap(version_before, new_version)
        base.swapped = True
        base.reason = (
            f"swapped v{version_before} -> v{new_version} "
            f"(val {metric} {base.recall_before:.3f} -> "
            f"{base.recall_after:.3f})"
        )
        return base

    # ---------------------------------------------------------------- daemon
    def start(self, interval_s: float = 1.0) -> None:
        """Run `step()` on a daemon thread every `interval_s` seconds.

        A failing step is recorded in `self.reports` (reason
        "step failed: ...") AND in `self.last_loop_error` (cleared by the
        next successful step) so a health check can see the failure without
        scanning reports; the loop continues — a transient encoder or
        refinement error must not silently kill the control plane for the
        rest of the serving process's lifetime.
        """
        assert self._thread is None, "controller already running"
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.step()
                    if self.last_loop_error is not None and self.bus is not None:
                        # transition back to healthy, not one event per step
                        self.bus.publish("loop_recovered", plane="control",
                                         controller=type(self).__name__)
                    self.last_loop_error = None
                except Exception as exc:  # survive transient failures
                    if self.last_loop_error is None:
                        # crash dump FIRST (reason "crash", full exception),
                        # so the loop_error publish below debounces into it
                        # rather than racing it for the dump slot
                        if self.flight_recorder is not None:
                            try:
                                self.flight_recorder.record_crash(
                                    exc, source=type(self).__name__
                                )
                            except Exception:  # noqa: BLE001 — never rethrow
                                pass  # the black box must not kill the loop
                        if self.bus is not None:
                            self.bus.publish("loop_error", plane="control",
                                             controller=type(self).__name__,
                                             error=repr(exc))
                    self.last_loop_error = exc
                    self.reports.append(
                        ControllerReport(
                            triggered=False,
                            reason=f"step failed: {exc!r}",
                            table_version=self.db.table_version,
                        )
                    )

        self._thread = threading.Thread(
            target=loop, name="refinement-controller", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
