"""TableGuard: post-swap shadow monitoring + automatic rollback.

The validation gate (`refine_with_gate`) protects a swap *before* deployment
on a held-out slice; the guard protects it *after*, on live labelled
traffic, against the failure modes the gate cannot see (distribution shift
between the validation slice and real traffic, a bad table deployed by an
out-of-band job that bypassed the gate). Serving code reports each labelled
result via `observe(...)`; the guard keeps a rolling NDCG@k / Recall@k
window per table version, and `check()` (run by the controller every step,
or callable directly) compares the live version's rolling NDCG against the
baseline frozen from its predecessor at swap time. A regression beyond
`tolerance`, judged only after `min_samples` observations, triggers
`ToolsDatabase.rollback()` to the most recent retained version — the table
that was serving before the condemned swap.

The restored table comes back under a NEW version number (rollback is
itself a swap), with a fresh observation window and no baseline — the
restored table *is* the baseline, so a rollback can never cascade into
flapping.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional

from repro.metrics.retrieval import ndcg_at_k, recall_at_k
from repro.obs.quality import RollingWindows
from repro.router.tooldb import ConflictError, ToolsDatabase

__all__ = ["GuardConfig", "GuardReport", "TableGuard"]


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    k: int = 5  # NDCG@k / Recall@k cutoff
    window: int = 256  # rolling observations kept per table version
    min_samples: int = 32  # judge a version only after this many labels
    tolerance: float = 0.02  # allowed NDCG drop vs the frozen baseline


@dataclasses.dataclass
class GuardReport:
    # "healthy" | "insufficient_data" | "no_baseline" | "stale" |
    # "regressed_unrestorable" | "rolled_back"
    action: str
    table_version: int  # version under judgement when check() ran
    ndcg: Optional[float] = None  # rolling NDCG@k of that version
    baseline: Optional[float] = None  # frozen predecessor NDCG@k
    n_samples: int = 0
    restored_version: Optional[int] = None  # new version after a rollback


class TableGuard:
    """Rolling per-version retrieval quality monitor over labelled traffic."""

    def __init__(
        self,
        db: ToolsDatabase,
        config: GuardConfig = GuardConfig(),
        bus: Optional["EventBus"] = None,  # repro.obs.events
    ):
        self.db = db
        self.config = config
        # per-version rolling windows (repro.obs.quality's shared machinery,
        # accessed only under self._lock — RollingWindows is not locked)
        self._ndcg = RollingWindows(config.window)
        self._recall = RollingWindows(config.window)
        self._baseline: Dict[int, Optional[float]] = {}  # frozen at swap time
        self._last_version = db.table_version
        self._lock = threading.Lock()
        self.rollbacks: List[GuardReport] = []
        self.bus = bus

    # ------------------------------------------------------------- observing
    def observe(
        self,
        table_version: int,
        ranked_tools: Iterable[int],
        relevant: Iterable[int],
    ) -> None:
        """Record one labelled result against the version that served it.

        `ranked_tools` is `RouteResult.tools` (use `RouteResult.table_version`
        — NOT `db.table_version`, which may have moved since the batch was
        scored); `relevant` is the ground-truth tool set once the label
        arrives (§4.1's o_j, minutes-to-hours after serving).
        """
        ranked = list(ranked_tools)
        rel = list(relevant)
        nd = ndcg_at_k(ranked, rel, self.config.k)
        rc = recall_at_k(ranked, rel, self.config.k)
        with self._lock:
            self._ndcg.push(table_version, nd)
            self._recall.push(table_version, rc)

    def note_swap(self, old_version: int, new_version: int) -> None:
        """Freeze the outgoing version's rolling NDCG as the incoming
        version's baseline (the controller calls this right after a swap).
        An old version without enough samples yields no baseline — the guard
        then has nothing to compare against and will not judge the swap."""
        with self._lock:
            self._baseline[new_version] = (
                self._ndcg.mean(old_version)
                if self._ndcg.n(old_version) >= self.config.min_samples
                else None
            )
            self._last_version = new_version

    def version_stats(self, table_version: int) -> dict:
        with self._lock:
            return {
                "n": self._ndcg.n(table_version),
                "ndcg": self._ndcg.mean(table_version),
                "recall": self._recall.mean(table_version),
                "baseline": self._baseline.get(table_version),
            }

    # -------------------------------------------------------------- judging
    def check(self) -> GuardReport:
        """Judge the live table; roll back if it regressed past tolerance."""
        with self._lock:
            version = self.db.table_version
            if version != self._last_version and version not in self._baseline:
                # unannounced swap (an out-of-band job that bypassed the
                # controller — the very case shadow monitoring exists for):
                # freeze the displaced version's rolling NDCG as baseline
                self._baseline[version] = (
                    self._ndcg.mean(self._last_version)
                    if self._ndcg.n(self._last_version) >= self.config.min_samples
                    else None
                )
            self._last_version = version
            # prune dead versions: anything no longer live nor retained can
            # never be judged or restored again, and a long-running daemon
            # under table churn would otherwise grow these windows forever
            alive = set(self.db.retained_versions())
            alive.add(version)
            self._ndcg.prune(alive)
            self._recall.prune(alive)
            for v in [v for v in self._baseline if v not in alive]:
                del self._baseline[v]
            n = self._ndcg.n(version)
            if n < self.config.min_samples:
                return GuardReport("insufficient_data", version, n_samples=n)
            ndcg = self._ndcg.mean(version)
            baseline = self._baseline.get(version)
            if baseline is None:
                return GuardReport("no_baseline", version, ndcg=ndcg, n_samples=n)
            if ndcg + self.config.tolerance >= baseline:
                return GuardReport(
                    "healthy", version, ndcg=ndcg, baseline=baseline, n_samples=n
                )
            if not self.db.retained_versions():
                # regression confirmed but no retained table to restore —
                # a distinct, alertable state (do NOT conflate with the
                # can't-judge "no_baseline" case)
                return GuardReport(
                    "regressed_unrestorable", version,
                    ndcg=ndcg, baseline=baseline, n_samples=n,
                )
        # rollback runs OUTSIDE the guard lock: it is itself a swap, and the
        # database fires swap listeners whose index rebuilds may upload to
        # device — holding _lock across that would stall every observe() for
        # the duration and nests the guard lock around device dispatch. The
        # compare-and-swap below still makes the judgement safe: if anything
        # (another guard thread, a deploy) moved the table after we released
        # the lock, expect_current refuses the rollback.
        try:
            restored = self.db.rollback(expect_current=version)
        except ConflictError:
            # the condemned table is no longer live; judge the new one
            # on its own evidence next check
            return GuardReport("stale", version, ndcg=ndcg, n_samples=n)
        with self._lock:
            # the restored table IS the new baseline: no judgement, no flap
            self._baseline[restored] = None
            self._last_version = restored
            report = GuardReport(
                "rolled_back",
                version,
                ndcg=ndcg,
                baseline=baseline,
                n_samples=n,
                restored_version=restored,
            )
            self.rollbacks.append(report)
        if self.bus is not None:  # outside the lock, like the rollback itself
            self.bus.publish(
                "rollback", plane="control",
                condemned_version=version, restored_version=restored,
                ndcg=ndcg, baseline=baseline,
            )
        return report
