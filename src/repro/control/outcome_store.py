"""OutcomeStore: the control plane's bounded, thread-safe outcome event store.

The ingestion side of §7.2's loop ("read outcome logs"): routers push
`OutcomeEvent`s — either directly (`router = SemanticRouter(...,
outcome_sink=store.append)`) or via periodic drains
(`store.drain_router(router)`, which the `RefinementController` does every
step). Events live in a ring buffer bounded at `capacity`; when full, the
oldest events are overwritten (and counted in `dropped`) — the store keeps
the freshest evidence window, which is exactly what repeated refinement
wants, and a stalled controller can never OOM the serving process.

Per-tool positive/negative counters are maintained incrementally (including
decrement-on-eviction), so data-density gating (`core.deployment`) reads
them in O(1) without scanning the ring.

`build_refinement_batch` turns the ring into the dense inputs
`refine_embeddings` consumes: queries are deduplicated by token content, the
unique queries are embedded through the shared encoder in ONE batched call,
and `core.outcomes.masks_from_stream` builds the [Q, T] pos/neg masks.

Persistence: `save`/`restore` round-trip the ring through
`repro.checkpoint` (msgpack + compression), padding the ragged query-token
arrays into one [E, L] matrix + length vector, so the outcome window
survives controller restarts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import deque
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.outcomes import masks_from_stream
from repro.router.gateway import OutcomeEvent

__all__ = ["RefinementBatch", "OutcomeStore"]


@dataclasses.dataclass
class RefinementBatch:
    """Dense refinement inputs built from the current event window."""

    query_tokens: List[np.ndarray]  # [Q] deduplicated query token arrays
    query_emb: np.ndarray  # [Q, D] batched-encoded unique queries
    pos_mask: np.ndarray  # [Q, T] observed successes (= relevance labels)
    neg_mask: np.ndarray  # [Q, T] observed failures (pos vetoes neg)
    n_events: int  # events folded into the masks
    # fingerprint of the EXACT window snapshot these inputs were built from,
    # taken under the same lock acquisition — an append racing the build
    # cannot desynchronize the two (the learning plane stamps artifacts
    # with it for attributability)
    fingerprint: str = ""

    @property
    def n_queries(self) -> int:
        return len(self.query_tokens)


def _query_key(tokens: np.ndarray) -> Tuple[int, bytes]:
    t = np.asarray(tokens)
    return (t.size, t.tobytes())


class OutcomeStore:
    """Thread-safe bounded ring of OutcomeEvents with per-tool counters."""

    def __init__(self, n_tools: int, capacity: int = 100_000):
        assert capacity >= 1
        self.n_tools = int(n_tools)
        self.capacity = int(capacity)
        self._events: Deque[OutcomeEvent] = deque()
        self._pos_counts = np.zeros(self.n_tools, dtype=np.int64)
        self._neg_counts = np.zeros(self.n_tools, dtype=np.int64)
        self.total_ingested = 0  # monotone; the controller's trigger watermark
        self.dropped = 0  # ring overwrites
        self._lock = threading.Lock()

    # ------------------------------------------------------------- ingestion
    def append(self, event: OutcomeEvent) -> None:
        """Ingest one event (the router's `outcome_sink` target)."""
        with self._lock:
            self._append_locked(event)

    def ingest(self, events: Iterable[OutcomeEvent]) -> int:
        """Ingest a drained batch; returns the number of events added."""
        n = 0
        with self._lock:
            for ev in events:
                self._append_locked(ev)
                n += 1
        return n

    def drain_router(self, router) -> int:
        """Pull a router's accumulated outcome log into the store."""
        return self.ingest(router.drain_outcomes())

    def clear(self) -> int:
        """Drop the whole event window (returns how many were dropped).

        Used by the controller after a guard rollback: the window is
        dominated by outcomes the condemned table generated and cannot be
        attributed per-version, so refinement must rebuild its evidence from
        fresh traffic. `total_ingested` stays monotone (it is a trigger
        watermark, not a window size)."""
        with self._lock:
            n = len(self._events)
            self._events.clear()
            self._pos_counts[:] = 0
            self._neg_counts[:] = 0
            return n

    def _append_locked(self, event: OutcomeEvent) -> None:
        if len(self._events) >= self.capacity:
            old = self._events.popleft()
            self._count(old, -1)
            self.dropped += 1
        self._events.append(event)
        self._count(event, +1)
        self.total_ingested += 1

    def _count(self, event: OutcomeEvent, delta: int) -> None:
        if event.outcome:
            self._pos_counts[event.tool_id] += delta
        else:
            self._neg_counts[event.tool_id] += delta

    # -------------------------------------------------------------- reading
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def tool_counts(self) -> Tuple[np.ndarray, np.ndarray]:
        """([T] positive, [T] negative) event counts over the current window."""
        with self._lock:
            return self._pos_counts.copy(), self._neg_counts.copy()

    def snapshot_events(self) -> List[OutcomeEvent]:
        """Consistent copy of the current window (events stay in the ring)."""
        with self._lock:
            return list(self._events)

    def _fingerprint_locked(self) -> str:
        h = hashlib.sha1()
        h.update(np.int64(self.total_ingested).tobytes())
        h.update(np.int64(len(self._events)).tobytes())
        h.update(self._pos_counts.tobytes())
        h.update(self._neg_counts.tobytes())
        return h.hexdigest()[:16]

    def window_fingerprint(self) -> str:
        """Content hash of the current evidence window.

        The learning plane stamps every trained artifact with this (plus the
        table version), so a deployed StageSet is attributable to the exact
        window it was trained from. Built from the watermark + window size +
        per-tool counters: O(T), no ring scan, and any ingest/evict/clear
        changes it. For a fingerprint guaranteed to match a training batch,
        use `RefinementBatch.fingerprint` (same lock acquisition as the
        event snapshot it hashes)."""
        with self._lock:
            return self._fingerprint_locked()

    def build_refinement_batch(
        self,
        embed_batch_fn: Callable[[Sequence[np.ndarray]], np.ndarray],
    ) -> RefinementBatch:
        """Dense [Q, T] masks + batched query embeddings for Alg. 1.

        Deduplicates queries by token content (a query served K tools yields
        K events but one row), embeds the unique queries in one
        `embed_batch_fn` call, and folds every event into pos/neg masks via
        `masks_from_stream` (positives veto negatives on conflict). The
        returned batch carries the window fingerprint taken atomically with
        the event snapshot.
        """
        with self._lock:
            events = list(self._events)
            fingerprint = self._fingerprint_locked()
        keys: Dict[Tuple[int, bytes], int] = {}
        uniq_tokens: List[np.ndarray] = []
        qids = np.empty(len(events), dtype=np.int64)
        tids = np.empty(len(events), dtype=np.int64)
        outs = np.empty(len(events), dtype=np.int64)
        for i, ev in enumerate(events):
            k = _query_key(ev.query_tokens)
            qid = keys.get(k)
            if qid is None:
                qid = keys[k] = len(uniq_tokens)
                uniq_tokens.append(np.asarray(ev.query_tokens))
            qids[i] = qid
            tids[i] = ev.tool_id
            outs[i] = ev.outcome
        pos, neg = masks_from_stream(
            qids, tids, outs, n_queries=len(uniq_tokens), n_tools=self.n_tools
        )
        if uniq_tokens:
            q_emb = np.asarray(embed_batch_fn(uniq_tokens), dtype=np.float32)
        else:
            q_emb = np.zeros((0, 0), dtype=np.float32)
        return RefinementBatch(
            query_tokens=uniq_tokens,
            query_emb=q_emb,
            pos_mask=pos,
            neg_mask=neg,
            n_events=len(events),
            fingerprint=fingerprint,
        )

    # ---------------------------------------------------------- persistence
    def save(self, directory: str, step: int = 0) -> str:
        """Persist the event window via repro.checkpoint (msgpack + codec)."""
        events = self.snapshot_events()
        max_len = max(
            max((len(np.asarray(e.query_tokens)) for e in events), default=1), 1
        )
        tokens = np.zeros((len(events), max_len), dtype=np.int64)
        lengths = np.zeros(len(events), dtype=np.int64)
        tool_ids = np.zeros(len(events), dtype=np.int64)
        outcomes = np.zeros(len(events), dtype=np.int64)
        timestamps = np.zeros(len(events), dtype=np.float64)
        for i, ev in enumerate(events):
            toks = np.asarray(ev.query_tokens)
            lengths[i] = len(toks)
            tokens[i, : len(toks)] = toks
            tool_ids[i] = ev.tool_id
            outcomes[i] = ev.outcome
            timestamps[i] = ev.timestamp
        tree = {
            "tokens": tokens,
            "lengths": lengths,
            "tool_ids": tool_ids,
            "outcomes": outcomes,
            "timestamps": timestamps,
            "counters": {
                "total_ingested": np.int64(self.total_ingested),
                "dropped": np.int64(self.dropped),
            },
        }
        meta = {
            "kind": "outcome_store",
            "n_tools": self.n_tools,
            "capacity": self.capacity,
        }
        return save_checkpoint(directory, step, tree, meta)

    @classmethod
    def restore(
        cls,
        directory: str,
        step: Optional[int] = None,
        capacity: Optional[int] = None,
    ) -> "OutcomeStore":
        """Rebuild a store (events + counters) from a saved window."""
        _, tree, meta = restore_checkpoint(directory, step)
        assert meta.get("kind") == "outcome_store", f"not an outcome store: {meta}"
        store = cls(
            n_tools=int(meta["n_tools"]),
            capacity=int(capacity if capacity is not None else meta["capacity"]),
        )
        lengths = tree["lengths"].reshape(-1)
        for i in range(len(lengths)):
            store.append(
                OutcomeEvent(
                    query_tokens=tree["tokens"][i, : int(lengths[i])].copy(),
                    tool_id=int(tree["tool_ids"].reshape(-1)[i]),
                    outcome=int(tree["outcomes"].reshape(-1)[i]),
                    timestamp=float(tree["timestamps"].reshape(-1)[i]),
                )
            )
        # restore() replays ingestion; overwrite the monotone counters with
        # the persisted lifetime values so trigger watermarks stay correct
        store.total_ingested = int(np.asarray(tree["counters"]["total_ingested"]))
        store.dropped = int(np.asarray(tree["counters"]["dropped"]))
        return store
