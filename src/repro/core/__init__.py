"""The paper's contribution: OATS stages S1/S2/S3, baselines, evaluation."""
