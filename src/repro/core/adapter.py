"""OATS-S3: contrastive embedding adaptation (§4.3). 197,248 parameters.

A residual two-layer projection head h(e) = normalize(e + W2 relu(W1 e + b1)
+ b2) with W2 zero-init, so the adapter starts as the identity and the small
learning rate (1e-5, §5.5) moves it gently — preserving base-model quality
and allowing instant rollback by disabling the head (the paper's deployment
requirements). Trained with InfoNCE (Eq. 6, tau=0.07) over mined triplets
(q, d+, hard d-), combining in-batch negatives with the mined hard negatives,
early-stopped on validation NDCG@5.

Output dimension is unchanged (384), so the adapter is a drop-in replacement:
tool embeddings are recomputed once and the serving path is untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.metrics.retrieval import batched_ndcg_at_k

__all__ = [
    "AdapterConfig",
    "init_adapter",
    "adapter_apply",
    "adapter_param_count",
    "mine_triplets",
    "train_adapter",
]

DIM = 384
HIDDEN = 256  # [384, 256, 384] => 197,248 params (98,304+256+98,304+384)


@dataclasses.dataclass(frozen=True)
class AdapterConfig:
    lr: float = 1e-5
    temperature: float = 0.07
    epochs: int = 5
    batch_size: int = 128
    n_hard_negatives: int = 4
    seed: int = 0
    # beyond-paper knob: scale the residual branch during warmup
    residual_scale: float = 1.0
    # adapt_tools=True is the paper's symmetric deployment: h() applied to
    # both sides, tool embeddings recomputed once at deploy time. The online
    # learning plane trains with adapt_tools=False — h() on queries only,
    # tool table frozen — so a promoted adapter is a pure query-side hot
    # swap: no table swap, no index rebuild, instant rollback.
    adapt_tools: bool = True


def init_adapter(key: jax.Array) -> dict:
    k1, _ = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (DIM, HIDDEN), jnp.float32) * jnp.sqrt(2.0 / DIM),
        "b1": jnp.zeros((HIDDEN,), jnp.float32),
        # zero-init second layer => identity at step 0
        "w2": jnp.zeros((HIDDEN, DIM), jnp.float32),
        "b2": jnp.zeros((DIM,), jnp.float32),
    }


def adapter_param_count(params: dict) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def adapter_apply(params: dict, emb: jnp.ndarray, scale: float = 1.0) -> jnp.ndarray:
    """emb: [..., 384] unit rows -> adapted unit rows (drop-in, same dim)."""
    h = jax.nn.relu(emb @ params["w1"] + params["b1"])
    out = emb + scale * (h @ params["w2"] + params["b2"])
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-9)


def mine_triplets(
    query_emb: np.ndarray,  # [Q, D] train queries
    tool_emb: np.ndarray,  # [T, D]
    relevance: np.ndarray,  # [Q, T]
    n_hard: int = 4,
    candidate_mask: Optional[np.ndarray] = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Triplets (q_idx, pos_tool, [n_hard] hard_neg_tools) (§4.3).

    Hard negatives = highest-similarity non-relevant tools for the query —
    the functional boundaries static embeddings miss.
    """
    rng = np.random.default_rng(seed)
    sims = query_emb @ tool_emb.T
    if candidate_mask is not None:
        sims = np.where(candidate_mask > 0, sims, -np.inf)
    sims = np.where(relevance > 0, -np.inf, sims)  # negatives only
    q_idx, pos, negs = [], [], []
    hard_order = np.argsort(-sims, axis=1)[:, : max(n_hard * 3, n_hard)]
    for j in range(query_emb.shape[0]):
        rel = np.flatnonzero(relevance[j])
        if len(rel) == 0:
            continue
        pool = hard_order[j]
        pool = pool[np.isfinite(sims[j, pool])]
        if len(pool) < n_hard:
            continue
        for t in rel:
            q_idx.append(j)
            pos.append(t)
            negs.append(rng.choice(pool, size=n_hard, replace=False))
    return (
        np.array(q_idx, dtype=np.int64),
        np.array(pos, dtype=np.int64),
        np.stack(negs).astype(np.int64) if negs else np.zeros((0, n_hard), np.int64),
    )


def _info_nce(params, q, pos, negs, temperature, scale, adapt_tools=True):
    """InfoNCE (Eq. 6) with in-batch + mined hard negatives.

    q: [B, D]; pos: [B, D]; negs: [B, H, D]. With `adapt_tools=False` the
    tool-side embeddings pass through unadapted (query-side-only training).
    """
    qa = adapter_apply(params, q, scale)
    if adapt_tools:
        pa = adapter_apply(params, pos, scale)
        na = adapter_apply(params, negs.reshape(-1, negs.shape[-1]), scale).reshape(
            negs.shape
        )
    else:
        pa, na = pos, negs
    pos_logit = (qa * pa).sum(-1, keepdims=True)  # [B, 1]
    inbatch = qa @ pa.T  # [B, B] — off-diagonal are in-batch negatives
    mask = jnp.eye(qa.shape[0], dtype=bool)
    inbatch = jnp.where(mask, -1e30, inbatch)
    hard = jnp.einsum("bd,bhd->bh", qa, na)  # [B, H]
    logits = jnp.concatenate([pos_logit, inbatch, hard], axis=1) / temperature
    return -jnp.mean(jax.nn.log_softmax(logits, axis=1)[:, 0])


def train_adapter(
    query_emb: np.ndarray,
    tool_emb: np.ndarray,
    triplets: tuple[np.ndarray, np.ndarray, np.ndarray],
    val_query_emb: np.ndarray,
    val_relevance: np.ndarray,
    val_candidate_mask: Optional[np.ndarray] = None,
    config: AdapterConfig = AdapterConfig(),
) -> tuple[dict, dict]:
    """InfoNCE training with early stopping on validation NDCG@5 (§5.5)."""
    key = jax.random.PRNGKey(config.seed)
    key, ik = jax.random.split(key)
    params = init_adapter(ik)
    opt = optim.adamw(config.lr)
    opt_state = opt.init(params)

    q_idx, pos_idx, neg_idx = triplets
    n = len(q_idx)
    qe = jnp.asarray(query_emb)
    te = jnp.asarray(tool_emb)
    vqe = jnp.asarray(val_query_emb)
    vrel = jnp.asarray(val_relevance)
    vmask = None if val_candidate_mask is None else jnp.asarray(val_candidate_mask)

    @jax.jit
    def step(params, opt_state, qb, pb, nb):
        loss, grads = jax.value_and_grad(_info_nce)(
            params, qb, pb, nb, config.temperature, config.residual_scale,
            config.adapt_tools,
        )
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    @jax.jit
    def val_ndcg(params):
        qa = adapter_apply(params, vqe, config.residual_scale)
        ta = adapter_apply(params, te, config.residual_scale) if config.adapt_tools else te
        sims = qa @ ta.T
        if vmask is not None:
            sims = jnp.where(vmask > 0, sims, -1e30)
        _, topk = jax.lax.top_k(sims, 5)
        return batched_ndcg_at_k(topk, vrel)

    best = {"params": params, "ndcg": float(val_ndcg(params)), "epoch": -1}
    history = {"loss": [], "val_ndcg": [best["ndcg"]]}
    bs = min(config.batch_size, max(n, 1))
    if n == 0:
        return params, history
    steps_per_epoch = max(n // bs, 1)
    for epoch in range(config.epochs):
        key, pk = jax.random.split(key)
        perm = np.asarray(jax.random.permutation(pk, n))
        ep_loss = 0.0
        for s in range(steps_per_epoch):
            rows = perm[s * bs : (s + 1) * bs]
            qb = qe[q_idx[rows]]
            pb = te[pos_idx[rows]]
            nb = te[neg_idx[rows].reshape(-1)].reshape(len(rows), -1, DIM)
            params, opt_state, loss = step(params, opt_state, qb, pb, nb)
            ep_loss += float(loss)
        history["loss"].append(ep_loss / steps_per_epoch)
        ndcg = float(val_ndcg(params))
        history["val_ndcg"].append(ndcg)
        if ndcg > best["ndcg"]:
            best = {"params": params, "ndcg": ndcg, "epoch": epoch}
    return best["params"], history
