"""Baselines (§5.3): BM25, Static Embedding, SE+Lexical, Random.

BM25 is Okapi BM25 (k1=1.5, b=0.75) over the tool-description token corpus,
vectorized as a dense [T, V] term-frequency matrix (fine at ToolBench scale:
2,413 x ~10k). SE+Lexical reproduces the semantic router's
FilterAndRankTools: a weighted blend of dense similarity, normalized BM25,
exact tool-name match, and a category prior.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["BM25", "se_lexical_scores", "random_rankings"]


@dataclasses.dataclass
class BM25:
    """Okapi BM25 with an inverted index (word -> (docs, weighted tf)).

    Sparse by construction: tool descriptions are ~12 tokens, so the index
    holds O(T * desc_len) postings regardless of vocabulary size.
    """

    idf: np.ndarray  # [V]
    postings: dict  # word -> (doc_ids int64[], saturated_tf float32[])
    n_docs: int
    k1: float
    b: float
    vocab_size: int

    @classmethod
    def fit(
        cls,
        doc_tokens: Sequence[np.ndarray],
        vocab_size: int,
        k1: float = 1.5,
        b: float = 0.75,
    ) -> "BM25":
        n_docs = len(doc_tokens)
        doc_len = np.array([len(t) for t in doc_tokens], dtype=np.float32)
        avg_len = max(doc_len.mean(), 1.0)
        df = np.zeros(vocab_size, dtype=np.float32)
        raw: dict[int, list[tuple[int, float]]] = {}
        for i, toks in enumerate(doc_tokens):
            words, counts = np.unique(np.asarray(toks, dtype=np.int64), return_counts=True)
            df[words] += 1.0
            norm = k1 * (1.0 - b + b * doc_len[i] / avg_len)
            for w, tf in zip(words, counts):
                sat = tf * (k1 + 1.0) / (tf + norm)
                raw.setdefault(int(w), []).append((i, float(sat)))
        idf = np.log((n_docs - df + 0.5) / (df + 0.5) + 1.0)
        postings = {
            w: (
                np.array([d for d, _ in lst], dtype=np.int64),
                np.array([s for _, s in lst], dtype=np.float32),
            )
            for w, lst in raw.items()
        }
        return cls(
            idf=idf, postings=postings, n_docs=n_docs, k1=k1, b=b, vocab_size=vocab_size
        )

    def scores(self, query_tokens: Sequence[np.ndarray]) -> np.ndarray:
        """[Q, T] BM25 scores."""
        out = np.zeros((len(query_tokens), self.n_docs), dtype=np.float32)
        for j, toks in enumerate(query_tokens):
            words, counts = np.unique(np.asarray(toks, dtype=np.int64), return_counts=True)
            for w, qtf in zip(words, counts):
                entry = self.postings.get(int(w))
                if entry is None:
                    continue
                docs, sat = entry
                # query term frequency beyond 1 adds linearly (standard Okapi)
                out[j, docs] += self.idf[w] * sat * qtf
        return out


def se_lexical_scores(
    dense_sims: np.ndarray,  # [Q, T] embedding similarity
    bm25_scores: np.ndarray,  # [Q, T]
    name_match: np.ndarray,  # [Q, T] {0,1} tool-name token appears in query
    category_prior: np.ndarray,  # [Q, T] in [0,1]
    w_embed: float = 0.60,
    w_lex: float = 0.25,
    w_name: float = 0.10,
    w_cat: float = 0.05,
) -> np.ndarray:
    """FilterAndRankTools-style weighted combination (§5.3 baseline 3)."""
    # normalize BM25 per query to [0, 1] so weights are comparable
    mx = bm25_scores.max(axis=1, keepdims=True)
    lex = bm25_scores / np.maximum(mx, 1e-9)
    return w_embed * dense_sims + w_lex * lex + w_name * name_match + w_cat * category_prior


def random_rankings(
    rng: np.random.Generator,
    n_queries: int,
    n_tools: int,
    k: int,
    candidates: Optional[List[np.ndarray]] = None,
) -> np.ndarray:
    """Random top-k per query (§5.3 lower bound)."""
    out = np.zeros((n_queries, k), dtype=np.int64)
    for j in range(n_queries):
        pool = candidates[j] if candidates is not None else np.arange(n_tools)
        perm = rng.permutation(pool)
        take = perm[:k]
        if len(take) < k:  # pad by cycling (tiny candidate sets)
            take = np.concatenate([take, perm[: k - len(take)]])
        out[j] = take
    return out
