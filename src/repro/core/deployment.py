"""Deployment decision rules (paper §7.2-7.3).

The paper's practitioner guidance, as executable policy:
  * refinement is always on (zero serving cost, gate-protected);
  * the MLP re-ranker deploys only above a ~10:1 outcome-to-tool ratio
    ("Gate behind a data-density check (>= 10 examples/tool)", §7.2) —
    below that it hurt on ToolBench;
  * the contrastive adapter targets large tool sets with abundant logs
    (|T| > 500, > 10K logs, §7.3).
"""
from __future__ import annotations

import dataclasses

__all__ = ["DeploymentPlan", "recommend_stages", "data_density", "refine_trigger"]

MLP_DENSITY_THRESHOLD = 10.0  # outcome examples per tool (§7.2)
ADAPTER_MIN_TOOLS = 500  # §7.3
ADAPTER_MIN_LOGS = 10_000


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    refine: bool
    mlp_reranker: bool
    contrastive_adapter: bool
    density: float
    reason: str

    @property
    def stages(self) -> frozenset:
        s = set()
        if self.refine:
            s.add("refine")
        if self.mlp_reranker:
            s.add("rerank")
        if self.contrastive_adapter:
            s.add("adapter")
        return frozenset(s)


def data_density(n_outcome_examples: int, n_tools: int) -> float:
    return n_outcome_examples / max(n_tools, 1)


def recommend_stages(n_tools: int, n_outcome_examples: int) -> DeploymentPlan:
    """Paper §7.3 decision table."""
    density = data_density(n_outcome_examples, n_tools)
    mlp = density >= MLP_DENSITY_THRESHOLD and n_tools <= 500
    adapter = n_tools > ADAPTER_MIN_TOOLS and n_outcome_examples > ADAPTER_MIN_LOGS
    if n_tools < 200:
        reason = "small tool set: refinement alone captures most gains (§7.3)"
        mlp = mlp and density >= 5 * MLP_DENSITY_THRESHOLD  # only if abundant
    elif mlp:
        reason = f"density {density:.1f} >= {MLP_DENSITY_THRESHOLD}: re-ranker viable"
    elif adapter:
        reason = "large tool set with abundant logs: contrastive adapter scales better"
    else:
        reason = f"density {density:.2f} < {MLP_DENSITY_THRESHOLD}: learned components would hurt"
    return DeploymentPlan(
        refine=True, mlp_reranker=mlp, contrastive_adapter=adapter,
        density=density, reason=reason,
    )


def refine_trigger(
    n_new_events: int,
    elapsed_s: float,
    min_events: int,
    max_interval_s: float,
) -> bool:
    """When should the online control plane wake the refinement job?

    §7.2's cadence guidance as policy: run when a full batch of fresh
    outcome evidence has accumulated (`min_events`), or when the table has
    gone stale (`max_interval_s` since the last refinement) *and* there is
    at least one new event — an idle router never churns its table, and a
    trickle of events is folded into the staleness cycle rather than waking
    the job per event.
    """
    if n_new_events >= min_events:
        return True
    return elapsed_s >= max_interval_s and n_new_events > 0
