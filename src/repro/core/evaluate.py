"""Benchmark evaluation harness: all methods x all metrics (paper §5-6).

Produces the rows of Tables 4/5 and the per-subtask splits of Table 3, on the
fixed held-out 30% test set. Every method ranks exactly the same test queries
under the same candidate constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.baselines import BM25, random_rankings, se_lexical_scores
from repro.core.pipeline import STAGE_PRESETS, OATSPipeline, PipelineConfig
from repro.data.benchmarks import SUBTASKS, Benchmark
from repro.embedding.bag_encoder import BagEncoder
from repro.metrics.retrieval import evaluate_ranking

__all__ = ["MethodResult", "BenchmarkEvaluator", "DEFAULT_METHODS"]

DEFAULT_METHODS = ("random", "bm25", "se", "se+lexical", "oats-s1", "oats-s2", "oats-s3")
K_EVAL = 10  # rankings depth: covers R@{1,3,5}, NDCG@5, MRR


@dataclasses.dataclass
class MethodResult:
    name: str
    metrics: Dict[str, float]
    per_subtask: Dict[str, Dict[str, float]]
    rankings: np.ndarray  # [n_test, K_EVAL]
    pipeline: Optional[OATSPipeline] = None


class BenchmarkEvaluator:
    def __init__(self, bench: Benchmark, seed: int = 0):
        self.bench = bench
        self.seed = seed
        self.encoder = BagEncoder(bench.vocab)
        self.tool_emb = self.encoder.encode(bench.desc_tokens)
        self.query_emb = self.encoder.encode(bench.query_tokens)
        self.relevance = bench.relevance_matrix()
        self.cand_mask = (
            bench.candidate_mask() if bench.candidates is not None else None
        )
        self.test_idx = bench.test_idx
        self.test_tokens = [bench.query_tokens[i] for i in self.test_idx]
        self._bm25 = BM25.fit(bench.desc_tokens, bench.vocab.size)
        # category prior for SE+Lexical: similarity of query to category centroid
        n_cat = int(bench.tool_category.max()) + 1
        cat_centroids = np.zeros((n_cat, self.tool_emb.shape[1]), np.float32)
        for c in range(n_cat):
            m = bench.tool_category == c
            if m.any():
                v = self.tool_emb[m].mean(axis=0)
                cat_centroids[c] = v / max(np.linalg.norm(v), 1e-9)
        self._cat_centroids = cat_centroids

    # ------------------------------------------------------------ rankings
    def _mask_test(self, sims: np.ndarray) -> np.ndarray:
        if self.cand_mask is not None:
            sims = np.where(self.cand_mask[self.test_idx] > 0, sims, -1e30)
        return sims

    def _rank_from_scores(self, sims: np.ndarray) -> np.ndarray:
        return np.argsort(-sims, axis=1, kind="stable")[:, :K_EVAL]

    def rankings_for(self, method: str) -> MethodResult:
        name = method.lower()
        pipeline = None
        if name == "random":
            rng = np.random.default_rng(self.seed)
            cands = (
                [self.bench.candidates[i] for i in self.test_idx]
                if self.bench.candidates is not None
                else None
            )
            rk = random_rankings(
                rng, len(self.test_idx), self.bench.n_tools, K_EVAL, cands
            )
        elif name == "bm25":
            scores = self._bm25.scores(self.test_tokens)
            rk = self._rank_from_scores(self._mask_test(scores))
        elif name == "se":
            sims = self.query_emb[self.test_idx] @ self.tool_emb.T
            rk = self._rank_from_scores(self._mask_test(sims))
        elif name == "se+lexical":
            sims = self.query_emb[self.test_idx] @ self.tool_emb.T
            bm = self._bm25.scores(self.test_tokens)
            name_match = np.zeros_like(sims)
            for j, toks in enumerate(self.test_tokens):
                toks = set(int(t) for t in toks)
                for t in range(self.bench.n_tools):
                    if self.bench.vocab.name_token(t) in toks:
                        name_match[j, t] = 1.0
            cat_sim = (
                self.query_emb[self.test_idx] @ self._cat_centroids.T
            )  # [Q, n_cat]
            cat_prior = cat_sim[:, self.bench.tool_category]  # [Q, T]
            scores = se_lexical_scores(sims, bm, name_match, cat_prior)
            rk = self._rank_from_scores(self._mask_test(scores))
        elif name in STAGE_PRESETS:
            cfg = PipelineConfig(stages=STAGE_PRESETS[name], seed=self.seed)
            pipeline = OATSPipeline.fit(self.bench, cfg, self.encoder)
            rk = pipeline.rank(
                self.test_tokens,
                K_EVAL,
                None if self.cand_mask is None else self.cand_mask[self.test_idx],
            )
        else:
            raise ValueError(f"unknown method {method!r}")
        return self._score(name, rk, pipeline)

    # -------------------------------------------------------------- scoring
    def _score(
        self, name: str, rankings: np.ndarray, pipeline: Optional[OATSPipeline]
    ) -> MethodResult:
        rows: List[Dict[str, float]] = []
        subtask_rows: Dict[str, List[Dict[str, float]]] = {s: [] for s in SUBTASKS}
        for j, qi in enumerate(self.test_idx):
            m = evaluate_ranking(rankings[j], self.bench.relevant[qi])
            rows.append(m)
            subtask_rows[SUBTASKS[self.bench.subtask[qi]]].append(m)

        def mean(rs: List[Dict[str, float]]) -> Dict[str, float]:
            if not rs:
                return {}
            return {k: float(np.mean([r[k] for r in rs])) for k in rs[0]}

        return MethodResult(
            name=name,
            metrics=mean(rows),
            per_subtask={s: mean(r) for s, r in subtask_rows.items()},
            rankings=rankings,
            pipeline=pipeline,
        )

    def run(self, methods: Sequence[str] = DEFAULT_METHODS) -> Dict[str, MethodResult]:
        return {m: self.rankings_for(m) for m in methods}
