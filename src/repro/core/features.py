"""Outcome-derived features for the Stage-2 re-ranker (Eq. 8).

features(q, t_i) = [ sim, Delta_sim, cat(t_i), sr_i(q), freq_i, len(q), margin ]

d_feat = 7, matching the paper's [7, 64, 32, 1] MLP. `sr_i(q)` is the
historical success rate of tool i on queries in the same cluster as q
(k-means over train query embeddings); `freq_i` is tool usage frequency in
the outcome logs; `cat` is a category-affinity indicator between the tool and
the query's cluster.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

__all__ = ["kmeans", "OutcomeFeaturizer", "N_FEATURES"]

N_FEATURES = 7


def kmeans(
    x: np.ndarray, k: int, iters: int = 25, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Plain Lloyd's k-means. Returns (centroids [k,D], assignment [N])."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    k = min(k, n)
    centroids = x[rng.choice(n, size=k, replace=False)].copy()
    assign = np.zeros(n, dtype=np.int64)
    for _ in range(iters):
        d2 = ((x[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
        new_assign = d2.argmin(axis=1)
        if (new_assign == assign).all():
            break
        assign = new_assign
        for c in range(k):
            m = assign == c
            if m.any():
                centroids[c] = x[m].mean(axis=0)
    return centroids, assign


@dataclasses.dataclass
class OutcomeFeaturizer:
    cluster_centroids: np.ndarray  # [C, D]
    success_rate: np.ndarray  # [T, C] per-tool-per-cluster success rate
    tool_freq: np.ndarray  # [T] normalized usage frequency
    tool_category: np.ndarray  # [T]
    cluster_category: np.ndarray  # [C] dominant ground-truth category per cluster
    mean_query_len: float

    @classmethod
    def fit(
        cls,
        train_query_emb: np.ndarray,  # [Q, D]
        train_query_tokens: Sequence[np.ndarray],
        train_relevance: np.ndarray,  # [Q, T]
        train_retrieved: np.ndarray,  # [Q, K] top-K under serving embeddings
        tool_category: np.ndarray,  # [T]
        n_clusters: int = 32,
        seed: int = 0,
    ) -> "OutcomeFeaturizer":
        n_q, n_t = train_relevance.shape
        n_clusters = max(min(n_clusters, n_q // 8), 1)
        centroids, assign = kmeans(train_query_emb, n_clusters, seed=seed)
        n_c = centroids.shape[0]
        # success rate: of the times tool t was retrieved for cluster c, how
        # often was it relevant (Laplace-smoothed)
        sel = np.zeros((n_t, n_c), dtype=np.float32)
        hit = np.zeros((n_t, n_c), dtype=np.float32)
        for j in range(n_q):
            c = assign[j]
            for t in train_retrieved[j]:
                sel[t, c] += 1.0
                hit[t, c] += train_relevance[j, t]
        success_rate = (hit + 0.5) / (sel + 1.0)
        tool_freq = train_relevance.sum(axis=0)
        tool_freq = tool_freq / max(tool_freq.max(), 1.0)
        # dominant ground-truth category per cluster
        n_cat = int(tool_category.max()) + 1
        cat_votes = np.zeros((n_c, n_cat), dtype=np.float32)
        for j in range(n_q):
            for t in np.flatnonzero(train_relevance[j]):
                cat_votes[assign[j], tool_category[t]] += 1.0
        cluster_category = cat_votes.argmax(axis=1)
        mean_len = float(np.mean([len(t) for t in train_query_tokens])) or 1.0
        return cls(
            cluster_centroids=centroids,
            success_rate=success_rate,
            tool_freq=tool_freq.astype(np.float32),
            tool_category=tool_category,
            cluster_category=cluster_category,
            mean_query_len=mean_len,
        )

    def assign_cluster(self, query_emb: np.ndarray) -> np.ndarray:
        d2 = ((query_emb[:, None, :] - self.cluster_centroids[None, :, :]) ** 2).sum(-1)
        return d2.argmin(axis=1)

    def features(
        self,
        query_emb: np.ndarray,  # [Q, D]
        query_tokens: Sequence[np.ndarray],
        cand_idx: np.ndarray,  # [Q, C] candidate tool ids (similarity-ordered)
        cand_sims: np.ndarray,  # [Q, C] similarity scores, descending
    ) -> np.ndarray:
        """[Q, C, 7] feature tensor for every (query, candidate).

        Candidate slots whose similarity is the candidate-mask sentinel
        (-1e30, i.e. the query has fewer candidates than C) get all-zero
        features; callers must also mask their scores out of the re-ranked
        ordering (see `reranker.rerank_topk`).
        """
        n_q, n_c = cand_idx.shape
        valid = cand_sims > -1e29  # [Q, C]
        sims = np.where(valid, cand_sims, 0.0)
        clusters = self.assign_cluster(query_emb)  # [Q]
        feats = np.zeros((n_q, n_c, N_FEATURES), dtype=np.float32)
        # 0: similarity
        feats[:, :, 0] = sims
        # 1: gap to the next candidate (0 for the last)
        feats[:, :-1, 1] = sims[:, :-1] - sims[:, 1:]
        # 2: category affinity — tool category matches the cluster's dominant one
        feats[:, :, 2] = (
            self.tool_category[cand_idx] == self.cluster_category[clusters][:, None]
        ).astype(np.float32)
        # 3: historical success rate of tool in the query's cluster
        feats[:, :, 3] = self.success_rate[cand_idx, clusters[:, None]]
        # 4: tool usage frequency
        feats[:, :, 4] = self.tool_freq[cand_idx]
        # 5: normalized query length
        qlen = np.array([len(t) for t in query_tokens], dtype=np.float32)
        feats[:, :, 5] = (qlen / self.mean_query_len)[:, None]
        # 6: margin to the top-1 candidate
        feats[:, :, 6] = sims[:, :1] - sims
        return np.where(valid[:, :, None], feats, 0.0).astype(np.float32)
