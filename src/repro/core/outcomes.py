"""Outcome-log machinery (Alg. 1 steps 1-2).

From production logs (here: retrieval against ground truth on the train split)
we build, per tool, the positive query set Q+ and the hard-negative set Q-.
Represented densely as [Q_train, T] masks so the whole of Alg. 1 jits.

`positives` semantics (paper App. A.3 vs Alg.1 line 10): the walkthrough
collects *all* ground-truth queries for the tool as Q+, while Alg. 1's
pseudo-code keeps only those that were also retrieved. We default to the
walkthrough behaviour ("ground_truth") — a missed ground-truth query is
precisely the signal that should pull an opaque tool toward its users — and
expose "retrieved" for the strict-pseudocode ablation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

__all__ = ["OutcomeLogs", "collect_outcomes"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OutcomeLogs:
    pos_mask: jnp.ndarray  # [Q, T] 1 where q in Q_i^+
    neg_mask: jnp.ndarray  # [Q, T] 1 where q in Q_i^- (retrieved, not relevant)
    retrieved: jnp.ndarray  # [Q, K] top-K indices under current embeddings

    @property
    def pos_counts(self) -> jnp.ndarray:  # [T]
        return self.pos_mask.sum(axis=0)

    @property
    def neg_counts(self) -> jnp.ndarray:  # [T]
        return self.neg_mask.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("k", "positives"))
def collect_outcomes(
    query_emb: jnp.ndarray,  # [Q, D] train queries
    tool_emb: jnp.ndarray,  # [T, D] current tool table
    relevance: jnp.ndarray,  # [Q, T] binary ground truth
    candidate_mask: jnp.ndarray | None = None,  # [Q, T] or None
    k: int = 5,
    positives: str = "ground_truth",
) -> OutcomeLogs:
    sims = query_emb @ tool_emb.T
    if candidate_mask is not None:
        sims = jnp.where(candidate_mask > 0, sims, -1e30)
    k = min(k, sims.shape[1])  # tool sets smaller than K
    _, topk = jax.lax.top_k(sims, k)  # [Q, K]
    # retrieved_mask[q, t] = 1 iff t in topk(q)
    retrieved_mask = jnp.zeros_like(relevance).at[
        jnp.arange(sims.shape[0])[:, None], topk
    ].set(1.0)
    if positives == "retrieved":
        pos_mask = retrieved_mask * relevance
    else:  # "ground_truth": every labelled-relevant train query counts
        pos_mask = relevance
    neg_mask = retrieved_mask * (1.0 - relevance)  # hard negatives only
    return OutcomeLogs(pos_mask=pos_mask, neg_mask=neg_mask, retrieved=topk)
