"""Outcome-log machinery (Alg. 1 steps 1-2).

From production logs we build, per tool, the positive query set Q+ and the
hard-negative set Q-. Represented densely as [Q_train, T] masks so the whole
of Alg. 1 jits. Two sources feed this machinery:

  * train-split ground truth (`collect_outcomes`): retrieval against a dense
    relevance matrix — the offline benchmark shape;
  * streamed serving outcomes (`masks_from_stream`): (query, tool, outcome)
    event triples logged by the live router and drained through the control
    plane's `OutcomeStore` — §7.2's "read outcome logs" step. The resulting
    positive mask doubles as the observed relevance matrix that
    `refine_embeddings` consumes (a logged success *is* the relevance label
    in deployment; no ground-truth file exists at serving time).

`positives` semantics (paper App. A.3 vs Alg.1 line 10): the walkthrough
collects *all* ground-truth queries for the tool as Q+, while Alg. 1's
pseudo-code keeps only those that were also retrieved. We default to the
walkthrough behaviour ("ground_truth") — a missed ground-truth query is
precisely the signal that should pull an opaque tool toward its users — and
expose "retrieved" for the strict-pseudocode ablation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["OutcomeLogs", "collect_outcomes", "masks_from_stream"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OutcomeLogs:
    pos_mask: jnp.ndarray  # [Q, T] 1 where q in Q_i^+
    neg_mask: jnp.ndarray  # [Q, T] 1 where q in Q_i^- (retrieved, not relevant)
    retrieved: jnp.ndarray  # [Q, K] top-K indices under current embeddings

    @property
    def pos_counts(self) -> jnp.ndarray:  # [T]
        return self.pos_mask.sum(axis=0)

    @property
    def neg_counts(self) -> jnp.ndarray:  # [T]
        return self.neg_mask.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("k", "positives"))
def collect_outcomes(
    query_emb: jnp.ndarray,  # [Q, D] train queries
    tool_emb: jnp.ndarray,  # [T, D] current tool table
    relevance: jnp.ndarray,  # [Q, T] binary ground truth
    candidate_mask: jnp.ndarray | None = None,  # [Q, T] or None
    k: int = 5,
    positives: str = "ground_truth",
) -> OutcomeLogs:
    sims = query_emb @ tool_emb.T
    if candidate_mask is not None:
        sims = jnp.where(candidate_mask > 0, sims, -1e30)
    k = min(k, sims.shape[1])  # tool sets smaller than K
    _, topk = jax.lax.top_k(sims, k)  # [Q, K]
    # retrieved_mask[q, t] = 1 iff t in topk(q)
    retrieved_mask = jnp.zeros_like(relevance).at[
        jnp.arange(sims.shape[0])[:, None], topk
    ].set(1.0)
    if positives == "retrieved":
        pos_mask = retrieved_mask * relevance
    else:  # "ground_truth": every labelled-relevant train query counts
        pos_mask = relevance
    neg_mask = retrieved_mask * (1.0 - relevance)  # hard negatives only
    return OutcomeLogs(pos_mask=pos_mask, neg_mask=neg_mask, retrieved=topk)


def masks_from_stream(
    query_ids: np.ndarray,  # [E] int — index into the deduped query axis
    tool_ids: np.ndarray,  # [E] int — routed tool per event
    outcomes: np.ndarray,  # [E] {0, 1} — logged success/failure
    n_queries: int,
    n_tools: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Dense `[Q, T]` pos/neg masks from streamed (q_j, t_i, o_j) events.

    Pure numpy — runs in the control plane, not inside jit. The same
    (query, tool) pair may be logged repeatedly across serving windows with
    mixed outcomes (outcomes are stochastic downstream signals); at least
    one logged success marks the pair positive — the evidence the tool *can*
    serve that intent — and positives veto negatives, so `pos * neg == 0`
    always holds. `pos` is the observed relevance matrix for
    `refine_embeddings`; `neg` is the observed-failure mask, kept for
    diagnostics and density accounting (Alg. 1 re-derives hard negatives
    against the *current* table each iteration, so the refinement itself
    only needs `pos`).
    """
    query_ids = np.asarray(query_ids, dtype=np.int64)
    tool_ids = np.asarray(tool_ids, dtype=np.int64)
    outcomes = np.asarray(outcomes)
    if query_ids.size:
        assert query_ids.min() >= 0 and query_ids.max() < n_queries
        assert tool_ids.min() >= 0 and tool_ids.max() < n_tools
    pos = np.zeros((n_queries, n_tools), dtype=np.float32)
    neg = np.zeros((n_queries, n_tools), dtype=np.float32)
    good = outcomes > 0
    pos[query_ids[good], tool_ids[good]] = 1.0
    neg[query_ids[~good], tool_ids[~good]] = 1.0
    neg *= 1.0 - pos
    return pos, neg
