"""OATS pipeline: stage composition + fit/serve (Eq. 4, §5.4).

Configurations (cumulative, as in the paper):
    OATS-S1 = {refine}
    OATS-S2 = {refine, rerank}
    OATS-S3 = {adapter, refine, rerank}

`fit` runs entirely offline (the control plane); `rank` is the serving path.
All learning uses only the train split; Stage 1's validation gate and Stage
3's early stopping use an 85/15 sub-split of train (§5.5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapter as adapter_lib
from repro.core import reranker as reranker_lib
from repro.core.features import OutcomeFeaturizer
from repro.core.refine import RefineConfig, RefineResult, refine_with_gate
from repro.data.benchmarks import Benchmark
from repro.embedding.bag_encoder import BagEncoder

__all__ = ["PipelineConfig", "OATSPipeline", "STAGE_PRESETS"]

STAGE_PRESETS = {
    "se": frozenset(),
    "oats-s1": frozenset({"refine"}),
    "oats-s2": frozenset({"refine", "rerank"}),
    "oats-s3": frozenset({"adapter", "refine", "rerank"}),
    # ablation rows (Table 5 components in isolation)
    "adapter-only": frozenset({"adapter"}),
    "rerank-only": frozenset({"rerank"}),
}


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    stages: frozenset = frozenset({"refine"})
    k: int = 5
    refine: RefineConfig = RefineConfig()
    reranker: reranker_lib.RerankerConfig = reranker_lib.RerankerConfig()
    adapter: adapter_lib.AdapterConfig = adapter_lib.AdapterConfig()
    gate_val_frac: float = 0.15  # 85/15 sub-split of train (§5.5)
    seed: int = 0


@dataclasses.dataclass
class OATSPipeline:
    config: PipelineConfig
    encoder: BagEncoder
    tool_table: np.ndarray  # serving tool-embedding table (post refinement)
    adapter_params: Optional[dict] = None
    mlp_params: Optional[dict] = None
    featurizer: Optional[OutcomeFeaturizer] = None
    refine_result: Optional[RefineResult] = None
    adapter_history: Optional[dict] = None

    # ------------------------------------------------------------------ fit
    @classmethod
    def fit(
        cls,
        bench: Benchmark,
        config: PipelineConfig,
        encoder: Optional[BagEncoder] = None,
    ) -> "OATSPipeline":
        enc = encoder or BagEncoder(bench.vocab)
        tool_emb0 = enc.encode(bench.desc_tokens)  # static table e(d_i)
        query_emb_all = enc.encode(bench.query_tokens)
        relevance = bench.relevance_matrix()
        cand_mask_all = bench.candidate_mask() if bench.candidates is not None else None

        train = bench.train_idx
        rng = np.random.default_rng(config.seed)
        perm = rng.permutation(len(train))
        n_val = max(int(round(config.gate_val_frac * len(train))), 1)
        fit_idx = train[np.sort(perm[n_val:])]
        val_idx = train[np.sort(perm[:n_val])]

        def sub(mat, idx):
            return None if mat is None else mat[idx]

        q_emb = query_emb_all
        tool_table = tool_emb0
        adapter_params = None
        adapter_history = None

        # ---- Stage 3 component: contrastive adapter (drop-in encoder swap)
        if "adapter" in config.stages:
            triplets = adapter_lib.mine_triplets(
                query_emb_all[fit_idx],
                tool_emb0,
                relevance[fit_idx],
                n_hard=config.adapter.n_hard_negatives,
                candidate_mask=sub(cand_mask_all, fit_idx),
                seed=config.seed,
            )
            adapter_params, adapter_history = adapter_lib.train_adapter(
                query_emb_all[fit_idx],
                tool_emb0,
                triplets,
                query_emb_all[val_idx],
                relevance[val_idx],
                sub(cand_mask_all, val_idx),
                config.adapter,
            )
            # recompute the tool table and all query embeddings once (§4.3)
            tool_table = np.asarray(adapter_lib.adapter_apply(adapter_params, tool_emb0))
            q_emb = np.asarray(adapter_lib.adapter_apply(adapter_params, query_emb_all))

        # ---- Stage 1: outcome-guided refinement with validation gate
        refine_result = None
        if "refine" in config.stages:
            refine_result = refine_with_gate(
                jnp.asarray(tool_table),
                jnp.asarray(q_emb[fit_idx]),
                jnp.asarray(relevance[fit_idx]),
                jnp.asarray(q_emb[val_idx]),
                jnp.asarray(relevance[val_idx]),
                config.refine,
                None if cand_mask_all is None else jnp.asarray(cand_mask_all[fit_idx]),
                None if cand_mask_all is None else jnp.asarray(cand_mask_all[val_idx]),
            )
            tool_table = np.asarray(refine_result.embeddings)

        # ---- Stage 2: MLP re-ranker over outcome features
        mlp_params = None
        featurizer = None
        if "rerank" in config.stages:
            c = config.k * config.reranker.candidate_multiplier
            c = min(c, tool_table.shape[0])
            sims = q_emb[fit_idx] @ tool_table.T
            cm = sub(cand_mask_all, fit_idx)
            if cm is not None:
                sims = np.where(cm > 0, sims, -1e30)
            order = np.argsort(-sims, axis=1)[:, :c]
            cand_sims = np.take_along_axis(sims, order, axis=1)
            featurizer = OutcomeFeaturizer.fit(
                q_emb[fit_idx],
                [bench.query_tokens[i] for i in fit_idx],
                relevance[fit_idx],
                order[:, : config.k],
                bench.tool_category,
                seed=config.seed,
            )
            feats = featurizer.features(
                q_emb[fit_idx],
                [bench.query_tokens[i] for i in fit_idx],
                order,
                cand_sims,
            )
            labels = np.take_along_axis(relevance[fit_idx], order, axis=1)
            valid = cand_sims > -1e29  # ignore padded candidate slots
            mlp_params, _ = reranker_lib.train_reranker(
                feats[valid], labels[valid], config.reranker
            )

        return cls(
            config=config,
            encoder=enc,
            tool_table=tool_table,
            adapter_params=adapter_params,
            mlp_params=mlp_params,
            featurizer=featurizer,
            refine_result=refine_result,
            adapter_history=adapter_history,
        )

    # ---------------------------------------------------------------- serve
    def embed_queries(self, query_tokens: Sequence[np.ndarray]) -> np.ndarray:
        q = self.encoder.encode(query_tokens)
        if self.adapter_params is not None:
            q = np.asarray(adapter_lib.adapter_apply(self.adapter_params, q))
        return q

    def rank(
        self,
        query_tokens: Sequence[np.ndarray],
        k: int,
        candidate_mask: Optional[np.ndarray] = None,
        query_emb: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Serving path: embed -> similarity -> (optional re-rank) -> top-k."""
        q = self.embed_queries(query_tokens) if query_emb is None else query_emb
        sims = q @ self.tool_table.T
        if candidate_mask is not None:
            sims = np.where(candidate_mask > 0, sims, -1e30)
        if self.mlp_params is None:
            return np.argsort(-sims, axis=1)[:, :k]
        c = min(
            max(self.config.k * self.config.reranker.candidate_multiplier, k),
            self.tool_table.shape[0],
        )
        order = np.argsort(-sims, axis=1)[:, :c]
        cand_sims = np.take_along_axis(sims, order, axis=1)
        feats = self.featurizer.features(q, query_tokens, order, cand_sims)
        reranked = reranker_lib.rerank_topk(
            self.mlp_params,
            jnp.asarray(feats),
            jnp.asarray(order),
            k,
            valid=jnp.asarray(cand_sims > -1e29),
        )
        return np.asarray(reranked)
