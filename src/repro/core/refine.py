"""OATS-S1: iterative outcome-guided embedding refinement (Alg. 1, §4.1).

The paper's core contribution. Pure JAX: one jitted function runs all N
iterations (outcome collection -> centroid interpolation -> momentum blend),
and a separate validation gate (Alg. 1 step 5) accepts the refined table only
if held-out Recall@K improves. Shardable over the tool axis for very large
tool databases (the [T, D] table and all [Q, T] masks are embarrassingly
parallel in T under pjit).

Update rule (Eq. 7), per tool i with |Q_i^+| >= 1:

    e_hat = (1 - alpha) * e + alpha * centroid(Q_i^+) - beta * centroid(Q_i^-)
    e_hat = e_hat / ||e_hat||
    e_new = mu * e_prev + (1 - mu) * e_hat        (momentum, iterations n > 1)

Defaults are the paper's: alpha=0.3, beta=0.1, N=3, mu=0.5, K=5.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.outcomes import collect_outcomes
from repro.metrics.retrieval import batched_ndcg_at_k, batched_recall_at_k

__all__ = ["RefineConfig", "RefineResult", "refine_embeddings", "refine_with_gate"]


@dataclasses.dataclass(frozen=True)
class RefineConfig:
    alpha: float = 0.3  # attraction toward positive centroid
    beta: float = 0.1  # repulsion from negative centroid (beta < alpha, §4.1)
    iterations: int = 3  # N
    momentum: float = 0.5  # mu
    k: int = 5  # top-K used both for outcome logs and the validation gate
    positives: str = "ground_truth"  # see outcomes.py
    # validation-gate metric: "recall" (Alg. 1 step 5, the offline default)
    # or "ndcg". With streamed-outcome relevance every logged positive was
    # in the serving top-K by construction, so held-out Recall@K starts at
    # exactly 1.0 and the gate can only tie or reject; rank-sensitive NDCG
    # still registers improvement (positives pulled toward rank 1) — the
    # online control plane gates on it.
    gate_metric: str = "recall"
    # materialize the [N+1, T, D] per-iteration history (Fig. 4 convergence
    # plots). The control plane's repeated refinements on large tables turn
    # this off: the buffer is N+1 full table copies of pure overhead there.
    keep_history: bool = True


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RefineResult:
    embeddings: jnp.ndarray  # [T, D] refined (post-gate) tool table
    accepted: jnp.ndarray  # bool — validation gate decision
    recall_before: jnp.ndarray
    recall_after: jnp.ndarray
    # [N+1, T, D] per-iteration tables (fig. 4 convergence), or None when
    # the run was configured with keep_history=False
    history: Optional[jnp.ndarray]


def _masked_centroid(mask: jnp.ndarray, query_emb: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """mask: [Q, T]; query_emb: [Q, D] -> ([T, D] centroids, [T] counts)."""
    counts = mask.sum(axis=0)  # [T]
    sums = mask.T @ query_emb  # [T, D]
    centroids = sums / jnp.maximum(counts, 1.0)[:, None]
    return centroids, counts


@functools.partial(
    jax.jit,
    static_argnames=(
        "alpha", "beta", "iterations", "momentum", "k", "positives", "keep_history"
    ),
)
def refine_embeddings(
    tool_emb: jnp.ndarray,  # [T, D] original table e(d_i)
    query_emb: jnp.ndarray,  # [Q, D] train-split query embeddings
    relevance: jnp.ndarray,  # [Q, T] binary outcome labels
    candidate_mask: Optional[jnp.ndarray] = None,
    *,
    alpha: float = 0.3,
    beta: float = 0.1,
    iterations: int = 3,
    momentum: float = 0.5,
    k: int = 5,
    positives: str = "ground_truth",
    keep_history: bool = True,
) -> jnp.ndarray:
    """Run Alg. 1 steps 1-4.

    With `keep_history` (default) returns [N+1, T, D]: the table after each
    iteration (index 0 = original), so callers can plot convergence (paper
    Fig. 4). With `keep_history=False` returns only the final [T, D] table —
    the N+1 table copies are never materialized, which is what the online
    control plane wants for repeated refinements on large tool sets.
    """

    def one_iteration(n, state):
        e_prev, history = state
        # Steps 1-2: outcome logs against *current* embeddings — each pass
        # exposes the new hard negatives created by the previous update.
        logs = collect_outcomes(
            query_emb, e_prev, relevance, candidate_mask, k=k, positives=positives
        )
        # Step 3: centroid interpolation (Eq. 7)
        pos_c, pos_n = _masked_centroid(logs.pos_mask, query_emb)
        neg_c, neg_n = _masked_centroid(logs.neg_mask, query_emb)
        e_hat = (1.0 - alpha) * e_prev + alpha * pos_c
        e_hat = e_hat - beta * jnp.where((neg_n > 0)[:, None], 1.0, 0.0) * neg_c
        e_hat = e_hat / jnp.maximum(jnp.linalg.norm(e_hat, axis=-1, keepdims=True), 1e-9)
        # tools with no positive outcomes stay at their previous embedding
        e_hat = jnp.where((pos_n > 0)[:, None], e_hat, e_prev)
        # Step 4: momentum blend with previous iterate (n > 1)
        blended = momentum * e_prev + (1.0 - momentum) * e_hat
        blended = blended / jnp.maximum(
            jnp.linalg.norm(blended, axis=-1, keepdims=True), 1e-9
        )
        e_new = jnp.where(n > 0, blended, e_hat)
        if keep_history:  # static: the False branch never allocates the buffer
            history = history.at[n + 1].set(e_new)
        return e_new, history

    t, d = tool_emb.shape
    history0 = (
        jnp.zeros((iterations + 1, t, d), tool_emb.dtype).at[0].set(tool_emb)
        if keep_history
        else jnp.zeros((0,), tool_emb.dtype)
    )
    e_final, history = jax.lax.fori_loop(
        0, iterations, one_iteration, (tool_emb, history0)
    )
    return history if keep_history else e_final


def _gate_metric_at_k(
    query_emb: jnp.ndarray,
    tool_emb: jnp.ndarray,
    relevance: jnp.ndarray,
    candidate_mask: Optional[jnp.ndarray],
    k: int,
    metric: str = "recall",
) -> jnp.ndarray:
    sims = query_emb @ tool_emb.T
    if candidate_mask is not None:
        sims = jnp.where(candidate_mask > 0, sims, -1e30)
    _, topk = jax.lax.top_k(sims, min(k, sims.shape[1]))
    if metric == "ndcg":
        return batched_ndcg_at_k(topk, relevance)
    assert metric == "recall", f"unknown gate metric {metric!r}"
    return batched_recall_at_k(topk, relevance)


def refine_with_gate(
    tool_emb: jnp.ndarray,
    train_query_emb: jnp.ndarray,
    train_relevance: jnp.ndarray,
    val_query_emb: jnp.ndarray,
    val_relevance: jnp.ndarray,
    config: RefineConfig = RefineConfig(),
    train_candidate_mask: Optional[jnp.ndarray] = None,
    val_candidate_mask: Optional[jnp.ndarray] = None,
) -> RefineResult:
    """Alg. 1 incl. step 5: accept the refined table only if the held-out
    gate metric (Recall@K by default, NDCG@K via `config.gate_metric`) does
    not degrade.

    The gate guarantees the deployed system cannot degrade below the static
    baseline (§4.1) — this invariant is property-tested.
    `RefineResult.recall_before/after` hold whichever gate metric ran.
    """
    out = refine_embeddings(
        tool_emb,
        train_query_emb,
        train_relevance,
        train_candidate_mask,
        alpha=config.alpha,
        beta=config.beta,
        iterations=config.iterations,
        momentum=config.momentum,
        k=config.k,
        positives=config.positives,
        keep_history=config.keep_history,
    )
    history = out if config.keep_history else None
    refined = out[-1] if config.keep_history else out
    r_before = _gate_metric_at_k(
        val_query_emb, tool_emb, val_relevance, val_candidate_mask,
        config.k, config.gate_metric,
    )
    r_after = _gate_metric_at_k(
        val_query_emb, refined, val_relevance, val_candidate_mask,
        config.k, config.gate_metric,
    )
    accepted = r_after >= r_before
    final = jnp.where(accepted, refined, tool_emb)
    return RefineResult(
        embeddings=final,
        accepted=accepted,
        recall_before=r_before,
        recall_after=r_after,
        history=history,
    )
