"""OATS-S2: learned re-ranking MLP (§4.2). 2,625 parameters, [7, 64, 32, 1].

Trained with BCE (Eq. 9) over outcome-labelled (query, candidate) pairs.
At inference: retrieve C = alpha*K candidates by similarity (alpha=5), rescore
with f_phi, return the top-K by MLP score. The paper's headline negative
result — the re-ranker *hurts* below a ~10:1 data-to-tool ratio — reproduces
on the toolbench-like benchmark (<0.15 positives/tool).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.features import N_FEATURES

__all__ = [
    "RerankerConfig",
    "init_mlp",
    "mlp_forward",
    "train_reranker",
    "mlp_param_count",
    "rerank_topk",
    "rerank_topk_scored",
]

LAYERS = (N_FEATURES, 64, 32, 1)  # paper §4.2: [7, 64, 32, 1] => 2,625 params


@dataclasses.dataclass(frozen=True)
class RerankerConfig:
    lr: float = 1e-3
    epochs: int = 30
    batch_size: int = 512
    dropout: float = 0.1  # §5.5
    weight_decay: float = 1e-4
    seed: int = 0
    candidate_multiplier: int = 5  # alpha: retrieve C = alpha*K then re-rank


def init_mlp(key: jax.Array) -> dict:
    params = {}
    for li, (din, dout) in enumerate(zip(LAYERS[:-1], LAYERS[1:])):
        key, wk = jax.random.split(key)
        params[f"w{li}"] = jax.random.normal(wk, (din, dout), jnp.float32) * jnp.sqrt(
            2.0 / din
        )
        params[f"b{li}"] = jnp.zeros((dout,), jnp.float32)
    return params


def mlp_param_count(params: dict) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def mlp_forward(
    params: dict, x: jnp.ndarray, *, dropout: float = 0.0, key: jax.Array | None = None
) -> jnp.ndarray:
    """x: [..., 7] -> logits [...]. Sigmoid is applied in the loss/score."""
    h = x
    n_layers = len(LAYERS) - 1
    for li in range(n_layers):
        h = h @ params[f"w{li}"] + params[f"b{li}"]
        if li < n_layers - 1:
            h = jax.nn.relu(h)
            if dropout > 0.0 and key is not None:
                key, dk = jax.random.split(key)
                keep = jax.random.bernoulli(dk, 1.0 - dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    return h[..., 0]


def _bce_loss(params, x, y, key, dropout):
    logits = mlp_forward(params, x, dropout=dropout, key=key)
    # Eq. 9: binary cross-entropy on outcome labels
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def train_reranker(
    features: np.ndarray,  # [N, 7] flattened (query, candidate) rows
    labels: np.ndarray,  # [N] outcome o in {0,1}
    config: RerankerConfig = RerankerConfig(),
) -> tuple[dict, list[float]]:
    """BCE training with AdamW. Returns (params, per-epoch losses)."""
    key = jax.random.PRNGKey(config.seed)
    key, ik = jax.random.split(key)
    params = init_mlp(ik)
    opt = optim.adamw(config.lr, weight_decay=config.weight_decay)
    opt_state = opt.init(params)

    x = jnp.asarray(features, jnp.float32)
    y = jnp.asarray(labels, jnp.float32)
    n = x.shape[0]
    bs = min(config.batch_size, n)
    steps_per_epoch = max(n // bs, 1)

    @jax.jit
    def step(params, opt_state, xb, yb, key):
        loss, grads = jax.value_and_grad(_bce_loss)(params, xb, yb, key, config.dropout)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    losses = []
    for epoch in range(config.epochs):
        key, pk = jax.random.split(key)
        perm = jax.random.permutation(pk, n)
        epoch_loss = 0.0
        for s in range(steps_per_epoch):
            idx = jax.lax.dynamic_slice_in_dim(perm, s * bs, bs)
            key, dk = jax.random.split(key)
            params, opt_state, loss = step(params, opt_state, x[idx], y[idx], dk)
            epoch_loss += float(loss)
        losses.append(epoch_loss / steps_per_epoch)
    return params, losses


@functools.partial(jax.jit, static_argnames=("k",))
def rerank_topk_scored(
    params: dict,
    features: jnp.ndarray,  # [Q, C, 7] similarity-ordered candidates
    cand_idx: jnp.ndarray,  # [Q, C]
    k: int,
    valid: jnp.ndarray | None = None,  # [Q, C] — False for padded slots
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Re-score candidates with f_phi; return (top-K ids, their f_phi scores).

    The returned scores are the MLP logits that *produced* the ordering, so
    serving code can report the ranking signal actually used (not the
    pre-rerank similarities, which may order differently).
    """
    scores = mlp_forward(params, features)  # [Q, C]
    if valid is not None:
        scores = jnp.where(valid, scores, -1e30)
    top_scores, order = jax.lax.top_k(scores, k)
    return jnp.take_along_axis(cand_idx, order, axis=1), top_scores


def rerank_topk(
    params: dict,
    features: jnp.ndarray,
    cand_idx: jnp.ndarray,
    k: int,
    valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Ids-only wrapper around `rerank_topk_scored`."""
    return rerank_topk_scored(params, features, cand_idx, k, valid)[0]
