"""Serving-path retrieval: embed query -> similarity -> top-K (Eq. 2).

The hot loop the paper constrains to single-digit milliseconds. Two
implementations share one interface:

  * `rank_dense` — jnp matmul + argsort (the CPU production path; also the
    oracle for the Pallas kernel);
  * `repro.kernels.topk_sim.ops.topk_sim` — the TPU-native fused
    similarity+top-K Pallas kernel for pod-co-located routers (DESIGN.md §4).

Candidate masking supports MetaTool-style per-query candidate subsets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["similarities", "rank_dense", "topk_dense"]

NEG_INF = -1e30


def similarities(query_emb: jnp.ndarray, tool_emb: jnp.ndarray) -> jnp.ndarray:
    """Cosine similarity assuming unit-normalized rows. [Q,D]x[T,D] -> [Q,T]."""
    return query_emb @ tool_emb.T


@functools.partial(jax.jit, static_argnames=("k",))
def topk_dense(
    query_emb: jnp.ndarray,
    tool_emb: jnp.ndarray,
    k: int,
    candidate_mask: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k (scores, indices) per query. candidate_mask: [Q,T] {0,1} or None."""
    sims = similarities(query_emb, tool_emb)
    if candidate_mask is not None:
        sims = jnp.where(candidate_mask > 0, sims, NEG_INF)
    return jax.lax.top_k(sims, k)


def rank_dense(
    query_emb: np.ndarray,
    tool_emb: np.ndarray,
    k: int,
    candidate_mask: np.ndarray | None = None,
) -> np.ndarray:
    """Numpy convenience wrapper returning indices only."""
    _, idx = topk_dense(
        jnp.asarray(query_emb),
        jnp.asarray(tool_emb),
        k,
        None if candidate_mask is None else jnp.asarray(candidate_mask),
    )
    return np.asarray(idx)
