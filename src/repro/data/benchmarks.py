"""Synthetic tool-selection benchmarks matched to MetaTool / ToolBench.

The real datasets are not available offline (repro band 2/5); these generators
reproduce the *structure* the paper's results depend on (DESIGN.md §2):

  * scale — `metatool_like`: 199 tools / 4,287 queries / ~10-candidate subsets
    / 4 subtask types; `toolbench_like`: 2,413 tools / 600 queries / 46
    categories / full-corpus retrieval;
  * failure modes — opaque (brand-heavy) descriptions, semantic decoys,
    lexical-overlap traps, low-similarity regimes (App. A.7);
  * the lexical/semantic split — MetaTool-like queries paraphrase (low token
    overlap → dense ≫ BM25), ToolBench-like queries quote API names and
    description tokens (high token overlap → BM25 ≥ dense), matching Table 4.

Everything is deterministic in `seed`.

Description composition per tool (length L, opacity o):
    [name token] + (1-o)·L functional words + o·L generic words + stopwords
where functional words split between *tool-specific* and *topic-shared*
vocabulary, and decoy tools swap part of their functional words for another
topic's shared vocabulary (similar description, different function).

Query composition per ground-truth tool (length L):
    lexical_overlap·L tokens copied verbatim from the description (BM25
    signal) + topic_word_frac·L topic-shared words + remaining tool-specific
    *query-side* words (dense-only signal) + optional name mention + stopwords.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.embedding.bag_encoder import BagEncoder
from repro.embedding.vocab import Vocab, make_vocab

__all__ = [
    "Benchmark",
    "SUBTASKS",
    "make_metatool_like",
    "make_toolbench_like",
    "make_benchmark",
    "scale_tool_corpus",
]

SUBTASKS = ("similar", "scenario", "reliability", "multi")


@dataclasses.dataclass
class Benchmark:
    name: str
    vocab: Vocab
    # tools
    desc_tokens: List[np.ndarray]  # ragged, per tool
    tool_category: np.ndarray  # [T] int
    tool_topic: np.ndarray  # [T] int   (latent; analysis only — never used by methods)
    tool_opacity: np.ndarray  # [T] float (latent; analysis only)
    # queries
    query_tokens: List[np.ndarray]  # ragged, per query
    relevant: List[np.ndarray]  # ground-truth tool indices per query
    candidates: Optional[List[np.ndarray]]  # candidate subset per query, or None
    subtask: np.ndarray  # [Q] int index into SUBTASKS
    # split (70/30, deterministic — paper §5.5)
    train_idx: np.ndarray
    test_idx: np.ndarray

    @property
    def n_tools(self) -> int:
        return len(self.desc_tokens)

    @property
    def n_queries(self) -> int:
        return len(self.query_tokens)

    def relevance_matrix(self) -> np.ndarray:
        """Dense [Q, T] binary relevance."""
        rel = np.zeros((self.n_queries, self.n_tools), dtype=np.float32)
        for j, r in enumerate(self.relevant):
            rel[j, r] = 1.0
        return rel

    def candidate_mask(self) -> np.ndarray:
        """[Q, T] 1 where a tool may be ranked for the query."""
        if self.candidates is None:
            return np.ones((self.n_queries, self.n_tools), dtype=np.float32)
        m = np.zeros((self.n_queries, self.n_tools), dtype=np.float32)
        for j, c in enumerate(self.candidates):
            m[j, c] = 1.0
        return m


def _sample_description(
    rng: np.random.Generator,
    vocab: Vocab,
    topic: int,
    tool_id: int,
    opacity: float,
    length: int,
    decoy_topic: int | None,
    tool_word_frac: float,
) -> np.ndarray:
    """Tool description tokens; see module docstring."""
    toks: List[int] = [vocab.name_token(tool_id)]  # every description brands itself
    n_body = max(length - 1, 4)
    n_func = int(round(n_body * (1.0 - opacity)))
    n_func = max(n_func, 1)  # even opaque tools leak one functional word
    n_generic = n_body - n_func
    n_tool = int(round(n_func * tool_word_frac))
    n_topic = n_func - n_tool
    if n_tool > 0:
        toks.extend(rng.choice(vocab.desc_words(tool_id), size=n_tool, replace=True))
    if n_topic > 0:
        toks.extend(rng.choice(vocab.topic_desc_words(topic), size=n_topic, replace=True))
    if decoy_topic is not None and n_func >= 2:
        # semantic decoy: replace ~40% of functional words with another topic's
        # surface vocabulary (similar description, different function — App. A.7)
        n_swap = max(1, int(0.4 * n_func))
        swap = rng.choice(vocab.topic_desc_words(decoy_topic), size=n_swap, replace=True)
        toks[1 : 1 + n_swap] = [int(w) for w in swap]
    if n_generic > 0:
        toks.extend(rng.choice(vocab.generic_words(), size=n_generic, replace=True))
    toks.extend(rng.choice(vocab.stop_words(), size=2, replace=True))
    return np.array(toks, dtype=np.int64)


def _sample_query(
    rng: np.random.Generator,
    vocab: Vocab,
    desc_tokens: List[np.ndarray],
    tool_topic: np.ndarray,
    gt: np.ndarray,
    lexical_overlap: float,
    topic_word_frac: float,
    name_mention_p: float,
    length: int,
    noise_words: int,
    hard: bool = False,
) -> np.ndarray:
    """Query tokens for ground-truth tool(s) `gt`; see module docstring.

    `hard` queries are irreducibly ambiguous: their semantic words name the
    function *family* (topic query bank) rather than the tool — the
    low-similarity regime of App. A.7 where no embedding method can fully
    resolve the tool.
    """
    toks: List[int] = []
    per_tool = max(length // max(len(gt), 1), 3)
    for t in gt:
        t = int(t)
        topic = int(tool_topic[t])
        n_copy = int(rng.binomial(per_tool, lexical_overlap))
        n_topic = int(rng.binomial(per_tool, topic_word_frac))
        n_sem = max(per_tool - n_copy - n_topic, 1)
        if n_copy > 0 and len(desc_tokens[t]) > 0:
            toks.extend(rng.choice(desc_tokens[t], size=n_copy, replace=True))
        if n_topic > 0:
            toks.extend(
                rng.choice(vocab.topic_desc_words(topic), size=n_topic, replace=True)
            )
        sem_bank = vocab.topic_query_words(topic) if hard else vocab.query_words(t)
        toks.extend(rng.choice(sem_bank, size=n_sem, replace=True))
        if rng.random() < name_mention_p:
            toks.append(vocab.name_token(t))
    if noise_words > 0:
        toks.extend(rng.choice(vocab.stop_words(), size=noise_words, replace=True))
    return np.array(toks, dtype=np.int64)


def make_benchmark(
    *,
    name: str,
    n_tools: int,
    n_queries: int,
    n_topics: int,
    n_categories: int,
    candidate_set_size: int | None,
    lexical_overlap: float,
    topic_word_frac: float,
    name_mention_p: float,
    opacity_beta: tuple[float, float] = (1.2, 3.0),
    decoy_fraction: float = 0.20,
    tool_word_frac: float = 0.65,
    function_spread: float = 0.9,
    desc_len: int = 12,
    query_len: int = 9,
    query_noise_words: int = 2,
    subtask_mix: tuple[float, float, float, float] = (0.23, 0.42, 0.23, 0.12),
    multi_tool_max: int = 3,
    reliability_extra_noise: int = 4,
    hard_query_frac: float = 0.12,
    candidate_style: str = "topic",  # "topic" | "function_nn" (hard pools)
    train_frac: float = 0.7,
    seed: int = 0,
    tool_word_noise: float = 0.45,
    topic_word_noise: float = 0.50,
) -> Benchmark:
    rng = np.random.default_rng(seed)
    tool_topic = rng.integers(0, n_topics, size=n_tools)
    vocab = make_vocab(
        tool_topic=tool_topic,
        n_topics=n_topics,
        function_spread=function_spread,
        tool_word_noise=tool_word_noise,
        topic_word_noise=topic_word_noise,
        seed=seed + 1,
    )

    # ---- tools ----------------------------------------------------------
    # categories group topics (S2's category feature; ToolBench has 46)
    topic_category = rng.integers(0, n_categories, size=n_topics)
    tool_category = topic_category[tool_topic]
    tool_opacity = rng.beta(*opacity_beta, size=n_tools)
    # decoys: a fraction of tools borrows surface vocabulary from another topic
    is_decoy = rng.random(n_tools) < decoy_fraction
    decoy_topic = np.where(is_decoy, rng.integers(0, n_topics, size=n_tools), -1)
    desc_tokens: List[np.ndarray] = []
    for i in range(n_tools):
        dt = (
            int(decoy_topic[i])
            if decoy_topic[i] >= 0 and decoy_topic[i] != tool_topic[i]
            else None
        )
        desc_tokens.append(
            _sample_description(
                rng,
                vocab,
                int(tool_topic[i]),
                i,
                float(tool_opacity[i]),
                desc_len + int(rng.integers(-2, 3)),
                dt,
                tool_word_frac,
            )
        )

    # ---- queries --------------------------------------------------------
    subtask = rng.choice(len(SUBTASKS), size=n_queries, p=np.array(subtask_mix))
    query_tokens: List[np.ndarray] = []
    relevant: List[np.ndarray] = []
    for j in range(n_queries):
        st = SUBTASKS[subtask[j]]
        if st == "multi":
            k = int(rng.integers(2, multi_tool_max + 1))
            gt = rng.choice(n_tools, size=k, replace=False)
        else:
            gt = np.array([int(rng.integers(0, n_tools))])
        noise = query_noise_words + (reliability_extra_noise if st == "reliability" else 0)
        query_tokens.append(
            _sample_query(
                rng,
                vocab,
                desc_tokens,
                tool_topic,
                gt,
                lexical_overlap,
                topic_word_frac,
                name_mention_p,
                query_len + int(rng.integers(-2, 3)),
                noise,
                hard=bool(rng.random() < hard_query_frac),
            )
        )
        relevant.append(np.sort(gt))

    # ---- candidate subsets (MetaTool-style) ------------------------------
    candidates: Optional[List[np.ndarray]] = None
    if candidate_set_size is not None:
        enc = BagEncoder(vocab)
        tool_emb = enc.encode(desc_tokens)  # [T, D] for hard-distractor mining
        sims_tt = tool_emb @ tool_emb.T
        np.fill_diagonal(sims_tt, -np.inf)
        candidates = []
        for j in range(n_queries):
            gt = relevant[j]
            st = SUBTASKS[subtask[j]]
            n_fill = max(candidate_set_size - len(gt), 0)
            pool: List[int] = []
            if candidate_style == "function_nn":
                # ToolBench-style hard pools: distractors are the nearest
                # tools in *function* space (intra-category confusables)
                f = vocab.tool_function
                for t in gt:
                    nn = np.argsort(-(f @ f[int(t)]))
                    pool.extend(int(x) for x in nn[1 : n_fill + 2])
            elif st == "similar":
                # hardest split: distractors are the gt tools' nearest
                # neighbours in description-embedding space
                for t in gt:
                    pool.extend(np.argsort(-sims_tt[t])[:n_fill].tolist())
            # pad with same-topic (functionally adjacent), then random tools
            same_topic = np.flatnonzero(tool_topic == tool_topic[gt[0]])
            pool.extend(rng.permutation(same_topic).tolist())
            pool.extend(rng.permutation(n_tools).tolist())
            seen = set(int(t) for t in gt)
            cand = [int(t) for t in gt]
            for t in pool:
                if len(cand) >= candidate_set_size:
                    break
                if t not in seen:
                    cand.append(int(t))
                    seen.add(int(t))
            candidates.append(np.sort(np.array(cand, dtype=np.int64)))

    # ---- split ------------------------------------------------------------
    perm = rng.permutation(n_queries)
    n_train = int(round(train_frac * n_queries))
    train_idx = np.sort(perm[:n_train])
    test_idx = np.sort(perm[n_train:])

    return Benchmark(
        name=name,
        vocab=vocab,
        desc_tokens=desc_tokens,
        tool_category=tool_category.astype(np.int64),
        tool_topic=tool_topic.astype(np.int64),
        tool_opacity=tool_opacity.astype(np.float32),
        query_tokens=query_tokens,
        relevant=relevant,
        candidates=candidates,
        subtask=subtask.astype(np.int64),
        train_idx=train_idx,
        test_idx=test_idx,
    )


def scale_tool_corpus(
    table: np.ndarray,
    n_tools: int,
    seed: int = 0,
    noise: float = 0.02,
) -> np.ndarray:
    """Tile + perturb a real tool table to MCP-registry scale (PR 3).

    The paper's tables stop at 2,413 tools; public MCP registries reach tens
    of thousands. This scaler preserves the structure index benchmarks care
    about: row `i` is a perturbed clone of source row `i % T` (provenance by
    modulo), so the scaled corpus keeps the real table's topic geometry —
    clusters of near-duplicate tools around each true tool direction, the
    regime where IVF coarse quantization must still separate neighbors. The
    first `T` rows are the original table bit-exact; clones get iid gaussian
    perturbation (`noise` per dimension) and are re-unit-normalized.
    Deterministic in `seed`.
    """
    base = np.asarray(table, np.float32)
    t = base.shape[0]
    assert n_tools >= t, f"cannot scale {t} tools down to {n_tools}"
    reps = -(-n_tools // t)  # ceil
    big = np.tile(base, (reps, 1))[:n_tools].copy()
    rng = np.random.default_rng(seed)
    clones = big[t:]
    clones += noise * rng.standard_normal(size=clones.shape).astype(np.float32)
    clones /= np.maximum(np.linalg.norm(clones, axis=-1, keepdims=True), 1e-9)
    return big


def make_metatool_like(seed: int = 0, n_tools: int = 199, n_queries: int = 4287) -> Benchmark:
    """199 tools, 4,287 queries, ~10-candidate subsets, 4 subtask types.

    Paraphrase-style queries: low lexical overlap (dense ≫ BM25, Table 4) and
    a rich outcome log (~13 positives/tool in the 70% train split).
    """
    return make_benchmark(
        name="metatool-like",
        n_tools=n_tools,
        n_queries=n_queries,
        n_topics=max(n_tools // 5, 4),
        n_categories=24,
        candidate_set_size=10,
        lexical_overlap=0.06,
        topic_word_frac=0.30,  # shared-topic tokens: BM25 gets topic-level signal only
        name_mention_p=0.02,
        opacity_beta=(1.0, 4.0),
        decoy_fraction=0.15,
        function_spread=1.05,
        hard_query_frac=0.14,
        tool_word_noise=0.35,
        query_noise_words=0,
        reliability_extra_noise=2,
        subtask_mix=(0.232, 0.420, 0.232, 0.116),  # 995/1800/995/497 of 4287
        seed=seed,
    )


def make_toolbench_like(seed: int = 0, n_tools: int = 2413, n_queries: int = 600) -> Benchmark:
    """2,413 APIs, 46 categories, 600 queries, hard candidate pools.

    API-quoting queries (lexical overlap ⇒ BM25 ≥ dense, Table 4) and a
    sparse outcome log (<0.15 positives/tool — the regime where the paper's
    MLP re-ranker hurts). The paper's random baseline (R@5=0.829) implies
    evaluation within small retrieved candidate pools rather than the full
    corpus, so we rank within 6-tool pools of function-space nearest
    neighbours (intra-category confusables, the G1-Category setting).
    """
    return make_benchmark(
        name="toolbench-like",
        n_tools=n_tools,
        n_queries=n_queries,
        n_topics=max(n_tools // 8, 4),
        n_categories=46,
        candidate_set_size=6,
        candidate_style="function_nn",
        lexical_overlap=0.18,
        topic_word_frac=0.10,
        name_mention_p=0.05,
        function_spread=0.9,
        tool_word_noise=0.40,
        query_noise_words=1,
        hard_query_frac=0.27,
        # G1-Instruction / G1-Category / G2-Instruction ≈ single, intra-category,
        # multi-tool thirds (§5.1)
        subtask_mix=(0.17, 0.33, 0.17, 0.33),
        multi_tool_max=3,
        seed=seed,
    )
