"""Synthetic LM token pipeline for backend training (deterministic, shardable).

A first-order Markov source over the model's vocabulary with Zipfian
stationary distribution — enough structure that a ~100M model's loss visibly
drops over a few hundred steps (the end-to-end training deliverable) while
staying fully offline and seed-deterministic.

The iterator yields host numpy batches; each data-parallel process would
slice `[process_index::process_count]` in a real multi-host launch (the
single-process CPU container yields the full global batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["LMDataConfig", "synthetic_lm_batches"]


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    batch_size: int = 8
    seq_len: int = 256
    seed: int = 0
    branching: int = 64  # successor fan-out per token (Markov structure)
    zipf_a: float = 1.2


def _zipf_probs(n: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** a
    return p / p.sum()


def synthetic_lm_batches(
    cfg: ModelConfig, data: LMDataConfig
) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(data.seed)
    v = cfg.vocab_size
    base = _zipf_probs(v, data.zipf_a)
    # per-token successor tables: token t -> `branching` likely successors
    succ = rng.choice(v, size=(min(v, 4096), data.branching), p=base)

    def sample_seq(r: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, dtype=np.int32)
        t = int(r.choice(v, p=base))
        for i in range(length):
            out[i] = t
            if r.random() < 0.85:  # follow Markov structure
                t = int(succ[t % succ.shape[0], r.integers(0, data.branching)])
            else:  # occasional jump
                t = int(r.choice(v, p=base))
        return out

    step = 0
    while True:
        r = np.random.default_rng((data.seed, step))
        if cfg.n_codebooks:
            toks = np.stack(
                [
                    np.stack(
                        [sample_seq(r, data.seq_len) % v for _ in range(cfg.n_codebooks)],
                        axis=-1,
                    )
                    for _ in range(data.batch_size)
                ]
            )
        else:
            toks = np.stack([sample_seq(r, data.seq_len) for _ in range(data.batch_size)])
        batch: Dict[str, np.ndarray] = {"tokens": toks}
        if cfg.cross_attn_every:
            # stubbed vision tower output (DESIGN.md §5)
            batch["image_embeds"] = r.normal(
                size=(data.batch_size, cfg.n_image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        step += 1
        yield batch
