"""Frozen bag-of-word-vectors encoder (the production router's e(.), §5.5).

Stands in for all-MiniLM-L6-v2: mean-pool word vectors, L2-normalize. The
encoder is deliberately *frozen* — OATS-S1 changes only the stored tool
vectors, never the encoder (paper §4.1), and OATS-S3 composes a trainable
adapter head on top of this encoder's output (paper §4.3).

Both a ragged (list-of-token-arrays) numpy path — used by the offline
benchmark/evaluation code — and a padded jnp path (used inside jitted serving
and training code) are provided and agree exactly.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.embedding.vocab import Vocab

__all__ = ["BagEncoder"]


class BagEncoder:
    def __init__(self, vocab: Vocab):
        self.vocab = vocab
        self.word_vecs = vocab.word_vecs  # [V, 384] float32
        self._word_vecs_j = jnp.asarray(self.word_vecs)

    @property
    def dim(self) -> int:
        return self.word_vecs.shape[1]

    # ---- ragged numpy path (offline) ------------------------------------
    def encode(self, token_lists: Sequence[np.ndarray]) -> np.ndarray:
        out = np.zeros((len(token_lists), self.dim), dtype=np.float32)
        for i, toks in enumerate(token_lists):
            if len(toks) == 0:
                continue
            v = self.word_vecs[np.asarray(toks)].mean(axis=0)
            n = np.linalg.norm(v)
            out[i] = v / max(n, 1e-9)
        return out

    def encode_one(self, tokens: np.ndarray) -> np.ndarray:
        return self.encode([tokens])[0]

    # ---- padded jnp path (jittable, used in the serving hot path) -------
    def encode_padded(self, ids: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
        """ids: [B, L] int32, mask: [B, L] {0,1}. Returns [B, 384] unit rows."""
        vecs = jnp.take(self._word_vecs_j, ids, axis=0)  # [B, L, D]
        m = mask[..., None].astype(vecs.dtype)
        summed = (vecs * m).sum(axis=1)
        count = jnp.maximum(m.sum(axis=1), 1.0)
        mean = summed / count
        norm = jnp.maximum(jnp.linalg.norm(mean, axis=-1, keepdims=True), 1e-9)
        return mean / norm


def pad_token_lists(
    token_lists: Sequence[np.ndarray], max_len: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Pack ragged token lists into (ids, mask) for the padded path."""
    if max_len is None:
        max_len = max((len(t) for t in token_lists), default=1)
        max_len = max(max_len, 1)
    ids = np.zeros((len(token_lists), max_len), dtype=np.int32)
    mask = np.zeros((len(token_lists), max_len), dtype=np.int32)
    for i, toks in enumerate(token_lists):
        n = min(len(toks), max_len)
        ids[i, :n] = np.asarray(toks)[:n]
        mask[i, :n] = 1
    return ids, mask
