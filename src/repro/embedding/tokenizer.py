"""Hashed word tokenizer: real text -> synthetic-vocab token ids.

The synthetic benchmarks speak token ids; production routers speak strings.
This deterministic hashed tokenizer maps whitespace/punctuation-split words
into the stopword band of a `Vocab` (unknown surface forms carry no topic
signal, exactly like stopwords), while letting callers register known words
(tool names, domain terms) to specific ids. It makes the gateway API
string-capable end-to-end without pretending we have a trained BPE.
"""
from __future__ import annotations

import hashlib
import re
from typing import Dict, Iterable, List

import numpy as np

from repro.embedding.vocab import Vocab

__all__ = ["HashTokenizer"]

_SPLIT = re.compile(r"[^a-z0-9_]+")


class HashTokenizer:
    def __init__(self, vocab: Vocab):
        self.vocab = vocab
        self._known: Dict[str, int] = {}

    def register(self, word: str, token_id: int):
        """Pin a surface form (e.g. a tool name) to a vocabulary id."""
        assert 0 <= token_id < self.vocab.size
        self._known[word.lower()] = int(token_id)

    def register_tool_names(self, names: Iterable[str]):
        for i, name in enumerate(names):
            self.register(name, self.vocab.name_token(i))

    def _hash_to_stopword(self, word: str) -> int:
        h = int.from_bytes(hashlib.blake2s(word.encode(), digest_size=4).digest(), "little")
        return self.vocab.stop_block + (h % self.vocab.n_stop)

    def encode(self, text: str) -> np.ndarray:
        words = [w for w in _SPLIT.split(text.lower()) if w]
        ids: List[int] = []
        for w in words:
            ids.append(self._known.get(w, self._hash_to_stopword(w)))
        return np.array(ids or [self.vocab.stop_block], dtype=np.int64)
