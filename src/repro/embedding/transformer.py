"""MiniLM-shaped sentence encoder in pure JAX (all-MiniLM-L6-v2 geometry).

6 layers, d_model=384, 12 heads, d_ff=1536, mean-pool + L2 — ~22M parameters
with a 30k vocab, matching the paper's production encoder (§5.5, Table 1).

No pretrained weights exist offline, so semantic evaluations use the frozen
bag encoder (DESIGN.md §2); *this* module exists for (a) honest latency
measurements — per-request cost is weight-independent, so Table 1/6 numbers
include a real 22M-parameter CPU forward pass — and (b) the Stage-3
trainable-encoder path and router integration tests.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["EncoderConfig", "init_encoder", "encode", "encoder_param_count"]


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    vocab_size: int = 30522
    n_layers: int = 6
    d_model: int = 384
    n_heads: int = 12
    d_ff: int = 1536
    max_len: int = 256
    dtype: str = "float32"  # CPU routers run fp32


def init_encoder(key: jax.Array, cfg: EncoderConfig = EncoderConfig()) -> dict:
    keys = jax.random.split(key, 8)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    L = cfg.n_layers

    def norm(k, *shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[-2]))
        return jax.random.normal(k, shape, jnp.float32) * scale

    return {
        "tok_emb": jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (cfg.max_len, d), jnp.float32) * 0.02,
        # stacked per-layer weights: scan-friendly
        "wqkv": norm(keys[2], L, d, 3 * d),
        "wo": norm(keys[3], L, d, d),
        "w1": norm(keys[4], L, d, f),
        "w2": norm(keys[5], L, f, d),
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def encoder_param_count(params: dict) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def _layer_norm(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * scale


def _block(x, mask, wqkv, wo, w1, w2, ln1, ln2, n_heads):
    b, s, d = x.shape
    h = _layer_norm(x, ln1)
    qkv = h @ wqkv  # [B, S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // n_heads
    q = q.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(hd)  # [B, H, S, S]
    att = jnp.where(mask[:, None, None, :] > 0, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d) @ wo
    x = x + o
    h = _layer_norm(x, ln2)
    x = x + jax.nn.gelu(h @ w1) @ w2
    return x


@functools.partial(jax.jit, static_argnames=("n_heads",))
def encode(
    params: dict, ids: jnp.ndarray, mask: jnp.ndarray, n_heads: int = 12
) -> jnp.ndarray:
    """ids, mask: [B, S] -> [B, 384] unit embeddings (mean-pool, §5.5)."""
    s = ids.shape[1]
    x = jnp.take(params["tok_emb"], ids, axis=0) + params["pos_emb"][:s][None]

    def body(x, layer):
        wqkv, wo, w1, w2, ln1, ln2 = layer
        return _block(x, mask, wqkv, wo, w1, w2, ln1, ln2, n_heads), None

    x, _ = jax.lax.scan(
        body,
        x,
        (
            params["wqkv"],
            params["wo"],
            params["w1"],
            params["w2"],
            params["ln1"],
            params["ln2"],
        ),
    )
    x = _layer_norm(x, params["ln_f"])
    m = mask[..., None].astype(x.dtype)
    pooled = (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)
