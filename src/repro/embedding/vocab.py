"""Topic/tool-structured synthetic vocabulary with frozen word vectors.

The offline container has neither MetaTool/ToolBench nor all-MiniLM-L6-v2, so
we reproduce the *geometry class* the paper's analysis relies on (DESIGN.md §2).

Latent structure:
  * topics: unit centroids c_t (function families, e.g. "meeting transcripts");
  * tools: per-tool function vector f_i = unit(c_topic(i) + spread * g_i) —
    each tool occupies a resolvable sub-region of its topic;
  * words: every word vector sits near one of {topic centroid, tool function
    vector, generic-SaaS centroid, isotropic noise}.

Word id layout (contiguous blocks):
  [0, n_topics*topic_words)                 topic-shared description words
  [.., + n_topics*topic_words)              topic-shared query-side words
  [.., + n_tools*tool_desc_words)           tool-specific description words
  [.., + n_tools*tool_query_words)          tool-specific query-side words
  [.., + n_generic)                         generic/brand/marketing words
  [.., + n_stop)                            stopwords (scattered)
  [.., + n_tools)                           unique tool-name tokens (opaque)

Tool-specific *query* words are token-disjoint from description words: they
model paraphrase — semantically adjacent (same f_i neighbourhood) but with no
lexical overlap, which is what separates dense retrieval from BM25.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Vocab", "make_vocab"]

EMBED_DIM = 384  # all-MiniLM-L6-v2 dimension (paper §5.5)


def _unit(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _perturb(rng: np.random.Generator, base: np.ndarray, sigma: float, n: int) -> np.ndarray:
    """n unit vectors at controlled angular distance from `base`:
    unit(base + sigma * unit(g)) => cos(base, out) ~= 1/sqrt(1+sigma^2).

    The noise *norm* is sigma (not sigma per coordinate) — in 384-d,
    per-coordinate Gaussian noise would have norm sigma*sqrt(384) and swamp
    the unit centroid entirely.
    """
    g = _unit(rng.normal(size=(n, base.shape[-1])))
    return _unit(base[None, :] + sigma * g)


@dataclasses.dataclass
class Vocab:
    """Frozen synthetic vocabulary."""

    word_vecs: np.ndarray  # [V, 384] float32, unit rows
    n_topics: int
    n_tools: int
    topic_words: int
    tool_desc_words: int
    tool_query_words: int
    n_generic: int
    n_stop: int
    topic_centroids: np.ndarray  # [n_topics, 384]
    tool_function: np.ndarray  # [n_tools, 384] latent f_i (analysis only)
    generic_centroid: np.ndarray  # [384]

    # ---- block offsets -------------------------------------------------
    @property
    def topic_block(self) -> int:
        return 0

    @property
    def topic_query_block(self) -> int:
        return self.n_topics * self.topic_words

    @property
    def tool_desc_block(self) -> int:
        return self.topic_query_block + self.n_topics * self.topic_words

    @property
    def tool_query_block(self) -> int:
        return self.tool_desc_block + self.n_tools * self.tool_desc_words

    @property
    def generic_block(self) -> int:
        return self.tool_query_block + self.n_tools * self.tool_query_words

    @property
    def stop_block(self) -> int:
        return self.generic_block + self.n_generic

    @property
    def name_block(self) -> int:
        return self.stop_block + self.n_stop

    @property
    def size(self) -> int:
        return self.name_block + self.n_tools

    # ---- word-id accessors ----------------------------------------------
    def topic_desc_words(self, topic: int) -> np.ndarray:
        b = self.topic_block + topic * self.topic_words
        return np.arange(b, b + self.topic_words)

    def topic_query_words(self, topic: int) -> np.ndarray:
        b = self.topic_query_block + topic * self.topic_words
        return np.arange(b, b + self.topic_words)

    def desc_words(self, tool: int) -> np.ndarray:
        b = self.tool_desc_block + tool * self.tool_desc_words
        return np.arange(b, b + self.tool_desc_words)

    def query_words(self, tool: int) -> np.ndarray:
        b = self.tool_query_block + tool * self.tool_query_words
        return np.arange(b, b + self.tool_query_words)

    def generic_words(self) -> np.ndarray:
        return np.arange(self.generic_block, self.generic_block + self.n_generic)

    def stop_words(self) -> np.ndarray:
        return np.arange(self.stop_block, self.stop_block + self.n_stop)

    def name_token(self, tool: int) -> int:
        assert tool < self.n_tools
        return self.name_block + tool


def make_vocab(
    *,
    tool_topic: np.ndarray,  # [n_tools] topic assignment
    n_topics: int,
    topic_words: int = 12,
    tool_desc_words: int = 8,
    tool_query_words: int = 8,
    n_generic: int = 160,
    n_stop: int = 64,
    function_spread: float = 0.9,  # tool sub-region spread within its topic (angular)
    topic_word_noise: float = 0.50,
    tool_word_noise: float = 0.45,
    generic_noise: float = 0.40,
    seed: int = 0,
) -> Vocab:
    """Build the frozen vocabulary + word-vector table."""
    rng = np.random.default_rng(seed)
    n_tools = len(tool_topic)
    centroids = _unit(rng.normal(size=(n_topics, EMBED_DIM)))
    generic_centroid = _unit(rng.normal(size=(EMBED_DIM,)))
    tool_function = np.stack(
        [
            _perturb(rng, centroids[tool_topic[i]], function_spread, 1)[0]
            for i in range(n_tools)
        ]
    )

    blocks = []
    # topic-shared description words
    for t in range(n_topics):
        blocks.append(_perturb(rng, centroids[t], topic_word_noise, topic_words))
    # topic-shared query-side words (paraphrase at topic granularity: used by
    # ambiguous queries that name the function family but not the tool)
    for t in range(n_topics):
        blocks.append(_perturb(rng, centroids[t], topic_word_noise, topic_words))
    # tool-specific description words (near f_i)
    for i in range(n_tools):
        blocks.append(_perturb(rng, tool_function[i], tool_word_noise, tool_desc_words))
    # tool-specific query words: same neighbourhood, disjoint tokens (paraphrase)
    for i in range(n_tools):
        blocks.append(_perturb(rng, tool_function[i], tool_word_noise, tool_query_words))
    # generic/brand words near the generic-SaaS centroid
    blocks.append(_perturb(rng, generic_centroid, generic_noise, n_generic))
    # stopwords: scattered, near-isotropic
    blocks.append(_unit(rng.normal(size=(n_stop, EMBED_DIM))))
    # tool-name tokens: opaque — near the generic centroid (a brand name tells
    # the encoder nothing about function: the `buildbetter` failure mode)
    blocks.append(_perturb(rng, generic_centroid, generic_noise, n_tools))

    word_vecs = np.concatenate(blocks, axis=0).astype(np.float32)
    return Vocab(
        word_vecs=word_vecs,
        n_topics=n_topics,
        n_tools=n_tools,
        topic_words=topic_words,
        tool_desc_words=tool_desc_words,
        tool_query_words=tool_query_words,
        n_generic=n_generic,
        n_stop=n_stop,
        topic_centroids=centroids.astype(np.float32),
        tool_function=tool_function.astype(np.float32),
        generic_centroid=generic_centroid.astype(np.float32),
    )
