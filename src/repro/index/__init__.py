"""Tool-index subsystem: pluggable similarity-scoring backends behind
`SemanticRouter.route_batch` (PR 3).

The paper keeps tool selection in the request path on a single-digit-ms CPU
budget; this package is what lets that hold as the tool table grows from
the paper's 2,413 entries to MCP-registry scale (25k-100k). Scoring is a
`ScorerBackend` built from one table snapshot, and `ToolIndexManager` keeps
the index consistent with the PR 2 swap/rollback protocol (exact fallback
while a rebuild is in flight — see `manager.py`).

Backend-selection guide
=======================

``dense`` — `DenseBackend` (default)
    Exact brute force: one jitted matmul + `lax.top_k`, candidate masks
    supported natively. Per-query cost O(T·D). Pick it when T is small
    (≲ 10k tools: on this CPU the whole batch scores in well under the
    budget), when results must be bit-exact (it is the oracle every other
    backend is validated against), or when queries carry candidate masks.
    Zero build cost beyond a device upload, so swap churn is nearly free.

``ivf`` — `IVFBackend`
    k-means coarse quantization: score C ≈ 4·√T centroids, visit the
    `nprobe` closest clusters, shortlist members with int8 codes
    (`models/quant` machinery), exact-re-rank the shortlist in fp32.
    Per-query cost O(C·D + nprobe·(T/C)·D) — at 100k tools ~60x less
    arithmetic than dense. Pick it when T ≳ 25k and approximate recall is
    acceptable (Recall@5 ≥ 0.98 vs exact at the default `nprobe=8`;
    raise `nprobe` to trade latency for recall). Builds take seconds at
    100k tools, so sustained swap churn serves through the exact fallback
    between rebuilds. No candidate-mask support (masked batches fall back).

``pallas`` — `PallasBackend`
    The fused score+top-K Pallas kernel (`kernels/topk_sim`): streams the
    table HBM→VMEM in tiles with a running top-K in scratch — exact
    results, no [Q, T] score matrix materialized. Pick it on TPU-backed
    routers at any scale where dense's HBM traffic is the bottleneck. On
    CPU it transparently serves the jnp reference (same numerics as
    ``dense``); `interpret=True` executes the kernel body on CPU for tests
    only. No candidate-mask support (masked batches fall back).

Sizing quickly: `benchmarks/index_bench.py` measures all three at 25k/50k/
100k synthetic tools (`data.benchmarks.scale_tool_corpus`) and records
qps + p99/query against the 10 ms budget in `BENCH_index.json`.
"""
from repro.index.base import NEG_INF, ScorerBackend
from repro.index.dense import DenseBackend
from repro.index.ivf import IVFBackend, IVFConfig
from repro.index.manager import ToolIndexManager
from repro.index.pallas_backend import PallasBackend

__all__ = [
    "NEG_INF",
    "ScorerBackend",
    "DenseBackend",
    "IVFBackend",
    "IVFConfig",
    "PallasBackend",
    "ToolIndexManager",
    "BACKENDS",
    "build_backend",
]

BACKENDS = {
    DenseBackend.name: DenseBackend,
    IVFBackend.name: IVFBackend,
    PallasBackend.name: PallasBackend,
}


def build_backend(kind: str, table, table_version: int, **opts) -> ScorerBackend:
    """Construct a registered backend over one table snapshot."""
    if kind not in BACKENDS:
        raise ValueError(f"unknown backend {kind!r} (available: {sorted(BACKENDS)})")
    return BACKENDS[kind](table, table_version, **opts)
