"""ScorerBackend: the contract every tool-index backend serves behind.

A backend is an *immutable* index built from one atomic table snapshot: it
captures `(table_version, table)` at build time and answers batched top-K
similarity queries against exactly that table until it is replaced. All
mutability lives one layer up in `ToolIndexManager`, which owns the
build/swap lifecycle — this split is what keeps the PR 2 swap/rollback
protocol intact: a backend can never serve scores from one version while
reporting another.

Contract (`topk`):

  * input `queries` is a `[Q, D]` float32 block of unit rows (the gateway's
    padded batch); `k` is the candidate count the caller wants back;
  * output is `(scores [Q, k] float32, indices [Q, k] int)` sorted by
    descending score per row. Slots that cannot be filled (masked-out, or
    fewer than `k` reachable candidates) carry the `NEG_INF` sentinel score
    shared with `core.retrieval` — callers already filter on
    `score > NEG_INF / 2`, so short results flow through `route_batch`
    unchanged;
  * `scores` must be the scores the final ranking was computed from
    (exact fp32 similarities after any approximate shortlist), so
    `RouteResult.scores` stays meaningful across backends;
  * backends that cannot honor per-query candidate masks declare
    `supports_masks = False`; `ToolIndexManager` routes masked batches to
    the exact dense fallback instead of calling them with one.
"""
from __future__ import annotations

from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.retrieval import NEG_INF

__all__ = ["NEG_INF", "ScorerBackend"]


@runtime_checkable
class ScorerBackend(Protocol):
    """Batched top-K similarity scoring over one immutable table snapshot."""

    name: str  # registry key ("dense" | "ivf" | "pallas")
    table_version: int  # ToolsDatabase version the index was built from
    n_tools: int  # rows in the indexed table
    supports_masks: bool  # can honor [Q, T] candidate masks natively

    def topk(
        self,
        queries: np.ndarray,  # [Q, D] float32 unit rows
        k: int,
        candidate_mask: Optional[np.ndarray] = None,  # [Q, T] {0,1} or None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(scores [Q, k], indices [Q, k]) by descending similarity."""
        ...
