"""DenseBackend: exact brute-force scoring — the default and the oracle.

This is the PR 1 serving path verbatim: one jitted `topk_dense` call (matmul
+ `lax.top_k`, optional per-query candidate masks) against a device-resident
copy of the table snapshot. It exists as a backend so the gateway stops
hardcoding it: the numerics are unchanged, only the ownership of the device
copy moved from `SemanticRouter._device_table` into the index layer.

Per-query cost is O(T·D) — at MCP-registry scale (100k tools) that is the
brute-force wall `IVFBackend` exists to avoid; dense remains the fallback
every other backend is validated against (and the path the manager serves
while an index rebuild is in flight).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import topk_dense

__all__ = ["DenseBackend"]


class DenseBackend:
    name = "dense"
    supports_masks = True
    # build == one device upload: the manager rebuilds inline on swap rather
    # than paying a thread spawn + duplicate fallback upload per version
    build_is_cheap = True

    def __init__(self, table: np.ndarray, table_version: int):
        table = np.asarray(table, np.float32)
        self.table_version = int(table_version)
        self.n_tools = table.shape[0]
        self._table_j = jnp.asarray(table)  # device-resident, built once

    def topk(
        self,
        queries: np.ndarray,
        k: int,
        candidate_mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        mask_j = None if candidate_mask is None else jnp.asarray(candidate_mask)
        scores, idx = topk_dense(jnp.asarray(queries), self._table_j, k, mask_j)
        return np.asarray(scores), np.asarray(idx)
