"""IVFBackend: coarse k-means quantization + int8 candidate scoring + exact
re-rank — sublinear per-query work for MCP-registry-scale tool tables.

Why: brute force is O(T·D) per query; at 100k tools that is ~40M MACs/query
and the 10 ms CPU budget starts to bind. IVF makes per-query work
O(C·D + nprobe·(T/C)·D): score C coarse centroids, visit only the `nprobe`
closest clusters, shortlist their members with int8 codes, and exact-re-rank
the shortlist in fp32. With the default C ≈ 4·√T and nprobe=8, a 100k-tool
query touches ~650 candidate rows instead of 100k.

Build (all deterministic in `config.seed`):

  * spherical k-means over the (unit-row) table — trained on a bounded
    sample (`train_sample`, FAISS-style) then one full assignment pass, so
    build cost stays O(T·C·D) not O(iters·T·C·D);
  * members stored CSR-style in cluster order (`member_ids` + `offsets`),
    so probing a cluster is a contiguous slice;
  * member embeddings stored as int8 codes with per-dimension scales,
    produced by `models/quant.quantize_tree` — the same symmetric
    per-channel machinery the serving pools use for weights. Candidate
    scoring never dequantizes: `score ≈ (q ⊙ scale) · codes^T`;
  * the fp32 snapshot is retained for the exact re-rank, so the scores a
    query returns are true similarities of the indexed table (the contract
    `RouteResult.scores` depends on).

Query: each query probes its `nprobe` coarse-closest clusters (expanded in
coarse order for the rare query whose probed clusters hold fewer than the
`rerank_multiplier · k` shortlist quota — tiny/skewed tables). Scoring is
*cluster-major*, not query-major: the batch's (query, cluster) pairs are
grouped by cluster, and each probed cluster is scored ONCE for all queries
probing it — one contiguous int8 slice (no index gather), one dtype
conversion, one [n_q_probing, cluster_size] GEMM. At batch 64 / 100k tools
this is ~4x faster than a per-query loop: the python overhead amortizes
over clusters instead of (query × cluster) pairs and the GEMMs are big
enough for BLAS. The shortlist is then re-ranked exactly per query and the
top-k emitted; rows with fewer than k reachable candidates pad `NEG_INF`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.core.retrieval import NEG_INF
from repro.models.quant import quantize_tree

__all__ = ["IVFConfig", "IVFBackend"]


@dataclasses.dataclass(frozen=True)
class IVFConfig:
    n_clusters: Optional[int] = None  # default: ~4·√T, clamped to [8, T//4]
    nprobe: int = 8  # clusters visited per query (floor; see shortlist quota)
    kmeans_iters: int = 6
    train_sample: int = 20_000  # k-means training subsample bound
    rerank_multiplier: int = 8  # exact-re-rank shortlist = multiplier · k
    seed: int = 0


def _unit_rows(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _chunked_argmax_sim(x: np.ndarray, centroids: np.ndarray, chunk: int = 8192) -> np.ndarray:
    """argmax_c <x_i, centroid_c> without materializing the full [N, C] block."""
    out = np.empty(x.shape[0], dtype=np.int32)
    for lo in range(0, x.shape[0], chunk):
        out[lo : lo + chunk] = np.argmax(x[lo : lo + chunk] @ centroids.T, axis=1)
    return out


class IVFBackend:
    name = "ivf"
    supports_masks = False

    def __init__(
        self,
        table: np.ndarray,
        table_version: int,
        config: IVFConfig = IVFConfig(),
        warm_start: Optional[np.ndarray] = None,
    ):
        """`warm_start`: centroids from a previous index over an earlier
        version of this table (`warm_start_state()`), used to seed k-means
        instead of random rows. Control-plane swaps move the table gently
        (centroid refinement preserves most geometry), so warm-started
        k-means converges in a fraction of the iterations — the manager
        passes it automatically on swap-triggered rebuilds. A shape-
        incompatible warm start (different cluster count/dim) is ignored."""
        table = np.asarray(table, np.float32)
        self.table_version = int(table_version)
        self.config = config
        self.n_tools, d = table.shape
        self._table = table  # fp32, for the exact re-rank
        rng = np.random.default_rng(config.seed)

        n_clusters = config.n_clusters or int(round(4 * math.sqrt(self.n_tools)))
        n_clusters = max(1, min(n_clusters, max(self.n_tools // 4, 1)))
        self.n_clusters = n_clusters

        # ---- spherical k-means (sampled train, full final assign) ---------
        if self.n_tools > config.train_sample:
            train = table[rng.choice(self.n_tools, config.train_sample, replace=False)]
        else:
            train = table
        if warm_start is not None and np.shape(warm_start) == (n_clusters, d):
            centroids = _unit_rows(np.asarray(warm_start, np.float32).copy())
        else:
            centroids = train[rng.choice(len(train), n_clusters, replace=False)].copy()
        prev_assign: Optional[np.ndarray] = None
        iters_run = 0
        for _ in range(config.kmeans_iters):
            assign = _chunked_argmax_sim(train, centroids)
            if prev_assign is not None and np.array_equal(assign, prev_assign):
                # converged: re-updating from an identical assignment is the
                # identity, so the remaining iterations are pure waste —
                # this is what makes a warm start cheap, not just safe
                break
            prev_assign = assign
            iters_run += 1
            sums = np.zeros_like(centroids)
            np.add.at(sums, assign, train)
            counts = np.bincount(assign, minlength=n_clusters)
            empty = counts == 0
            centroids = _unit_rows(sums / np.maximum(counts, 1)[:, None])
            if empty.any():  # re-seed dead centroids from random train rows
                centroids[empty] = train[rng.choice(len(train), int(empty.sum()))]
        self.kmeans_iters_run = iters_run
        self.centroids = centroids.astype(np.float32)

        # ---- inverted lists: CSR layout in cluster order ------------------
        assign = _chunked_argmax_sim(table, self.centroids)
        order = np.argsort(assign, kind="stable")
        self.member_ids = order.astype(np.int64)
        self.offsets = np.searchsorted(assign[order], np.arange(n_clusters + 1))

        # ---- int8 cluster storage (models/quant machinery) ----------------
        leaf = quantize_tree({"codes": table[order]})["codes"]
        if isinstance(leaf, dict):  # {"q": int8 [T, D], "scale": bf16 [1, D]}
            self._codes = np.asarray(leaf["q"])
            self._scale = np.asarray(leaf["scale"]).astype(np.float32).reshape(-1)
        else:  # tiny tables fall below quant's size floor; store fp32 codes
            self._codes = np.asarray(leaf, np.float32)
            self._scale = np.ones(d, np.float32)
        # query-time scratch: slice views instead of per-cluster aranges; the
        # conversion buffer is sized here but allocated per call (topk must
        # stay re-entrant — routers share backends across serving threads)
        self._pos = np.arange(self.n_tools, dtype=np.int64)
        self._max_cluster = int((self.offsets[1:] - self.offsets[:-1]).max(initial=1))
        self._dim = d

    def warm_start_state(self) -> np.ndarray:
        """Centroids to seed the next rebuild's k-means (see `warm_start`).

        `ToolIndexManager` calls this on the outgoing backend when a
        swap/rollback triggers a rebuild, cutting the dominant k-means cost
        of the 10-14 s build at registry scale."""
        return self.centroids

    # ------------------------------------------------------------------ query
    def topk(
        self,
        queries: np.ndarray,
        k: int,
        candidate_mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        assert candidate_mask is None, (
            "IVFBackend cannot honor candidate masks (tools outside the probed "
            "clusters would silently vanish); ToolIndexManager routes masked "
            "batches to the exact fallback"
        )
        q = np.asarray(queries, np.float32)
        n_q = q.shape[0]
        if n_q == 0:  # contract: any Q, including an empty batch
            return (
                np.full((0, k), NEG_INF, np.float32),
                np.zeros((0, k), np.int64),
            )
        cfg = self.config
        shortlist = max(cfg.rerank_multiplier * k, k)
        nprobe = min(cfg.nprobe, self.n_clusters)
        sizes = self.offsets[1:] - self.offsets[:-1]  # [C]

        # ---- probe selection: top-nprobe clusters per query ---------------
        qc = q @ self.centroids.T  # [Q, C]
        if nprobe < self.n_clusters:
            probes = np.argpartition(-qc, nprobe - 1, axis=1)[:, :nprobe]
        else:
            probes = np.broadcast_to(
                np.arange(self.n_clusters), (n_q, self.n_clusters)
            )
        under = np.flatnonzero(sizes[probes].sum(axis=1) < min(shortlist, self.n_tools))
        if len(under):
            probe_list = list(probes)
            quota = min(shortlist, self.n_tools)
            for j in under:
                # rare: probed clusters too small for the shortlist quota —
                # extend this query's probes in coarse order until it is met
                ranked = np.argsort(-qc[j], kind="stable")
                n_cand = np.cumsum(sizes[ranked])
                stop = int(np.searchsorted(n_cand, quota)) + 1
                probe_list[j] = ranked[: max(stop, nprobe)]
            pair_q = np.concatenate(
                [np.full(len(p), j, np.int64) for j, p in enumerate(probe_list)]
            )
            pair_c = np.concatenate(probe_list)
        else:
            pair_q = np.repeat(np.arange(n_q, dtype=np.int64), nprobe)
            pair_c = probes.ravel()

        # ---- cluster-major int8 scoring -----------------------------------
        # group the (query, cluster) pairs by cluster: each probed cluster is
        # scored once for ALL queries probing it — a contiguous codes slice
        # (no gather) and one GEMM per cluster instead of per pair
        order = np.argsort(pair_c, kind="stable")
        pair_q, pair_c = pair_q[order], pair_c[order]
        bounds = np.flatnonzero(np.diff(pair_c)) + 1
        starts = np.concatenate(([0], bounds))
        ends = np.concatenate((bounds, [len(pair_c)]))
        qs = q * self._scale  # fold the int8 scales into the queries once
        cand_scores: list = [[] for _ in range(n_q)]
        cand_pos: list = [[] for _ in range(n_q)]
        # one conversion buffer per CALL (not per cluster: allocation cost;
        # not per backend: concurrent topk calls would corrupt each other)
        convert_buf = np.empty((self._max_cluster, self._dim), np.float32)
        for a, b in zip(starts, ends):
            c = pair_c[a]
            lo, hi = self.offsets[c], self.offsets[c + 1]
            if hi == lo:
                continue
            block = convert_buf[: hi - lo]
            np.copyto(block, self._codes[lo:hi], casting="unsafe")
            scores = qs[pair_q[a:b]] @ block.T  # [n_q_probing, cluster_size]
            pos = self._pos[lo:hi]  # view, no arange
            for i, j in enumerate(pair_q[a:b]):
                cand_scores[j].append(scores[i])
                cand_pos[j].append(pos)

        # ---- per-query shortlist + exact fp32 re-rank ---------------------
        out_s = np.full((n_q, k), NEG_INF, np.float32)
        out_i = np.zeros((n_q, k), np.int64)
        for j in range(n_q):
            if not cand_pos[j]:
                continue
            approx = np.concatenate(cand_scores[j])
            pos = np.concatenate(cand_pos[j])
            if len(pos) > shortlist:
                sel = np.argpartition(-approx, shortlist)[:shortlist]
                pos = pos[sel]
            ids = self.member_ids[pos]
            exact = self._table[ids] @ q[j]
            kk = min(k, len(ids))
            if len(ids) > kk:
                top = np.argpartition(-exact, kk - 1)[:kk]
            else:
                top = np.arange(len(ids))
            top = top[np.argsort(-exact[top], kind="stable")]
            out_i[j, :kk] = ids[top]
            out_s[j, :kk] = exact[top]
        return out_s, out_i
