"""ToolIndexManager: version-tracked index lifecycle between the database
and the scorer backends.

The swap-compatibility problem this layer solves: an index (IVF clusters,
a device-resident table copy, Pallas tiles) is derived state over one table
snapshot, but `ToolsDatabase.swap_table`/`rollback` can land at any moment
— including mid-batch, including from the PR 2 control plane's guard. The
manager keeps the invariant that *served scores always come from the table
version they are reported under*:

  * every `topk` call starts from an atomic `db.snapshot()`;
  * if the built backend matches the snapshot version (and can honor the
    batch's candidate mask), it serves;
  * otherwise the call is served by the exact dense fallback **on the
    snapshot itself** — the PR 1 jitted `topk_dense` path with a
    version-keyed device cache, numerically identical to `DenseBackend` —
    and an async rebuild for the new version is kicked off (at most one
    in-flight build per version).

Rebuilds are also triggered eagerly: the manager registers a
`ToolsDatabase.add_swap_listener` hook at construction, so a control-plane
swap or guard rollback starts the rebuild immediately instead of on the
next unlucky request. `async_rebuild=False` makes builds synchronous (the
swap listener blocks until the index is fresh) — deterministic for tests
and offline jobs; serving processes keep the default. Backends whose build
is one device upload (`build_is_cheap`: dense, pallas) always rebuild
inline — under swap churn a rebuild thread per version costs more than the
build itself and doubles the uploads; only genuinely expensive builds
(IVF k-means) go to a background thread.

A failed build (bad table, backend bug) is counted in
`stats["build_failures"]` and leaves the fallback serving — an index is an
optimization, never a correctness dependency.
"""
from __future__ import annotations

import threading
import time  # time.sleep only; clocks come from repro.obs.clock
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.index.dense import DenseBackend
from repro.obs import clock
from repro.obs.metrics import MetricsRegistry, get_registry
from repro.router.tooldb import ToolsDatabase

__all__ = ["ToolIndexManager"]


class _IndexInstruments:
    """Preresolved metric handles (catalog: `repro.obs` docstring)."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.served = {
            "index": registry.counter("index_served_total", path="index"),
            "exact": registry.counter("index_served_total", path="exact"),
        }
        self.rebuilds = registry.counter("index_rebuilds_total")
        self.build_failures = registry.counter("index_build_failures_total")
        self.build_ms = registry.histogram("index_build_ms")


def _build_backend(kind: str, table: np.ndarray, table_version: int, **opts):
    # local import so manager <-> package __init__ stay cycle-free
    from repro.index import build_backend

    return build_backend(kind, table, table_version, **opts)


class ToolIndexManager:
    def __init__(
        self,
        db: ToolsDatabase,
        backend: str = "dense",
        backend_opts: Optional[dict] = None,
        async_rebuild: bool = True,
        watch_swaps: bool = True,
        metrics: Union[MetricsRegistry, bool, None] = None,
        bus: Optional["EventBus"] = None,  # repro.obs.events
    ):
        from repro.index import BACKENDS  # call-time import: no module cycle

        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r} (available: {sorted(BACKENDS)})"
            )
        self.db = db
        self.backend_kind = backend
        self.backend_opts = dict(backend_opts or {})
        # cheap builds (dense/pallas: one device upload) always run inline —
        # a rebuild thread per swap costs more than the build and doubles
        # uploads (listener build + fallback cache) under swap churn
        self._inline_build = bool(
            getattr(BACKENDS[backend], "build_is_cheap", False)
        )
        self.async_rebuild = async_rebuild and not self._inline_build
        self._lock = threading.Lock()
        # waiters for an in-flight build (refresh(block=True) must join the
        # running build, not duplicate a 10+ s k-means); shares self._lock
        self._build_cond = threading.Condition(self._lock)
        self._backend = None
        self._building_for: Optional[int] = None  # version with an in-flight build
        self._failed_for: Optional[int] = None  # version whose build failed
        self._fallback: Optional[DenseBackend] = None  # exact path, per version
        self.stats: Dict[str, int] = {
            "served_index": 0,
            "served_exact": 0,
            "rebuilds": 0,
            "build_failures": 0,
        }
        # telemetry mirrors of `stats` + rebuild lifecycle events; the bus
        # is a plain attribute so launchers can attach one to a manager a
        # router already built (`manager.bus = bus`)
        if metrics is False:
            self._obs: Optional[_IndexInstruments] = None
        else:
            registry = metrics if isinstance(metrics, MetricsRegistry) else get_registry()
            self._obs = _IndexInstruments(registry)
        self.bus = bus
        # which path served the calling thread's last topk ("index:<kind>" |
        # "exact"): thread-local so concurrent batches don't cross-stamp
        # their traces during a fallback-serving window
        self._tls = threading.local()
        # fail fast on misconfigured backend_opts: a tiny synchronous
        # validation build surfaces TypeError/ValueError at construction
        # instead of a silent build-failure loop behind the fallback
        _, probe_table = db.snapshot()
        _build_backend(
            backend, np.asarray(probe_table[:64]), -1, **self.backend_opts
        )
        self._watching = watch_swaps
        if watch_swaps:
            db.add_swap_listener(self._on_swap)
        self.refresh(block=not self.async_rebuild)

    # ------------------------------------------------------------- lifecycle
    def _on_swap(self, new_version: int) -> None:
        self.refresh(block=not self.async_rebuild)

    def close(self) -> None:
        """Unregister from the database's swap listeners (idempotent).

        A manager that is being retired (router torn down, backend
        reconfigured) must be closed, or the database keeps a strong
        reference and keeps triggering rebuilds — and keeps this manager's
        table copies alive — on every future swap.
        """
        if self._watching:
            self.db.remove_swap_listener(self._on_swap)
            self._watching = False

    def is_fresh(self) -> bool:
        """True when the built index matches the database's live version."""
        with self._lock:
            backend = self._backend
        return backend is not None and backend.table_version == self.db.table_version

    def wait_ready(self, timeout_s: float = 60.0, poll_s: float = 0.01) -> bool:
        """Block until the index is fresh (benchmarks/tests); True on success.

        Returns False immediately (not after the full timeout) when the
        build for the live version has already failed and nothing is
        retrying it — callers must check the result: False means the exact
        fallback is serving, not the configured backend.
        """
        deadline = clock.monotonic() + timeout_s
        while clock.monotonic() < deadline:
            if self.is_fresh():
                return True
            with self._lock:
                building = self._building_for is not None
                failed_version = self._failed_for
            if not building and failed_version == self.db.table_version:
                return False  # doomed: failed build, no retry in flight
            time.sleep(poll_s)
        return self.is_fresh()

    def refresh(self, block: bool = False) -> None:
        """Ensure a build for the current table version is done or in flight."""
        version, table = self.db.snapshot()
        with self._lock:
            if self._backend is not None and self._backend.table_version == version:
                return
            if self._building_for == version:
                if not block:
                    return  # one in-flight build per version is enough
                # join the in-flight build instead of duplicating it; when
                # it finishes (installed or failed) this refresh is done
                while self._building_for == version:
                    self._build_cond.wait()
                return
            if self._failed_for == version and not block:
                # this version's build already failed (counted in stats);
                # don't respawn a doomed build per serving call — the next
                # swap, or an explicit refresh(block=True), retries
                return
            self._building_for = version
        if block:
            self._build(version, np.asarray(table))
        else:
            threading.Thread(
                target=self._build,
                args=(version, np.asarray(table)),
                name=f"index-rebuild-v{version}",
                daemon=True,
            ).start()

    def _build(self, version: int, table: np.ndarray) -> None:
        bus, obs = self.bus, self._obs
        if bus is not None:
            bus.publish("rebuild_start", plane="index", version=version,
                        backend=self.backend_kind)
        t0 = clock.perf()
        opts = dict(self.backend_opts)
        with self._lock:
            prev = self._backend
        if prev is not None and hasattr(prev, "warm_start_state"):
            # swap-triggered rebuild: seed the new build from the outgoing
            # index's state (IVF k-means centroids). Control-plane swaps
            # move the table gently, so the warm start converges in a
            # fraction of the iterations; a stale/incompatible state is
            # validated and ignored by the backend, never an error.
            opts["warm_start"] = prev.warm_start_state()
        try:
            backend = _build_backend(self.backend_kind, table, version, **opts)
        except Exception as exc:
            with self._lock:
                self.stats["build_failures"] += 1
                self._failed_for = version
                if self._building_for == version:
                    self._building_for = None
                self._build_cond.notify_all()
            if obs is not None:
                obs.build_failures.inc()
            if bus is not None:
                bus.publish("rebuild_failure", plane="index", version=version,
                            backend=self.backend_kind, error=repr(exc))
            return  # the exact fallback keeps serving
        build_ms = clock.duration_ms(t0)
        with self._lock:
            # never replace a fresher index with a slower build's older one
            if self._backend is None or self._backend.table_version <= version:
                self._backend = backend
                self.stats["rebuilds"] += 1
            if self._building_for == version:
                self._building_for = None
            self._build_cond.notify_all()
        if obs is not None:
            obs.rebuilds.inc()
            obs.build_ms.record(build_ms)
        if bus is not None:
            bus.publish("rebuild_finish", plane="index", version=version,
                        backend=self.backend_kind, build_ms=build_ms)

    # ----------------------------------------------------------------- serve
    def topk(
        self,
        queries: np.ndarray,
        k: int,
        candidate_mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """(scores [Q, k], indices [Q, k], table_version) for this batch.

        The returned version is the snapshot the scores were computed from —
        the backend's when it serves, the fallback snapshot's otherwise.
        """
        version, table = self.db.snapshot()
        with self._lock:
            backend = self._backend
        if backend is None or backend.table_version != version:
            # cheap builds (a device upload) run inline — the PR 1 serving
            # path paid exactly this upload on version change; expensive
            # builds (IVF) go async and this batch serves the exact fallback
            self.refresh(block=self._inline_build)
            with self._lock:
                backend = self._backend
        maskable = candidate_mask is None or (
            backend is not None and backend.supports_masks
        )
        if backend is not None and backend.table_version == version and maskable:
            scores, idx = backend.topk(queries, k, candidate_mask)
            with self._lock:  # counters race under concurrent serving
                self.stats["served_index"] += 1
            self._tls.path = f"index:{self.backend_kind}"
            if self._obs is not None:
                self._obs.served["index"].inc()
            return scores, idx, version
        scores, idx = self._exact_topk(queries, table, version, k, candidate_mask)
        with self._lock:
            self.stats["served_exact"] += 1
        self._tls.path = "exact"
        if self._obs is not None:
            self._obs.served["exact"].inc()
        return scores, idx, version

    def last_path(self) -> str:
        """Which path served the calling thread's most recent `topk`."""
        return getattr(self._tls, "path", "unknown")

    def _exact_topk(
        self,
        queries: np.ndarray,
        table: np.ndarray,
        version: int,
        k: int,
        candidate_mask: Optional[np.ndarray],
    ) -> Tuple[np.ndarray, np.ndarray]:
        # the exact path IS a DenseBackend over the snapshot — one
        # implementation, so fallback and dense-index numerics are identical
        # by construction; rebuilt only on version change (a benign race can
        # at worst double-upload, exactly like the PR 1 gateway cache)
        fallback = self._fallback
        if fallback is None or fallback.table_version != version:
            fallback = DenseBackend(table, version)
            self._fallback = fallback
        return fallback.topk(queries, k, candidate_mask)
