"""PallasBackend: the fused score+top-K TPU kernel behind the serving path.

`kernels/topk_sim` streams the tool table HBM→VMEM in tiles and carries a
running top-K in scratch, so no global [Q, T] score matrix is ever
materialized — at 100k tools that is the difference between streaming and
spilling (see the kernel's module docstring). This backend is the wiring
that was missing: `topk_sim` existed but nothing served through it.

Backend selection is `ops.topk_sim`'s: the Pallas kernel on TPU, the jitted
jnp reference elsewhere, `interpret=True` to execute the kernel body on CPU
(tests pin kernel-vs-ref parity that way; interpret mode is a correctness
harness, not a performance path). The reference path computes the identical
matmul + `lax.top_k` as `DenseBackend`, so on CPU this backend is
bit-compatible with exact dense — the cross-backend consistency test relies
on that.

No candidate-mask support: the kernel scores every table row by design
(masks would break its streaming tile layout). The manager's exact fallback
covers masked batches.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.topk_sim.ops import topk_sim

__all__ = ["PallasBackend"]


class PallasBackend:
    name = "pallas"
    supports_masks = False
    build_is_cheap = True  # one device upload; manager rebuilds inline on swap

    def __init__(
        self,
        table: np.ndarray,
        table_version: int,
        use_pallas: Optional[bool] = None,  # None: auto (TPU -> kernel)
        interpret: bool = False,  # run the kernel body on CPU (tests)
    ):
        table = np.asarray(table, np.float32)
        self.table_version = int(table_version)
        self.n_tools = table.shape[0]
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._table_j = jnp.asarray(table)

    def topk(
        self,
        queries: np.ndarray,
        k: int,
        candidate_mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        assert candidate_mask is None, (
            "PallasBackend scores the full table (streaming kernel, no mask "
            "support); ToolIndexManager routes masked batches to the exact "
            "fallback"
        )
        scores, idx = topk_sim(
            jnp.asarray(queries),
            self._table_j,
            k,
            use_pallas=self.use_pallas,
            interpret=self.interpret,
        )
        return np.asarray(scores), np.asarray(idx)
