"""Pallas TPU kernels (validated with interpret=True on CPU):

  topk_sim        — the paper's serving hot spot: fused similarity + top-K
  flash_attention — backend prefill attention (causal + sliding window)
  ssd_scan        — Mamba-2 chunked state-space scan

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with TPU/CPU dispatch), ref.py (pure-jnp oracle).
"""
