"""Pallas TPU kernel: flash attention (online softmax), causal + window.

Backend-pool prefill hot spot. TPU-native tiling (DESIGN.md §4): q blocks of
[BLOCK_Q, hd] stay resident in VMEM while k/v stream through in [BLOCK_KV,
hd] tiles; the online-softmax running max/denominator/accumulator live in
VMEM scratch (HBM->VMEM once per tile — no [Sq, Skv] score matrix in HBM).
Both matmuls hit the MXU with 128-aligned contraction dims. Fully-masked
tiles (future tiles under causality, expired tiles under a sliding window)
are skipped via `pl.when`, which is what makes the windowed variant
sub-quadratic in wall-clock, not just in mask shape.

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost (sequential carry).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas", "BLOCK_Q", "BLOCK_KV"]

BLOCK_Q = 128
BLOCK_KV = 128
NEG = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
    *, sm_scale: float, causal: bool, window: int, q_offset: int, skv: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_lo = q_offset + qi * BLOCK_Q  # absolute position of the q tile start
    k_lo = ki * BLOCK_KV
    # tile-level skip: entirely in the future (causal) or expired (window)
    live = True
    if causal:
        live = k_lo <= q_lo + BLOCK_Q - 1
    if window:
        live = jnp.logical_and(live, k_lo + BLOCK_KV - 1 > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[0]  # [BQ, hd]
        k = k_ref[0]  # [BK, hd]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [BQ, BK]
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < skv  # kv padding
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_s[...]  # [BQ, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)  # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)  # [BQ, 1]
        l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [BH, Sq, hd]
    k: jnp.ndarray,  # [BH, Skv, hd]
    v: jnp.ndarray,  # [BH, Skv, hd]
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, hd = q.shape
    skv = k.shape[1]
    sm_scale = 1.0 / np.sqrt(hd)
    qp = (-sq) % BLOCK_Q
    kp = (-skv) % BLOCK_KV
    dp = (-hd) % 128
    if qp or dp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, dp)))
    if kp or dp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, dp)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, dp)))
    sqq, skk, hdd = sq + qp, skv + kp, hd + dp

    grid = (bh, sqq // BLOCK_Q, skk // BLOCK_KV)
    out = pl.pallas_call(
        functools.partial(
            _kernel, sm_scale=sm_scale, causal=causal, window=window,
            q_offset=q_offset, skv=skv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, hdd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, BLOCK_KV, hdd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, BLOCK_KV, hdd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, hdd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqq, hdd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, hdd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :hd]
