"""Jit'd public wrapper for flash attention (Pallas on TPU, jnp elsewhere)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention"]


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            interpret=interpret,
        )
    return attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
