"""Pure-jnp oracle: causal (optionally sliding-window) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref"]


def attention_ref(
    q: jnp.ndarray,  # [BH, Sq, hd]
    k: jnp.ndarray,  # [BH, Skv, hd]
    v: jnp.ndarray,  # [BH, Skv, hd]
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    hd = q.shape[-1]
    logits = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / np.sqrt(hd)
    qpos = q_offset + jnp.arange(q.shape[1])[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(logits, dtype=bool)
    if causal:
        mask &= (kpos <= qpos)[None]
    if window:
        mask &= (kpos > qpos - window)[None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", probs, v)
