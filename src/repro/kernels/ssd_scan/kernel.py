"""Pallas TPU kernel: Mamba-2 SSD chunk scan (arXiv:2405.21060, §6).

TPU-native adaptation (DESIGN.md §4): one (batch x head) pair per grid row,
chunks sequential so the carried state [P, N] lives in VMEM scratch across
the chunk axis. Per tile, all four contractions (C B^T scores, diag-block
output, state readout, chunk-state update) are [chunk x N/P] matmuls that
land on the MXU — chunk=256, P=64, N=128 are all lane/sublane aligned. The
decay matrices are built in-register from a cumulative-sum iota; nothing
quadratic in S ever touches HBM.

Grid: (B*H, n_chunks). The inter-chunk recurrence — a sequential
multiply-accumulate in the original — becomes the scratch carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, st_out, state_s, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    x = x_ref[0].astype(jnp.float32)  # [L, P]
    dt = dt_ref[0].astype(jnp.float32)  # [L, 1]
    a = -jnp.exp(alog_ref[0, 0].astype(jnp.float32))  # scalar
    b = b_ref[0].astype(jnp.float32)  # [L, N]
    c = c_ref[0].astype(jnp.float32)  # [L, N]

    xd = x * dt  # discretized input [L, P]
    adt = a * dt  # [L, 1] log-decays
    a_cum = jnp.cumsum(adt, axis=0)  # [L, 1]

    li = a_cum  # [L, 1]
    lj = a_cum.T  # [1, L]
    l_size = x.shape[0]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (l_size, l_size), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (l_size, l_size), 1)
    )
    l_mat = jnp.where(causal, jnp.exp(jnp.minimum(li - lj, 0.0)), 0.0)  # [L, L]

    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, L]
    y = jax.lax.dot(scores * l_mat, xd, preferred_element_type=jnp.float32)

    # carried-state readout: y += (C * exp(a_cum)) @ state^T  ([L,N]@[N,P])
    state = state_s[...]  # [P, N]
    y = y + jax.lax.dot_general(
        c * jnp.exp(a_cum), state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # chunk-state update: state' = state * exp(sum adt) + (xd^T @ (B * seg))
    seg = jnp.exp(a_cum[-1:] - a_cum)  # [L, 1]
    contrib = jax.lax.dot_general(
        xd, b * seg, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [P, N]
    state_s[...] = state * jnp.exp(a_cum[-1, 0]) + contrib

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit():
        st_out[0] = state_s[...].astype(st_out.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,  # [B, S, H, P]
    dt: jnp.ndarray,  # [B, S, H]
    a_log: jnp.ndarray,  # [H]
    b_mat: jnp.ndarray,  # [B, S, G, N]
    c_mat: jnp.ndarray,  # [B, S, G, N]
    chunk: int,
    interpret: bool = False,
):
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g

    # lay out as (B*H, S, ...) rows; broadcast groups over heads
    xq = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtq = dt.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    bq = jnp.repeat(b_mat.transpose(0, 2, 1, 3), rep, axis=1).reshape(bsz * h, s, n)
    cq = jnp.repeat(c_mat.transpose(0, 2, 1, 3), rep, axis=1).reshape(bsz * h, s, n)
    alogq = jnp.tile(a_log, bsz).reshape(bsz * h, 1)

    grid = (bsz * h, nc)
    y, st = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda r, ci: (r, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda r, ci: (r, ci, 0)),
            pl.BlockSpec((1, 1), lambda r, ci: (r, 0)),
            pl.BlockSpec((1, chunk, n), lambda r, ci: (r, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda r, ci: (r, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda r, ci: (r, ci, 0)),
            pl.BlockSpec((1, p, n), lambda r, ci: (r, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xq, dtq, alogq, bq, cq)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    st = st.reshape(bsz, h, p, n)
    return y, st
