"""Jit'd public wrapper for the SSD chunk scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref

__all__ = ["ssd_scan"]


def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a_log: jnp.ndarray,
    b_mat: jnp.ndarray,
    c_mat: jnp.ndarray,
    chunk: int,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return ssd_scan_pallas(x, dt, a_log, b_mat, c_mat, chunk, interpret=interpret)
    return ssd_scan_ref(x, dt, a_log, b_mat, c_mat, chunk)
