"""Pure-jnp oracle for the SSD chunk-scan kernel: the chunked state-space
duality algorithm from repro.models.ssm (single source of truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(
    x: jnp.ndarray,  # [B, S, H, P]
    dt: jnp.ndarray,  # [B, S, H]
    a_log: jnp.ndarray,  # [H]
    b_mat: jnp.ndarray,  # [B, S, G, N]
    c_mat: jnp.ndarray,  # [B, S, G, N]
    chunk: int,
):
    return ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk)
