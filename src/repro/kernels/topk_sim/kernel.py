"""Pallas TPU kernel: fused tool-similarity + running top-K.

The paper's serving hot spot (embed -> dot-products -> top-K, §4.1) for
routers co-located with TPU pods. TPU-native design (DESIGN.md §4):

  * the tool table streams HBM->VMEM in [BLOCK_T, D] tiles; D is padded to a
    lane multiple (384 -> 512) so the q @ tile^T contraction runs on the MXU;
  * a running top-K (scores + indices) lives in VMEM scratch across the tool
    grid axis — one pass over the table, no global [Q, T] score matrix is
    ever materialized (the jnp reference writes Q*T floats to HBM; at
    T=100k tools that is the difference between streaming and spilling);
  * the merge is a single descending sort over [K + BLOCK_T] candidates per
    query row (K <= 64 << BLOCK_T, so sort cost is dominated by the tile).

Grid: (q_blocks, t_blocks), t innermost so the scratch carry is sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.retrieval import NEG_INF

__all__ = ["topk_sim_pallas", "BLOCK_Q", "BLOCK_T"]

BLOCK_Q = 128
BLOCK_T = 512
# the canonical padding sentinel: the gateway filters selected tools by
# `score > NEG_INF / 2`, so the kernel's padding mask must use the SAME
# constant or padded slots could surface as results
NEG = NEG_INF


def _kernel(q_ref, t_ref, vals_out, idx_out, vals_s, idx_s, *, k: int, n_tools: int):
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        vals_s[...] = jnp.full_like(vals_s, NEG)
        idx_s[...] = jnp.zeros_like(idx_s)

    q = q_ref[...]  # [BQ, D]
    t = t_ref[...]  # [BT, D]
    scores = jax.lax.dot_general(
        q, t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [BQ, BT]
    base = ti * BLOCK_T
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    # mask padding rows of the table (T padded up to a BLOCK_T multiple)
    scores = jnp.where(col < n_tools, scores, NEG)

    cand_v = jnp.concatenate([vals_s[...], scores], axis=1)  # [BQ, K+BT]
    cand_i = jnp.concatenate([idx_s[...], col], axis=1)
    order = jnp.argsort(-cand_v, axis=1)[:, :k]
    vals_s[...] = jnp.take_along_axis(cand_v, order, axis=1)
    idx_s[...] = jnp.take_along_axis(cand_i, order, axis=1)

    @pl.when(ti == nt - 1)
    def _emit():
        vals_out[...] = vals_s[...]
        idx_out[...] = idx_s[...]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_sim_pallas(
    queries: jnp.ndarray,  # [Q, D]
    table: jnp.ndarray,  # [T, D]
    k: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    q, d = queries.shape
    t = table.shape[0]
    # pad every axis to hardware-aligned multiples
    qp = (-q) % BLOCK_Q
    tp = (-t) % BLOCK_T
    dp = (-d) % 128
    if qp or dp:
        queries = jnp.pad(queries, ((0, qp), (0, dp)))
    if tp or dp:
        table = jnp.pad(table, ((0, tp), (0, dp)))
    qq, tt, dd = q + qp, t + tp, d + dp

    grid = (qq // BLOCK_Q, tt // BLOCK_T)
    vals, idx = pl.pallas_call(
        functools.partial(_kernel, k=k, n_tools=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_Q, dd), lambda qi, ti: (qi, 0)),
            pl.BlockSpec((BLOCK_T, dd), lambda qi, ti: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_Q, k), lambda qi, ti: (qi, 0)),
            pl.BlockSpec((BLOCK_Q, k), lambda qi, ti: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qq, k), jnp.float32),
            jax.ShapeDtypeStruct((qq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, k), jnp.float32),
            pltpu.VMEM((BLOCK_Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, table)
    return vals[:q], idx[:q]
