"""Jit'd public wrapper for the fused similarity+top-K op.

`use_pallas=None` auto-selects: the Pallas kernel on TPU backends, the jnp
reference elsewhere (this CPU container validates the kernel body with
interpret=True in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_sim.kernel import topk_sim_pallas
from repro.kernels.topk_sim.ref import topk_sim_ref

__all__ = ["topk_sim"]


def topk_sim(
    queries: jnp.ndarray,
    table: jnp.ndarray,
    k: int,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return topk_sim_pallas(queries, table, k, interpret=interpret)
    return topk_sim_ref(queries, table, k)
