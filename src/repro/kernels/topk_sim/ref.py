"""Pure-jnp oracle for the fused similarity + top-K kernel (Eq. 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["topk_sim_ref"]


def topk_sim_ref(
    queries: jnp.ndarray,  # [Q, D] unit rows
    table: jnp.ndarray,  # [T, D] unit rows
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (scores [Q, k], indices [Q, k]) by descending similarity."""
    sims = queries @ table.T
    return jax.lax.top_k(sims, k)
