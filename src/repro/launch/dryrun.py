import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both

The XLA_FLAGS line above MUST stay the first statement — jax locks the device
count at first init, and the dry-run (and only the dry-run) needs 512
placeholder host devices for `jax.make_mesh((2,16,16), ...)`.

Each run writes experiments/dryrun/<arch>__<shape>__<mesh>.json with:
  * per-device memory_analysis (argument/output/temp bytes) — proves it fits,
  * cost_analysis FLOPs + bytes (per device, per step),
  * collective op counts/bytes parsed from the partitioned HLO,
  * the three §Roofline terms and the dominant bottleneck.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.common.meshctx import cost_analysis_dict, use_mesh
from repro.common.sharding import set_policy
from repro.configs import ARCHITECTURES, get_config
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cache_structs, input_specs, variant_for_shape
from repro.launch.hbm_model import analytic_hbm_bytes
from repro.launch.state_specs import opt_state_structs
from repro.models import model as M
from repro.models.params import param_structs
from repro.training.train_step import TrainConfig, make_train_step


def build_program(cfg, shape, mesh, tc: TrainConfig, quantize: bool = False):
    """Returns (fn, arg_structs tuple) for the shape's program kind.

    `quantize=True` (inference only): lower over int8 weights with an inline
    dequant at the program boundary — XLA fuses it into the consumer matmuls
    (see models/quant.py)."""
    specs = M.make_specs(cfg)
    dtype = jnp.dtype(cfg.dtype)
    if quantize and shape.kind != "train":
        from repro.models.quant import dequantize_tree, quantized_structs

        pstructs = quantized_structs(specs, mesh=mesh, dtype=dtype)
        deq = lambda qp: dequantize_tree(qp, dtype)
    else:
        pstructs = param_structs(specs, dtype=dtype, mesh=mesh)
        deq = lambda p: p
    batch = input_specs(cfg, shape, mesh)
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat=True)  # activation checkpointing
        step_fn, _ = make_train_step(cfg, tc)
        opt_name = tc.optimizer
        if opt_name == "auto":
            opt_name = "adafactor" if cfg.param_count() > 30e9 else "adamw"
        ostructs = opt_state_structs(opt_name, specs, mesh)
        return step_fn, (pstructs, ostructs, batch)
    if shape.kind == "prefill":
        fn = lambda p, b: M.prefill(cfg, deq(p), b, max_cache_len=shape.seq_len)
        return fn, (pstructs, batch)
    # decode
    cache = cache_structs(cfg, shape, mesh)
    fn = lambda p, c, b: M.decode_step(cfg, deq(p), c, b)
    return fn, (pstructs, cache, batch)


def _probe_depths(cfg) -> tuple:
    """Two shallow depths for unrolled cost probes (VLM keeps its 4+1 groups)."""
    if cfg.cross_attn_every:
        return cfg.cross_attn_every, 2 * cfg.cross_attn_every
    return 2, 4


def _measure(cfg, shape, mesh, tc, quantize=False):
    """Compile and return (flops, bytes, wire_bytes) per device for cfg."""
    fn, args = build_program(cfg, shape, mesh, tc, quantize)
    with use_mesh(mesh):
        compiled = jax.jit(fn).lower(*args).compile()
    cost = cost_analysis_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(colls.wire_bytes),
        colls,
    )


def probe_corrected_costs(cfg, shape, mesh, tc, quantize=False):
    """XLA cost analysis counts while-loop bodies ONCE, so a scanned L-layer
    model under-reports by ~L x. We compile two shallow *unrolled* variants
    (scan_unroll=True removes every while loop) and linearly extrapolate:
    metric(L) = intercept + slope * L. Exact for everything linear in depth
    (per-layer flops, bytes, and per-layer collectives), with embed/head/
    optimizer costs captured by the intercept."""
    l1, l2 = _probe_depths(cfg)
    c1 = dataclasses.replace(cfg, n_layers=l1, scan_unroll=True)
    c2 = dataclasses.replace(cfg, n_layers=l2, scan_unroll=True)
    m1 = _measure(c1, shape, mesh, tc, quantize)[:3]
    m2 = _measure(c2, shape, mesh, tc, quantize)[:3]
    out = []
    for a, b in zip(m1, m2):
        slope = (b - a) / (l2 - l1)
        out.append(max(a + slope * (cfg.n_layers - l1), 0.0))
    return {"flops": out[0], "bytes_accessed": out[1], "wire_bytes": out[2],
            "probe_depths": [l1, l2]}


def run_one(
    arch: str, shape_name: str, mesh_kind: str, tc: TrainConfig, out_dir: str,
    probe: bool = True, policy: str = "tp", moe_impl: str = "gspmd",
    repeat_kv: bool = False, decode_attn: str = "gspmd", quantize: bool = False,
    tag: str = "",
):
    shape = SHAPES[shape_name]
    cfg = variant_for_shape(get_config(arch), shape)
    if moe_impl != "gspmd":
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if repeat_kv:
        cfg = dataclasses.replace(cfg, repeat_kv=True)
    if decode_attn != "gspmd":
        cfg = dataclasses.replace(cfg, decode_attn=decode_attn)
    set_policy(policy)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    fn, args = build_program(cfg, shape, mesh, tc, quantize)
    with use_mesh(mesh):
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_total = time.time() - t0

    cost = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
    }
    colls = parse_collectives(compiled.as_text())

    if probe:
        corrected = probe_corrected_costs(cfg, shape, mesh, tc, quantize)
        flops = corrected["flops"]
        bytes_acc = corrected["bytes_accessed"]
        wire = corrected["wire_bytes"]
    else:
        corrected = None
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        wire = colls.wire_bytes

    # memory term: analytic HBM floor (HLO "bytes accessed" is fusion-naive
    # on the CPU backend and recorded separately as the upper bound)
    model_shards = 16
    opt_name = tc.optimizer
    if opt_name == "auto":
        opt_name = "adafactor" if cfg.param_count() > 30e9 else "adamw"
    traffic = analytic_hbm_bytes(
        cfg, shape.kind, shape.global_batch, shape.seq_len,
        mesh.devices.size, model_shards, opt_name,
        weight_bytes=(1.07 if quantize and shape.kind != "train" else 2.0),
    )
    terms = roofline_terms(flops, traffic["total"], wire)
    terms["memory_upper_s"] = bytes_acc / 819e9

    n = cfg.param_count()
    # MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for inference tokens
    factor = 6 if shape.kind == "train" else 2
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = factor * cfg.active_param_count() * d_tokens
    chips = mesh.devices.size
    record = {
        "arch": arch,
        "variant": cfg.name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": mesh_kind,
        "policy": policy,
        "moe_impl": moe_impl,
        "repeat_kv": repeat_kv,
        "decode_attn": decode_attn,
        "quantize": quantize,
        "chips": chips,
        "params": n,
        "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_total - t_lower, 2),
        "per_device": {"flops": flops, "bytes_accessed": bytes_acc,
                       "hbm_bytes_analytic": traffic, **mem},
        "hlo_raw": {  # uncorrected (scan bodies counted once) — for reference
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "probe": corrected,
        "collectives": {
            "bytes_by_type": colls.bytes_by_type,
            "count_by_type": colls.count_by_type,
            "wire_bytes": wire,
        },
        "roofline": terms,
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / max(flops * chips, 1.0)),
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--optimizer", default="auto")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip unrolled cost probes (pass/fail lowering only)")
    ap.add_argument("--policy", default="tp",
                    help="sharding policy: tp | tp_sp | tp_kvs | fsdp")
    ap.add_argument("--moe-impl", default="gspmd", choices=["gspmd", "shard_map"])
    ap.add_argument("--repeat-kv", action="store_true")
    ap.add_argument("--decode-attn", default="gspmd", choices=["gspmd", "seq_shard"])
    ap.add_argument("--quantize", action="store_true",
                    help="int8 weights for inference programs")
    ap.add_argument("--tag", default="", help="suffix for output json files")
    args = ap.parse_args()

    archs = sorted(ARCHITECTURES) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    tc = TrainConfig(optimizer=args.optimizer)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch} x {shape} x {mesh_kind}"
                try:
                    r = run_one(arch, shape, mesh_kind, tc, args.out,
                                probe=not args.no_probe, policy=args.policy,
                                moe_impl=args.moe_impl, repeat_kv=args.repeat_kv,
                                decode_attn=args.decode_attn,
                                quantize=args.quantize, tag=args.tag)
                    rt = r["roofline"]
                    print(
                        f"OK   {tag:60s} compile={r['compile_s']:6.1f}s "
                        f"flops/dev={r['per_device']['flops']:.3e} "
                        f"dominant={rt['dominant']:10s} "
                        f"(c={rt['compute_s']*1e3:.2f}ms m={rt['memory_s']*1e3:.2f}ms "
                        f"coll={rt['collective_s']*1e3:.2f}ms)",
                        flush=True,
                    )
                except Exception as e:  # a failure here is a sharding bug
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nAll dry-runs passed.")


if __name__ == "__main__":
    main()
