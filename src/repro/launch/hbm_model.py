"""Analytic HBM-traffic model per (arch x shape) — the roofline memory floor.

`cost_analysis()["bytes accessed"]` on the CPU backend is fusion-naive: every
intermediate is counted at every op, so it overestimates TPU HBM traffic by
5-20x (on TPU, fused intermediates live in VMEM/VREGs). For the §Roofline
memory term we therefore use this analytic floor — the bytes that MUST move
through HBM given perfect fusion — and record the HLO number as the no-fusion
upper bound. The true machine sits between the two, much closer to the floor.

Model (per device, per step; dtype = 2 bytes bf16):
  weights     r_w reads of the device's weight working set
              (active_params / model_shards — FSDP gathers materialize the
              full "model"-shard slice on every device regardless of the
              data-axis shard)
  optimizer   train only: adamw 3x fp32 state r/w + grad write
  activations residual-stream saves: ~n_saves per layer of [T_local, d]
  kv cache    decode: full read + 1-token write; prefill: full write
  ssm state   decode: read + write of [H, P, N] per layer
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

__all__ = ["analytic_hbm_bytes"]

BF16 = 2
F32 = 4


def analytic_hbm_bytes(
    cfg: ModelConfig,
    kind: str,  # train | prefill | decode
    global_batch: int,
    seq_len: int,
    chips: int,
    model_shards: int,
    optimizer: str = "adamw",
    weight_bytes: float = BF16,  # 1.0 for int8-quantized serving
) -> Dict[str, float]:
    p_active = cfg.active_param_count()
    p_total = cfg.param_count()
    # per-device weight working set (TP slice; FSDP all-gather materializes it)
    w_dev = p_active / model_shards * weight_bytes
    w_dev_total = p_total / chips * BF16  # true resident shard (FSDP+TP)

    t_local = global_batch * (seq_len if kind != "decode" else 1) / chips
    d = cfg.d_model
    L = cfg.n_layers

    out: Dict[str, float] = {}
    if kind == "train":
        # fwd read + remat re-read + bwd read; grads written once (f32)
        out["weights"] = 3 * w_dev
        out["grads"] = p_total / chips * F32
        if optimizer == "adamw":
            out["opt_state"] = p_total / chips * F32 * 4  # mu,nu read+write
        else:  # adafactor: factored stats ~ negligible vs params
            out["opt_state"] = p_total / chips * F32 * 0.1
        out["param_update"] = w_dev_total * 2  # read + write
        # remat saves: residual stream + a few per-layer boundaries
        n_saves = 2
        out["activations"] = t_local * d * L * BF16 * n_saves * 2  # write + read
    elif kind == "prefill":
        out["weights"] = w_dev
        n_flows = 4  # residual r/w at block boundaries (flash-fused attention)
        out["activations"] = t_local * d * L * BF16 * n_flows
        out["kv_write"] = _cache_bytes(cfg, global_batch, seq_len, chips, model_shards)
    else:  # decode
        out["weights"] = w_dev
        cache = _cache_bytes(cfg, global_batch, seq_len, chips, model_shards)
        out["cache_read"] = cache
        out["cache_write"] = t_local * L * _cache_row_bytes(cfg, model_shards)
        out["activations"] = t_local * d * L * BF16 * 4
    out["total"] = sum(out.values())
    return out


def _cache_row_bytes(cfg: ModelConfig, model_shards: int) -> float:
    """Per-token per-layer cache bytes on one device."""
    b = 0.0
    if cfg.has_attention:
        if cfg.decode_attn == "seq_shard":
            kv_shards = model_shards  # cache seq dim sharded (tp_kvs policy)
        else:
            kv_shards = model_shards if cfg.n_kv_heads % model_shards == 0 else 1
        b += 2 * cfg.n_kv_heads * cfg.hd / kv_shards * BF16
    return b


def _cache_bytes(
    cfg: ModelConfig, global_batch: int, seq_len: int, chips: int, model_shards: int
) -> float:
    """Total per-device cache bytes for the full context."""
    data_shards = max(chips // model_shards, 1)
    b_local = max(global_batch / data_shards, 1)
    total = 0.0
    if cfg.has_attention:
        w = min(cfg.sliding_window, seq_len) if cfg.sliding_window else seq_len
        total += b_local * cfg.n_layers * w * _cache_row_bytes(cfg, model_shards)
    if cfg.has_ssm:
        h_shards = model_shards if cfg.ssm_heads % model_shards == 0 else 1
        state = cfg.ssm_heads / h_shards * cfg.ssm_head_dim * cfg.ssm_state * BF16
        total += 2 * b_local * cfg.n_layers * state  # read + write
    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        kv_shards = model_shards if cfg.n_kv_heads % model_shards == 0 else 1
        total += (
            2 * b_local * n_cross * cfg.n_image_tokens
            * cfg.n_kv_heads * cfg.hd / kv_shards * BF16
        )
    return total
