"""Post-SPMD HLO analysis: collective-bytes extraction + roofline terms.

`cost_analysis()` gives per-device FLOPs and HBM bytes but says nothing about
collectives, so we parse the partitioned HLO (`compiled.as_text()`) and sum
the buffer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (DESIGN.md §7).

Conventions (documented in EXPERIMENTS.md §Roofline):
  * HLO shapes after SPMD partitioning are per-device; all numbers here are
    per-device per step.
  * wire-cost weights approximate ring algorithms: all-reduce 2x its buffer,
    gather/scatter/permute/all-to-all 1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms", "HW"]

# TPU v5e hardware constants (per chip)
HW = {
    "peak_flops": 197e12,  # bf16 FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# wire multiplier (ring algorithm approximation)
_WIRE_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}() /+\-*#_]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: Dict[str, int]
    count_by_type: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_type.values())

    @property
    def wire_bytes(self) -> float:
        return sum(_WIRE_WEIGHT[k] * v for k, v in self.bytes_by_type.items())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, op = m.group(1), m.group(2)
        if "-done(" in line:  # async pair: count the start only
            continue
        b = _shape_bytes(shape_text)
        bytes_by[op] += b
        count_by[op] += 1
    return CollectiveStats(bytes_by_type=bytes_by, count_by_type=count_by)


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_wire_bytes: float,
    n_links: int = 4,  # v5e: 4 ICI links per chip (2D torus)
) -> Dict[str, float]:
    """Three roofline terms in seconds (per device, per step)."""
    compute_s = flops_per_device / HW["peak_flops"]
    memory_s = hbm_bytes_per_device / HW["hbm_bw"]
    collective_s = collective_wire_bytes / (HW["ici_bw"] * n_links)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
