"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — `dryrun.py` must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* the first jax
device query, and smoke tests must keep seeing 1 device.

Mesh construction and activation go through `repro.common.meshctx`, which
papers over the JAX-version drift in `jax.make_mesh(axis_types=...)` /
`jax.set_mesh` (see that module's portability contract).
"""
from __future__ import annotations

import jax

from repro.common import meshctx

__all__ = ["make_production_mesh", "make_local_mesh", "CHIPS_PER_POD"]

CHIPS_PER_POD = 256  # 16 x 16 TPU v5e pod


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return meshctx.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally, as a 1D (data,) mesh — CPU tests."""
    n = len(jax.devices())
    return meshctx.make_mesh((n,), ("data",))
