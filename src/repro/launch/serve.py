"""Serving launcher: the OATS gateway in front of a backend pool.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 32 --max-new-tokens 8

Wires together the full paper pipeline (Fig. 2): a synthetic MetaTool-like
tool database, the OATS offline refinement job (Stage 1 + validation gate +
atomic table swap), the CPU serving path (embed -> top-K -> attach tools),
and a backend model pool doing real prefill+decode on a reduced config.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import OATSPipeline, PipelineConfig, STAGE_PRESETS
from repro.data.benchmarks import make_metatool_like, scale_tool_corpus
from repro.embedding.bag_encoder import BagEncoder
from repro.models import model as M
from repro.models.config import reduced
from repro.obs import (
    EventBus,
    FlightRecorder,
    HealthMonitor,
    JitProfiler,
    ObsServer,
    QualityConfig,
    QualityMonitor,
    RouteTracer,
    SamplingProfiler,
    SLOEngine,
    TimeSeriesRing,
    get_registry,
    stamp_router_costs,
)
from repro.router.gateway import SemanticRouter
from repro.router.latency import measure_latency, percentile_stats
from repro.router.tooldb import ToolRecord, ToolsDatabase


def build_router(
    bench,
    stage: str = "oats-s1",
    k: int = 5,
    backend: str = "dense",
    num_tools: int = 0,
    seed: int = 0,
    tracer=None,
    bus=None,
    quality=None,
    cache=None,
    cleanups=None,
):
    """Gateway over the refined table; `backend` picks the index scorer.

    `num_tools > bench.n_tools` tiles + perturbs the refined table to that
    size (`scale_tool_corpus`) — the MCP-registry-scale demo. Scaled row i
    is a clone of base tool `i % bench.n_tools` (provenance by modulo).

    `cleanups`, when passed, collects the detach handles of any listeners
    this builder registers on the database (bus/quality watches) so the
    caller can unregister them at shutdown instead of leaking them across
    instances.
    """
    detach = (cleanups.append if cleanups is not None else lambda fn: None)
    enc = BagEncoder(bench.vocab)
    # offline control plane: fit the requested OATS stage, then deploy it
    pipe = OATSPipeline.fit(bench, PipelineConfig(stages=STAGE_PRESETS[stage], k=k), enc)
    if num_tools and num_tools < bench.n_tools:
        raise SystemExit(
            f"--num-tools {num_tools} is below the native table size "
            f"({bench.n_tools}); the scaler only tiles up — "
            f"use --n-tools for a smaller benchmark"
        )
    if num_tools and num_tools > bench.n_tools:
        base_t = bench.n_tools
        table = scale_tool_corpus(np.asarray(pipe.tool_table), num_tools, seed=seed)
        records = [
            ToolRecord(
                i,
                f"tool_{i % base_t}" + ("" if i < base_t else f"_clone{i // base_t}"),
                bench.desc_tokens[i % base_t],
                int(bench.tool_category[i % base_t]),
            )
            for i in range(num_tools)
        ]
        db = ToolsDatabase(records, table)  # refined table baked in at scale
        if bus is not None:
            detach(bus.watch_db(db))
        if quality is not None:
            detach(quality.watch_db(db))
    else:
        records = [
            ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
            for i in range(bench.n_tools)
        ]
        db = ToolsDatabase(records, enc.encode(bench.desc_tokens))
        # watch BEFORE the deploy swap: every table move — this one, later
        # controller swaps, guard rollbacks, out-of-band deploys — must land
        # on the bus (and refresh the drift detector's reference stats)
        if bus is not None:
            detach(bus.watch_db(db))
        if quality is not None:
            detach(quality.watch_db(db))
        # the §7.2 deploy step, exercised; the db was constructed just above
        # so version 0 is the only possible live version — the CAS still
        # guards against this block ever being reordered after serving starts
        db.swap_table(pipe.tool_table, expect_current=0)
    router = SemanticRouter(
        db,
        embed_fn=lambda toks: enc.encode_one(toks),
        embed_batch_fn=enc.encode,  # one encoder call per route_batch
        k=k,
        backend=backend,
        tracer=tracer,
        bus=bus,
        quality=quality,
        cache=cache,
    )
    # purge version-dead cache entries eagerly on swap/stage_swap (lookup
    # stamps already make stale serves impossible; this reclaims memory and
    # emits the `cache_invalidated` event the runbook watches)
    if cache is not None and bus is not None:
        detach(cache.watch(bus))
    # demo timing should reflect the index path, not the mid-build fallback
    if not router.index.wait_ready(timeout_s=300.0):
        print(
            f"WARNING: {backend} index never became fresh "
            f"(stats: {router.index.stats}); serving the exact dense fallback"
        )
    return router, pipe


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--stage", default="oats-s1", choices=sorted(STAGE_PRESETS))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--route-batch", type=int, default=16,
                    help="queries per batched route_batch call")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--n-tools", type=int, default=199)
    ap.add_argument("--n-queries", type=int, default=800)
    ap.add_argument("--backend", default="dense", choices=("dense", "ivf", "pallas"),
                    help="index scorer behind route_batch (repro.index)")
    ap.add_argument("--num-tools", type=int, default=0,
                    help="tile+perturb the tool table to this size "
                         "(> --n-tools; 0 = no scaling) — the index-at-scale demo")
    ap.add_argument("--learn", action="store_true",
                    help="after serving, run one learning-plane step "
                         "(repro.learn) over the logged outcomes: the "
                         "recommend_stages density plan decides whether the "
                         "adapter/re-ranker even train, and any promotion "
                         "is held-out-gated and hot-swapped into the router")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve /metrics (Prometheus), /health (JSON; 503 on "
                         "a failing daemon loop), and /events on "
                         "127.0.0.1:PORT (0 = ephemeral port, printed)")
    ap.add_argument("--trace-every", type=int, default=8,
                    help="route-trace sampling rate (~1-in-N batches)")
    ap.add_argument("--trace-export", metavar="PATH", default=None,
                    help="write sampled route traces as JSONL on exit "
                         "(render with `repro-obs PATH`)")
    ap.add_argument("--dump-dir", metavar="DIR", default=None,
                    help="flight-recorder black-box dumps land here on "
                         "slo_burn/quality_drift/loop_error/rollback/"
                         "demotion or a fatal crash "
                         "(postmortem: `repro-obs replay DIR`)")
    ap.add_argument("--profile-daemons", action="store_true",
                    help="opt-in sampling wall-clock profiler over the "
                         "cadence daemons (exported at /profile)")
    ap.add_argument("--route-cache", action="store_true",
                    help="front route_batch with SemanticRouteCache: "
                         "near-duplicate queries are served the cached "
                         "top-K without paying embed-adjacent score+rerank "
                         "(exact version-stamped invalidation; see "
                         "repro.cache for the config tradeoffs)")
    ap.add_argument("--cache-threshold", type=float, default=0.95,
                    help="min cosine(stored query, new query) to serve a "
                         "cached decision (the correctness knob)")
    ap.add_argument("--cache-capacity", type=int, default=65536,
                    help="retained key slots; one decision occupies "
                         "n_tables (8) slots, LRU-evicted beyond this")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # telemetry plane: metrics go to the process registry (the router
    # records into it by default), lifecycle events to one shared bus,
    # sampled traces to a bounded ring; the judgement layer (timeseries
    # ring + SLO engine + quality monitor) watches all three
    bus = EventBus()
    tracer = RouteTracer(sample_every=max(args.trace_every, 1), seed=args.seed)
    quality = QualityMonitor(QualityConfig(drift_every=4),
                             registry=get_registry(), bus=bus)
    cleanups = []
    cache = None
    if args.route_cache:
        from repro.cache import CacheConfig, SemanticRouteCache

        cache = SemanticRouteCache(
            CacheConfig(threshold=args.cache_threshold,
                        capacity=args.cache_capacity, seed=args.seed),
            metrics=get_registry(), bus=bus,
        )

    print("== building tool benchmark + OATS control plane ==")
    bench = make_metatool_like(seed=args.seed, n_tools=args.n_tools, n_queries=args.n_queries)
    router, pipe = build_router(
        bench, args.stage, backend=args.backend, num_tools=args.num_tools,
        seed=args.seed, tracer=tracer, bus=bus, quality=quality,
        cache=cache, cleanups=cleanups,
    )
    print(f"== index backend: {args.backend} over {len(router.db)} tools ==")

    ring = TimeSeriesRing(get_registry(), bus=bus)
    slo_engine = SLOEngine(ring, bus=bus, registry=get_registry())
    monitor = HealthMonitor(routers=[router], indexes=[router.index], bus=bus,
                            slo=slo_engine)
    # live compile telemetry over the gateway's hot jits: the router build
    # above warmed them, so the first collect() is the warmup baseline and
    # anything counted after it is a production retrace
    profiler = JitProfiler(registry=get_registry())
    profiler.collect()
    stamp_router_costs(profiler, router, batch_size=args.route_batch)
    recorder = None
    if args.dump_dir:
        recorder = FlightRecorder(
            args.dump_dir, bus=bus, registry=get_registry(), tracer=tracer,
            ring=ring, slo=slo_engine, health=monitor, profiler=profiler,
            routers=[router],
        )
        print(f"== flight recorder armed: dumps -> {args.dump_dir} ==")
    sampler = SamplingProfiler() if args.profile_daemons else None
    obs_server = None
    if args.metrics_port is not None:
        # the ring's cadence is also the SLO judgement cadence (and the
        # compile-cache poll): one daemon snapshots the registry, counts
        # post-warmup jit compiles, and evaluates burn rates on every tick
        ring.start(
            interval_s=1.0,
            on_tick=lambda r: (profiler.collect(), slo_engine.evaluate()),
        )
        if sampler is not None:
            sampler.watch_thread(ring.thread(), "timeseries-ring")
            sampler.start()
        obs_server = ObsServer(monitor, get_registry(), bus,
                               port=args.metrics_port,
                               slo=slo_engine, tracer=tracer,
                               recorder=recorder, profiler=profiler,
                               sampler=sampler).start()
        print(f"== obs: http://{obs_server.host}:{obs_server.port}"
              f"{{/metrics,/health,/events,/slo,/traces,/dumps,/profile}} ==")

    # orderly teardown, shared by the normal exit path and the signal path:
    # recorder first (stop turning shutdown noise into dumps), then the
    # cadence daemons, then the HTTP surface, then the db listeners this
    # process attached — idempotent end to end, so signal-then-finally is
    # safe
    def _shutdown(*_sig):
        if recorder is not None:
            recorder.stop()
        if sampler is not None:
            sampler.stop()
        ring.stop()
        if obs_server is not None:
            obs_server.stop()
        while cleanups:
            cleanups.pop()()

    try:
        # orderly stop on SIGTERM; signal handlers only install from the
        # main thread (tests drive main() from workers — skip there)
        signal.signal(signal.SIGTERM,
                      lambda *sig: (_shutdown(), sys.exit(143)))
    except ValueError:
        pass

    # fatal-exception hook: anything that kills the serving body below
    # becomes one black-box dump before the process dies — the launcher
    # analogue of the controllers' daemon-loop crash hook
    try:
        return _serve_body(args, bench, router, pipe, bus, tracer, quality,
                           monitor)
    except BaseException as exc:
        if recorder is not None and not isinstance(exc, SystemExit):
            recorder.record_crash(exc, source="launch.serve")
        raise
    finally:
        _shutdown()
        router.close()


def _serve_body(args, bench, router, pipe, bus, tracer, quality, monitor):
    print("== loading backend pool ==")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = M.init(cfg, jax.random.PRNGKey(args.seed))
    decode = jax.jit(lambda p, c, b: M.decode_step(cfg, p, c, b))

    test = bench.test_idx[: args.requests]
    hits, lat = 0, []
    t_start = time.time()
    rng = np.random.default_rng(args.seed)
    # 1) router: select tools on CPU (the paper's single-digit-ms path),
    #    batched — each route_batch call scores a whole block of queries in
    #    one jitted top-K pass
    bs = max(args.route_batch, 1)
    results = []
    for lo in range(0, len(test), bs):
        chunk = test[lo : lo + bs]
        results.extend(router.route_batch([bench.query_tokens[q] for q in chunk]))
    base_t = bench.n_tools  # scaled tool i is a clone of base tool i % base_t
    for qi, res in zip(test, results):
        lat.append(res.latency_ms)
        hits += int(any(t % base_t == bench.relevant[qi][0] for t in res.tools))
        # 2) backend: prefill the (stub-tokenized) request + decode new tokens
        prompt_shape = (1, 32, cfg.n_codebooks) if cfg.n_codebooks else (1, 32)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, prompt_shape), jnp.int32)
        batch = {"tokens": prompt}
        if cfg.cross_attn_every:
            batch["image_embeds"] = jnp.zeros((1, cfg.n_image_tokens, cfg.d_model))
        logits, cache = M.prefill(cfg, params, batch, max_cache_len=64)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if cfg.n_codebooks:
            tok = tok  # [1,1,K] already
        for step in range(args.max_new_tokens - 1):
            logits, cache = decode(params, cache, {"token": tok, "pos": jnp.asarray(32 + step, jnp.int32)})
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        # 3) feedback: log the outcome for the next refinement cycle
        for t in res.tools:
            router.record_outcome(bench.query_tokens[qi], t, int(t in bench.relevant[qi]))

    stats = percentile_stats(lat)
    print(
        f"served {len(test)} requests in {time.time() - t_start:.1f}s | "
        f"router R@{router.k}: {hits / len(test):.3f} | "
        f"selection p50={stats.p50_ms:.2f}ms p99={stats.p99_ms:.2f}ms"
    )
    print(f"outcome log: {len(router.outcome_log)} events (feeds the next cron refinement)")
    print(f"index stats: {router.index.stats}")
    if router.cache is not None:
        print(f"route cache: hit_rate={router.cache.hit_rate():.3f} "
              f"stats={router.cache.stats}")
    print(f"health: {monitor.snapshot()['status']} | bus events: {bus.counts()}")
    q = quality.summary()
    drift = q["drift_score"]
    print(f"quality: drift_score={drift:.3f} "
          f"(drifting={q['drifting']})" if drift is not None
          else "quality: no drift reference")
    if args.trace_export:
        n = tracer.export_jsonl(args.trace_export)
        print(f"wrote {n} route traces to {args.trace_export} "
              f"(render: repro-obs {args.trace_export})")

    if args.learn:
        from repro.control import OutcomeStore
        from repro.learn import LearnConfig, LearningController

        print("== learning plane: one density-gated step over the outcome log ==")
        store = OutcomeStore(n_tools=len(router.db))
        store.drain_router(router)
        learner = LearningController(
            router.db, store, router, pipe.encoder.encode,
            config=LearnConfig(min_new_events=1, min_queries=10),
            bus=bus,
        )
        report = learner.step()
        plan = report.plan
        print(f"plan: density {plan.density:.2f} ev/tool -> "
              f"{sorted(plan.stages)} ({plan.reason})")
        for stage, d in sorted(report.decisions.items()):
            print(f"  {stage:8s}: {d.action} {d.reason}")
        print(f"live stages: {sorted(report.active) or '(none)'} "
              f"(stage v{report.stage_version})")
    # shutdown (recorder -> daemons -> server -> listeners -> router) runs
    # in main()'s finally via _shutdown, shared with the SIGTERM path
    return stats


if __name__ == "__main__":
    main()
