"""Input ShapeDtypeStructs for every (architecture x input shape) program.

The assigned input shapes (see DESIGN.md):
    train_4k      seq=4,096    global_batch=256   -> train_step
    prefill_32k   seq=32,768   global_batch=32    -> prefill
    decode_32k    seq=32,768   global_batch=128   -> decode_step
    long_500k     seq=524,288  global_batch=1     -> decode_step (sub-quadratic)

Everything here is ShapeDtypeStruct — no allocation ever happens; dry-run
lowering reads these directly. Shardings resolve through the same logical
rules as the model itself.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import named_sharding
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec

__all__ = ["SHAPES", "ShapeCase", "input_specs", "program_for", "variant_for_shape"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}

# Full-attention architectures run long_500k as an explicit sliding-window
# VARIANT (DESIGN.md §5); SSM/hybrid run it natively.
LONG_CONTEXT_WINDOW = 8192


def variant_for_shape(cfg: ModelConfig, shape: ShapeCase) -> ModelConfig:
    """Apply the long-context sliding-window variant where required."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return dataclasses.replace(
            cfg, name=cfg.name + "+swa", sliding_window=LONG_CONTEXT_WINDOW
        )
    return cfg


def _struct(mesh, shape: Tuple[int, ...], axes, dtype) -> jax.ShapeDtypeStruct:
    sharding = named_sharding(mesh, axes, shape) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(
    cfg: ModelConfig, shape: ShapeCase, mesh=None
) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for the given program kind."""
    b, s = shape.global_batch, shape.seq_len
    act_dtype = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.n_codebooks:
            tokens = _struct(mesh, (b, s, cfg.n_codebooks), ("batch", None, None), jnp.int32)
        else:
            tokens = _struct(mesh, (b, s), ("batch", None), jnp.int32)
        batch = {"tokens": tokens}
        if cfg.cross_attn_every:
            batch["image_embeds"] = _struct(
                mesh, (b, cfg.n_image_tokens, cfg.d_model), ("batch", None, None), act_dtype
            )
        return batch
    # decode: one new token against a seq_len cache
    if cfg.n_codebooks:
        token = _struct(mesh, (b, 1, cfg.n_codebooks), ("batch", None, None), jnp.int32)
    else:
        token = _struct(mesh, (b, 1), ("batch", None), jnp.int32)
    return {"token": token, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_structs(cfg: ModelConfig, shape: ShapeCase, mesh=None) -> Dict[str, Any]:
    spec = M.cache_spec(cfg, shape.global_batch, shape.seq_len)
    dtype = jnp.dtype(cfg.dtype)

    def leaf(ps: ParamSpec):
        sharding = named_sharding(mesh, ps.axes, ps.shape) if mesh is not None else None
        return jax.ShapeDtypeStruct(ps.shape, dtype, sharding=sharding)

    return jax.tree.map(leaf, spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def program_for(kind: str):
    """Map a shape kind to the (cfg, params, ...) program it lowers."""
    return {"train": "train_step", "prefill": "prefill", "decode": "decode_step"}[kind]
