"""Optimizer-state ShapeDtypeStructs (with shardings) for dry-run lowering.

Optimizer state mirrors parameter sharding: Adam's mu/nu inherit the param's
logical axes; Adafactor's factored vr/vc drop the reduced dimension's axis.
Built straight from the ParamSpec tree, so the dry-run never allocates.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common.sharding import named_sharding
from repro.models.params import ParamSpec
from repro.optim.adafactor import AdafactorState, _should_factor
from repro.optim.adamw import AdamState
from repro.optim.sgd import SgdState

__all__ = ["opt_state_structs"]


def _leaf_struct(mesh, shape, axes, dtype):
    sharding = named_sharding(mesh, axes, shape) if mesh is not None else None
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sharding)


def _mirror(specs, mesh, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: _leaf_struct(mesh, s.shape, s.axes, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _scalar(dtype=jnp.int32):
    return jax.ShapeDtypeStruct((), dtype)


def opt_state_structs(optimizer_name: str, specs, mesh) -> Any:
    if optimizer_name == "adamw":
        return AdamState(
            step=_scalar(), mu=_mirror(specs, mesh), nu=_mirror(specs, mesh)
        )
    if optimizer_name == "sgd":
        return SgdState(step=_scalar(), momentum=_mirror(specs, mesh))
    if optimizer_name == "adafactor":

        def leaf(s: ParamSpec):
            if _should_factor(s.shape):
                return {
                    "vr": _leaf_struct(mesh, s.shape[:-1], s.axes[:-1], jnp.float32),
                    "vc": _leaf_struct(
                        mesh, s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:],
                        jnp.float32,
                    ),
                }
            return {"v": _leaf_struct(mesh, s.shape, s.axes, jnp.float32)}

        stats = jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        return AdafactorState(step=_scalar(), stats=stats)
    raise ValueError(f"unknown optimizer {optimizer_name!r}")
