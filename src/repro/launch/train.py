"""Training launcher: --arch <id> [--smoke] [key=value overrides].

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch-size 4 --seq-len 128

On real hardware the same entry point runs the production mesh; on this CPU
container `--smoke` selects the reduced config (2 layers, d_model<=256).
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import get_config
from repro.data.lm_data import LMDataConfig, synthetic_lm_batches
from repro.models.config import reduced
from repro.training.train_step import TrainConfig
from repro.training.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config for CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="auto")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    tcfg = TrainerConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        seed=args.seed,
        train=TrainConfig(
            learning_rate=args.lr, optimizer=args.optimizer, total_steps=args.steps
        ),
    )
    trainer = Trainer(cfg, tcfg)
    data = synthetic_lm_batches(
        cfg, LMDataConfig(batch_size=args.batch_size, seq_len=args.seq_len, seed=args.seed)
    )
    history = trainer.fit(data)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} ({100 * (first - last) / first:.1f}% drop)")
    return history


if __name__ == "__main__":
    main()
