"""Learning plane: train, version, and gate the learned stages against the
live router (PR 4).

The paper's practical guidance is staged (§7.2-7.3): start with zero-cost
centroid refinement (the `repro.control` plane), then add learned
components *only when data density warrants it*. This package is the
subsystem that acts on that guidance: it turns the outcome window the
control plane already maintains into trained stage artifacts, and promotes
them into the serving path only when a held-out gate says they beat the
live configuration — then keeps watching them on live traffic and demotes
on regression.

  * `AdapterTrainer` / `RerankerTrainer` (trainers.py) — build training
    sets from the `OutcomeStore` window (triplet mining via
    `core.adapter.mine_triplets`, featurization via `core.features`) and
    run `train_adapter` / `train_reranker` off the hot path.
  * `ArtifactRegistry` (registry.py) — versioned, bounded, rollback-able
    store of trained artifacts keyed by (stage, version) and stamped with
    (table_version, window fingerprint); persists via `repro.checkpoint`.
  * `StageGuard` (guard.py) — TableGuard-style shadow monitoring of the
    live `StageSet` on labelled traffic, with compare-and-swap
    auto-demotion through `SemanticRouter.rollback_stages`.
  * `LearningController` (controller.py) — the loop: plan
    (`core.deployment.recommend_stages` over live counters) -> train ->
    held-out NDCG@5 gate -> CAS activation -> shadow monitoring.

Stage-selection guide (the §7.3 decision table, as live policy)
===============================================================

``refine`` — always on. Zero serving cost, gate-protected; owned by
    `repro.control.RefinementController`, not this package.

``adapter`` — the 197,248-param contrastive head. Trained and promoted
    only for large tool sets with abundant logs (|T| > 500, > 10K outcome
    examples). Served *query-side only*: `route_batch` applies it to the
    query block before the index backend scores, so the tool table — and
    any built IVF/Pallas index — is untouched by a promotion, and demotion
    is an instant StageSet rollback. Adds one tiny [Q,384]x[384,256]x
    [256,384] matmul pair per batch.

``rerank`` — the 2,625-param MLP over outcome features. Viable only above
    the ~10:1 outcome-to-tool density threshold (and below ~500 tools);
    below it the paper measured it *hurting* — the LearningController
    never trains it there, so sparse-density regimes never deploy it.
    Adds featurization + one MLP pass over C = 5K candidates per query.

Both gates are empirical on top of the density policy: a stage activates
only if it beats the live configuration's held-out NDCG@5 on the window's
positive-bearing queries, and stays only while live labelled traffic
agrees (`StageGuard`).

`benchmarks/learn_bench.py` records the density sweep (refine-only vs
+adapter vs +reranker NDCG@5) and the all-stages-active `route_batch`
p99/query against the 10 ms budget in BENCH_learn.json.
"""
from repro.learn.controller import (
    LearnConfig,
    LearnReport,
    LearningController,
    StageDecision,
    build_train_window,
)
from repro.learn.guard import StageGuard, StageGuardConfig, StageGuardReport
from repro.learn.registry import ArtifactRegistry, StageArtifact
from repro.learn.trainers import (
    AdapterTrainer,
    RerankerTrainer,
    TrainedStage,
    TrainWindow,
    featurizer_from_tree,
    featurizer_to_tree,
    stage_ndcg,
)

__all__ = [
    "LearnConfig",
    "LearnReport",
    "LearningController",
    "StageDecision",
    "StageGuard",
    "StageGuardConfig",
    "StageGuardReport",
    "ArtifactRegistry",
    "StageArtifact",
    "AdapterTrainer",
    "RerankerTrainer",
    "TrainedStage",
    "TrainWindow",
    "build_train_window",
    "featurizer_from_tree",
    "featurizer_to_tree",
    "stage_ndcg",
]
