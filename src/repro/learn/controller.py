"""LearningController: density-gated training + promotion of learned stages.

The control plane (`repro.control`) closes the §7.2 loop for the zero-cost
Stage-1 refinement; this controller closes it for the *learned* stages the
paper says to add "only when data density warrants it" (§7.3). One `step()`
= one pass of:

    (drain routers) -> StageGuard check -> recommend_stages plan over the
    live outcome counters -> per stage {adapter, rerank}:
        plan veto?  -> suppressed (sparse regimes never even train)
        trigger?    -> enough new events since this stage last trained
        train       -> StageTrainer off the hot path (table snapshot +
                       window fingerprint frozen into a TrainWindow)
        gate        -> held-out NDCG@5 of the candidate StageSet vs the
                       live one, on the exact serving composition
        activate    -> ArtifactRegistry.register + compare-and-swap
                       `SemanticRouter.set_stages(expect_version=...)`
        monitor     -> StageGuard.note_promotion (shadow windows +
                       auto-demotion on live labelled traffic)

The plan policy is the same `core.deployment.recommend_stages` decision
table the RefinementController records on every triggered step — here it
*acts*: below the §7.2 density threshold the re-ranker is never trained,
so the paper's negative result (the 2,625-param MLP hurts when outcomes
are sparse relative to the tool set) becomes live behavior instead of a
logged warning. Promotion is strictly additive-gated (`min_gain`): a
heavier serving stage must *beat* the current configuration on held-out
evidence, not tie it.

Step-driven for tests/cron; `start(interval_s)` runs the same `step()` on
an exception-surviving daemon thread, like `RefinementController`. After a
guard demotion the controller holds a training cooldown (watermarks reset
to the live ingest count): the window is dominated by outcomes the
condemned stage set generated, and retraining from it immediately would
re-promote essentially the same regression in a flap loop.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.deployment import DeploymentPlan, recommend_stages
from repro.learn.guard import StageGuard, StageGuardReport
from repro.learn.registry import ArtifactRegistry
from repro.learn.trainers import (
    AdapterTrainer,
    RerankerTrainer,
    TrainWindow,
    stage_ndcg,
)
from repro.obs import clock as obs_clock
from repro.router.tooldb import ConflictError, ToolsDatabase

__all__ = [
    "LearnConfig",
    "StageDecision",
    "LearnReport",
    "LearningController",
    "build_train_window",
]


def build_train_window(
    db: ToolsDatabase,
    store,
    embed_batch_fn: Callable[[Sequence[np.ndarray]], np.ndarray],
    val_fraction: float = 0.15,
    min_queries: int = 40,
    seed: int = 0,
) -> Optional[TrainWindow]:
    """Freeze one (table snapshot, outcome window, split) training set.

    Returns None when the window cannot support a training run: fewer than
    `min_queries` unique queries, or too few positive-bearing queries to
    hold out a gate slice. The gate slice is drawn ONLY from queries with
    >= 1 logged success (failure-only rows are excluded from
    batched_ndcg_at_k, so a val slice without positives would make the gate
    vacuous) — the same discipline as `RefinementController`.
    """
    batch = store.build_refinement_batch(embed_batch_fn)
    if batch.n_queries < min_queries:
        return None
    pos_rows = np.flatnonzero(batch.pos_mask.sum(axis=1) > 0)
    n_val = max(int(round(val_fraction * len(pos_rows))), 2)
    if len(pos_rows) < 2 * n_val:
        return None
    rng = np.random.default_rng(seed + store.total_ingested)
    val_idx = np.sort(rng.permutation(pos_rows)[:n_val])
    train_idx = np.setdiff1d(np.arange(batch.n_queries), val_idx)
    table_version, table = db.snapshot()
    return TrainWindow(
        table=np.asarray(table),
        table_version=table_version,
        query_emb=batch.query_emb,
        query_tokens=batch.query_tokens,
        pos_mask=batch.pos_mask,
        neg_mask=batch.neg_mask,
        tool_category=db.categories(),
        train_idx=train_idx,
        val_idx=val_idx,
        # taken atomically with the event snapshot the batch was built from,
        # so the stamped lineage matches the training data even while the
        # router's outcome_sink appends concurrently
        fingerprint=batch.fingerprint,
    )


@dataclasses.dataclass(frozen=True)
class LearnConfig:
    min_new_events: int = 512  # per-stage retrain trigger (fresh evidence)
    val_fraction: float = 0.15  # held-out slice of positive-bearing queries
    min_queries: int = 40  # don't train off a handful of queries
    # a promotion must beat the live config by MORE than this on held-out
    # NDCG@5 — learned stages carry serving cost, so a tie is a rejection
    min_gain: float = 0.0
    k: int = 5
    seed: int = 0


@dataclasses.dataclass
class StageDecision:
    """What one step decided for one learned stage."""

    stage: str
    # "suppressed" | "below_trigger" | "too_few_queries" | "train_failed" |
    # "gate_rejected" | "table_moved" | "promoted" | "activation_conflict"
    action: str
    reason: str = ""
    ndcg_current: Optional[float] = None  # held-out NDCG@5 of the live set
    ndcg_candidate: Optional[float] = None  # ... of the trained candidate
    artifact_version: Optional[int] = None  # registry version when promoted
    stage_version: Optional[int] = None  # router stage version after action


@dataclasses.dataclass
class LearnReport:
    """What one `step()` did, for logs/tests/benchmarks."""

    plan: Optional[DeploymentPlan]
    n_events: int = 0
    density: float = 0.0
    decisions: Dict[str, StageDecision] = dataclasses.field(default_factory=dict)
    guard: Optional[StageGuardReport] = None
    stage_version: int = 0  # live stage version when the step finished
    active: frozenset = frozenset()  # live stages when the step finished
    reason: str = ""


class LearningController:
    def __init__(
        self,
        db: ToolsDatabase,
        store,  # OutcomeStore
        router,  # SemanticRouter whose StageSet this plane deploys to
        embed_batch_fn: Callable[[Sequence[np.ndarray]], np.ndarray],
        registry: Optional[ArtifactRegistry] = None,
        guard: Optional[StageGuard] = None,
        config: LearnConfig = LearnConfig(),
        adapter_trainer: Optional[AdapterTrainer] = None,
        reranker_trainer: Optional[RerankerTrainer] = None,
        routers: Sequence = (),  # extra routers to drain into the store
        clock: Callable[[], float] = obs_clock.monotonic,
        # injectable for tests; production keeps the §7.3 decision table
        plan_fn: Callable[[int, int], DeploymentPlan] = recommend_stages,
        bus: Optional["EventBus"] = None,  # repro.obs.events lifecycle surface
        flight_recorder=None,  # repro.obs.flightrec — daemon crash dumps
    ):
        self.db = db
        self.store = store
        self.router = router
        self.embed_batch_fn = embed_batch_fn
        self.registry = registry if registry is not None else ArtifactRegistry()
        self.guard = guard
        self.config = config
        self.trainers = {
            "adapter": adapter_trainer or AdapterTrainer(),
            "rerank": reranker_trainer or RerankerTrainer(k=config.k),
        }
        self.routers = list(routers)
        self.clock = clock
        self.plan_fn = plan_fn
        # lifecycle events (promotion, gate_reject, cooldown, loop_error
        # transitions); demotions reach the bus via the StageGuard's own bus
        self.bus = bus
        # black-box hook: a daemon-step crash dumps the full telemetry state
        # (works without a bus; the recorder's debounce dedupes against the
        # loop_error event when both paths are wired)
        self.flight_recorder = flight_recorder
        self.reports: List[LearnReport] = []
        # daemon-loop health surface: most recent step() exception, cleared
        # by the next successful step (mirrors RefinementController) — a
        # health check polls this instead of scanning reports
        self.last_loop_error: Optional[BaseException] = None
        # per-stage trigger watermark: a stage retrains only on fresh
        # evidence (min_new_events ingested since its last training attempt)
        self._seen: Dict[str, int] = {"adapter": 0, "rerank": 0}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------ step
    def step(self) -> LearnReport:
        for router in self.routers:
            self.store.drain_router(router)
        guard_report = self.guard.check() if self.guard is not None else None
        if guard_report is not None and guard_report.action == "demoted":
            # cooldown: the window is dominated by outcomes the condemned
            # stage set served — a retrain from it would pass the same gate
            # the condemned artifact passed and re-promote essentially the
            # same regression in a flap loop. Purge the window and consume
            # the watermarks so training restarts from fresh evidence — the
            # same discipline RefinementController applies after a guard
            # table rollback (and on the same store, when both planes share
            # one: condemned-era outcomes are biased evidence for both).
            n_purged = self.store.clear()
            for stage in self._seen:
                self._seen[stage] = self.store.total_ingested
            # the registry must agree with what serves: drop the condemned
            # artifact(s) so `latest` cannot resurrect them
            self._sync_registry_to_live()
            report = LearnReport(
                plan=None,
                reason=(
                    f"cooldown after stage demotion "
                    f"({n_purged} condemned-era events purged)"
                ),
            )
            if self.bus is not None:
                self.bus.publish("cooldown", plane="learn", purged=n_purged)
        else:
            report = self._learn_step()
        report.guard = guard_report
        report.stage_version, stages = self.router.stage_set()
        report.active = stages.active
        self.reports.append(report)
        return report

    def _learn_step(self) -> LearnReport:
        cfg = self.config
        pos_counts, neg_counts = self.store.tool_counts()
        n_examples = int(pos_counts.sum() + neg_counts.sum())
        # the same §7.2/§7.3 decision table the RefinementController records
        # on its reports — evaluated over the live counters, and acted on
        plan = self.plan_fn(len(self.db), n_examples)
        report = LearnReport(
            plan=plan, n_events=len(self.store), density=plan.density
        )
        window: Optional[TrainWindow] = None
        window_built = False  # None is also a valid build result (unusable
        for stage, wanted in (  # window) — don't rebuild it per stage
            ("adapter", plan.contrastive_adapter),
            ("rerank", plan.mlp_reranker),
        ):
            if not wanted:
                report.decisions[stage] = StageDecision(
                    stage, "suppressed", reason=plan.reason
                )
                continue
            n_new = self.store.total_ingested - self._seen[stage]
            if n_new < cfg.min_new_events:
                report.decisions[stage] = StageDecision(
                    stage,
                    "below_trigger",
                    reason=f"{n_new} new events < {cfg.min_new_events}",
                )
                continue
            if not window_built:
                window = self._build_window()
                window_built = True
            report.decisions[stage] = self._consider(stage, window)
        return report

    def _sync_registry_to_live(self) -> None:
        """Roll the registry back to the artifacts the live StageSet serves.

        A StageGuard demotion restores a previous StageSet on the router;
        without this, the condemned artifact would linger as
        `registry.latest(stage)` and any lineage consumer (persistence,
        displays, future warm starts) would pick up exactly what the guard
        just condemned. A live artifact no longer retained by the bounded
        registry history degrades to dropping the stage's whole retained
        lineage — everything newer than it is condemned by construction.
        """
        _, stages = self.router.stage_set()
        live = {
            "adapter": stages.adapter_artifact,
            "rerank": stages.rerank_artifact,
        }
        for stage, live_version in live.items():
            latest = self.registry.latest(stage)
            if latest is None or latest.version == live_version:
                continue
            if live_version in self.registry.versions(stage):
                self.registry.rollback(stage, to_version=live_version)
            else:
                for v in self.registry.versions(stage):
                    self.registry.discard(stage, v)

    def _build_window(self) -> Optional[TrainWindow]:
        cfg = self.config
        return build_train_window(
            self.db,
            self.store,
            self.embed_batch_fn,
            val_fraction=cfg.val_fraction,
            min_queries=cfg.min_queries,
            seed=cfg.seed,
        )

    def _consider(self, stage: str, window: Optional[TrainWindow]) -> StageDecision:
        cfg = self.config
        # training consumes the watermark whatever happens next — a window
        # that fails to train or gate should not retry every step until
        # traffic doubles it, just fold into the next trigger cycle
        self._seen[stage] = self.store.total_ingested
        if window is None:
            return StageDecision(
                stage,
                "too_few_queries",
                reason=(
                    f"window below min_queries={cfg.min_queries} or too few "
                    f"positive-bearing queries for a held-out gate"
                ),
            )
        # one stage snapshot anchors the whole train -> gate -> activate
        # pass: the re-ranker trains on the representation this snapshot
        # serves (the live adapter's output), the gate judges against it,
        # and the CAS activation refuses if it moved mid-training
        sv, current = self.router.stage_set()
        try:
            trained = self.trainers[stage].train(window, current)
        except ValueError as exc:
            return StageDecision(stage, "train_failed", reason=str(exc))
        # gate on the exact serving composition: candidate = live StageSet
        # with this one stage replaced, judged on the held-out slice
        candidate = trained.apply_to(current)
        val_q = window.query_emb[window.val_idx]
        val_tokens = window.tokens(window.val_idx)
        val_rel = window.pos_mask[window.val_idx]
        mult = getattr(self.router, "candidate_multiplier", 5)
        ndcg_cur = stage_ndcg(
            window.table, val_q, val_tokens, val_rel, current, cfg.k, mult
        )
        ndcg_new = stage_ndcg(
            window.table, val_q, val_tokens, val_rel, candidate, cfg.k, mult
        )
        decision = StageDecision(
            stage, "", ndcg_current=ndcg_cur, ndcg_candidate=ndcg_new
        )
        if not ndcg_new > ndcg_cur + cfg.min_gain:
            decision.action = "gate_rejected"
            decision.reason = (
                f"held-out NDCG@{cfg.k} {ndcg_new:.3f} did not beat the live "
                f"config's {ndcg_cur:.3f} (+{cfg.min_gain})"
            )
            if self.bus is not None:
                self.bus.publish("gate_reject", plane="learn", stage=stage,
                                 reason=decision.reason)
            return decision
        if self.db.table_version != window.table_version:
            # the gate judged this candidate against the window's table
            # snapshot; a refinement swap landed mid-training, so that
            # evidence is stale on the live table — stand down and fold
            # into the next cycle (a swap slipping in after this check is
            # the narrow residual race the StageGuard exists to catch)
            decision.action = "table_moved"
            decision.reason = (
                f"table moved v{window.table_version} -> "
                f"v{self.db.table_version} mid-training; gate evidence is "
                f"stale"
            )
            return decision
        artifact = self.registry.register(
            stage,
            trained.params,
            table_version=window.table_version,
            fingerprint=window.fingerprint,
            metrics={
                "ndcg_current": ndcg_cur,
                "ndcg_candidate": ndcg_new,
                "n_train_queries": float(len(window.train_idx)),
                "n_val_queries": float(len(window.val_idx)),
                **trained.info,
            },
            aux=trained.aux,
        )
        decision.artifact_version = artifact.version
        try:
            # compare-and-swap: this candidate was gated against stage
            # version `sv`; if another promotion landed mid-training, stand
            # down rather than clobber a set the gate never saw
            new_sv = self.router.set_stages(
                trained.apply_to(current, artifact_version=artifact.version),
                expect_version=sv,
            )
        except ConflictError as exc:
            # the artifact never deployed: drop it so it cannot shadow the
            # artifact that won the race as `latest`
            self.registry.discard(stage, artifact.version)
            decision.action = "activation_conflict"
            decision.reason = str(exc)
            return decision
        if self.guard is not None:
            self.guard.note_promotion(sv, new_sv)
        decision.action = "promoted"
        decision.stage_version = new_sv
        decision.reason = (
            f"stage v{sv} -> v{new_sv} (held-out NDCG@{cfg.k} "
            f"{ndcg_cur:.3f} -> {ndcg_new:.3f}, artifact "
            f"{stage}/v{artifact.version})"
        )
        if self.bus is not None:
            self.bus.publish("promotion", plane="learn", stage=stage,
                             from_version=sv, to_version=new_sv,
                             artifact_version=artifact.version)
        return decision

    # ---------------------------------------------------------------- daemon
    def start(self, interval_s: float = 1.0) -> None:
        """Run `step()` on a daemon thread every `interval_s` seconds.

        A failing step is recorded in `self.reports` (reason
        "step failed: ...") AND in `self.last_loop_error` (cleared by the
        next successful step) so a health check can see the failure without
        scanning reports; the loop continues — a transient trainer or
        encoder error must not silently kill the learning plane for the
        rest of the serving process's lifetime."""
        assert self._thread is None, "learning controller already running"
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.step()
                    if self.last_loop_error is not None and self.bus is not None:
                        # transition back to healthy, not one event per step
                        self.bus.publish("loop_recovered", plane="learn",
                                         controller=type(self).__name__)
                    self.last_loop_error = None
                except Exception as exc:  # survive transient failures
                    if self.last_loop_error is None:
                        # crash dump FIRST (reason "crash", full exception),
                        # so the loop_error publish below debounces into it
                        # rather than racing it for the dump slot
                        if self.flight_recorder is not None:
                            try:
                                self.flight_recorder.record_crash(
                                    exc, source=type(self).__name__
                                )
                            except Exception:  # noqa: BLE001 — never rethrow
                                pass  # the black box must not kill the loop
                        if self.bus is not None:
                            self.bus.publish("loop_error", plane="learn",
                                             controller=type(self).__name__,
                                             error=repr(exc))
                    self.last_loop_error = exc
                    self.reports.append(
                        LearnReport(plan=None, reason=f"step failed: {exc!r}")
                    )

        self._thread = threading.Thread(
            target=loop, name="learning-controller", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
