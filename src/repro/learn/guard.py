"""StageGuard: post-promotion shadow monitoring + automatic demotion.

The learning plane's promotion gate protects a StageSet *before* activation
on a held-out slice of the outcome window; this guard protects it *after*,
on live labelled traffic — the same division of labor `TableGuard` gives
table swaps, against the same blind spots (window-vs-traffic distribution
shift, a stage activated out-of-band that bypassed the gate).

Serving code reports each labelled result via
`observe(result.stage_version, result.tools, relevant)`; the guard keeps a
rolling NDCG@k window per stage version, freezes the predecessor's rolling
NDCG as each promoted version's baseline (`note_promotion`, or lazily for
unannounced out-of-band `set_stages` calls), and `check()` demotes a
version regressing past `tolerance` after `min_samples` labels via
`SemanticRouter.rollback_stages(expect_current=...)` — compare-and-swap, so
a promotion that lands after judgement can never be condemned on evidence
it did not generate. The restored StageSet comes back under a new version
with no baseline (it *is* the baseline), so demotion cannot cascade into
flapping — the invariants are `TableGuard`'s, applied to the stage axis.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional

from repro.metrics.retrieval import ndcg_at_k
from repro.obs.quality import RollingWindows
from repro.router.tooldb import ConflictError

__all__ = ["StageGuardConfig", "StageGuardReport", "StageGuard"]


@dataclasses.dataclass(frozen=True)
class StageGuardConfig:
    k: int = 5  # NDCG@k cutoff
    window: int = 256  # rolling observations kept per stage version
    min_samples: int = 32  # judge a version only after this many labels
    tolerance: float = 0.02  # allowed NDCG drop vs the frozen baseline


@dataclasses.dataclass
class StageGuardReport:
    # "healthy" | "insufficient_data" | "no_baseline" | "stale" |
    # "regressed_unrestorable" | "demoted"
    action: str
    stage_version: int  # version under judgement when check() ran
    ndcg: Optional[float] = None
    baseline: Optional[float] = None
    n_samples: int = 0
    restored_version: Optional[int] = None  # new version after a demotion


class StageGuard:
    """Rolling per-stage-version quality monitor over labelled traffic."""

    def __init__(
        self,
        router,
        config: StageGuardConfig = StageGuardConfig(),
        bus: Optional["EventBus"] = None,  # repro.obs.events
    ):
        self.router = router
        self.config = config
        # per-version rolling windows (repro.obs.quality's shared machinery,
        # accessed only under self._lock — RollingWindows is not locked)
        self._ndcg = RollingWindows(config.window)
        self._baseline: Dict[int, Optional[float]] = {}
        self._last_version = router.stage_version
        self._lock = threading.Lock()
        self.demotions: List[StageGuardReport] = []
        self.bus = bus

    # ------------------------------------------------------------- observing
    def observe(
        self,
        stage_version: int,
        ranked_tools: Iterable[int],
        relevant: Iterable[int],
    ) -> None:
        """Record one labelled result against the stage set that served it
        (`RouteResult.stage_version` — NOT `router.stage_version`, which may
        have moved since the batch was scored)."""
        nd = ndcg_at_k(list(ranked_tools), list(relevant), self.config.k)
        with self._lock:
            self._ndcg.push(stage_version, nd)

    def note_promotion(self, old_version: int, new_version: int) -> None:
        """Freeze the outgoing stage set's rolling NDCG as the promoted
        set's baseline (the LearningController calls this right after a
        CAS activation). A predecessor without enough samples yields no
        baseline — the guard then has nothing to judge the promotion by."""
        with self._lock:
            self._baseline[new_version] = (
                self._ndcg.mean(old_version)
                if self._ndcg.n(old_version) >= self.config.min_samples
                else None
            )
            self._last_version = new_version

    def version_stats(self, stage_version: int) -> dict:
        with self._lock:
            return {
                "n": self._ndcg.n(stage_version),
                "ndcg": self._ndcg.mean(stage_version),
                "baseline": self._baseline.get(stage_version),
            }

    # -------------------------------------------------------------- judging
    def check(self) -> StageGuardReport:
        """Judge the live stage set; demote if it regressed past tolerance."""
        with self._lock:
            version = self.router.stage_version
            if version != self._last_version and version not in self._baseline:
                # unannounced promotion (out-of-band set_stages that bypassed
                # the controller): freeze the displaced version's rolling
                # NDCG as its baseline, like TableGuard does for tables
                self._baseline[version] = (
                    self._ndcg.mean(self._last_version)
                    if self._ndcg.n(self._last_version) >= self.config.min_samples
                    else None
                )
            self._last_version = version
            # prune dead versions (neither live nor a demotion target):
            # a long-running daemon under promotion churn must not grow
            # these windows forever
            alive = set(self.router.retained_stage_versions())
            alive.add(version)
            self._ndcg.prune(alive)
            for v in [v for v in self._baseline if v not in alive]:
                del self._baseline[v]
            n = self._ndcg.n(version)
            if n < self.config.min_samples:
                return StageGuardReport("insufficient_data", version, n_samples=n)
            ndcg = self._ndcg.mean(version)
            baseline = self._baseline.get(version)
            if baseline is None:
                return StageGuardReport("no_baseline", version, ndcg=ndcg, n_samples=n)
            if ndcg + self.config.tolerance >= baseline:
                return StageGuardReport(
                    "healthy", version, ndcg=ndcg, baseline=baseline, n_samples=n
                )
            if not self.router.retained_stage_versions():
                return StageGuardReport(
                    "regressed_unrestorable", version,
                    ndcg=ndcg, baseline=baseline, n_samples=n,
                )
        # demotion runs OUTSIDE the guard lock: rollback_stages takes the
        # router's stage lock, and restored stage sets may touch device state
        # on their next application — holding _lock across that would stall
        # every observe() and nest the guard lock around router internals.
        # The compare-and-swap keeps the judgement safe after the release:
        # a promotion landing in the gap makes expect_current refuse.
        try:
            restored = self.router.rollback_stages(expect_current=version)
        except ConflictError:
            # the condemned stage set is no longer live; judge the new
            # one on its own evidence next check
            return StageGuardReport("stale", version, ndcg=ndcg, n_samples=n)
        with self._lock:
            # the restored set IS the new baseline: no judgement, no flap
            self._baseline[restored] = None
            self._last_version = restored
            report = StageGuardReport(
                "demoted",
                version,
                ndcg=ndcg,
                baseline=baseline,
                n_samples=n,
                restored_version=restored,
            )
            self.demotions.append(report)
        if self.bus is not None:  # outside the lock, like the demotion itself
            self.bus.publish(
                "demotion", plane="learn",
                condemned_version=version, restored_version=restored,
                ndcg=ndcg, baseline=baseline,
            )
        return report
