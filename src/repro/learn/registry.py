"""ArtifactRegistry: versioned store of trained stage artifacts.

Every trained stage (adapter head, re-ranker MLP + featurizer) becomes a
`StageArtifact` keyed by (stage, version) and stamped with the table version
it was trained against and a fingerprint of the outcome window it was
trained from — so a live `StageSet` is always attributable to a specific
training run, and a demotion can name exactly what it demoted.

Semantics mirror `ToolsDatabase`: versions are per-stage monotone, history
is bounded (`history_limit`, oldest evicted first), `rollback` drops the
condemned head version and re-exposes the previous artifact as `latest`.
Persistence round-trips through `repro.checkpoint` (msgpack + compression),
the same substrate the outcome window uses, so the learning plane survives
controller restarts with its deployment lineage intact.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.obs import clock

__all__ = ["StageArtifact", "ArtifactRegistry"]


@dataclasses.dataclass(frozen=True)
class StageArtifact:
    stage: str  # "adapter" | "rerank"
    version: int  # per-stage monotone registry version
    table_version: int  # ToolsDatabase version the training set was built on
    fingerprint: str  # OutcomeStore.window_fingerprint() of the train window
    params: dict  # model params (pytree of arrays)
    aux: dict  # stage extras (e.g. featurizer state), pytree of arrays
    metrics: Dict[str, float]  # held-out gate numbers recorded at training
    created_at: float = 0.0


class ArtifactRegistry:
    """Thread-safe bounded per-stage artifact history with rollback."""

    def __init__(self, history_limit: int = 4):
        assert history_limit >= 1
        self.history_limit = int(history_limit)
        # per stage: {version -> artifact}, oldest first, newest == latest
        self._artifacts: Dict[str, "OrderedDict[int, StageArtifact]"] = {}
        self._next_version: Dict[str, int] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ registering
    def register(
        self,
        stage: str,
        params: dict,
        *,
        table_version: int,
        fingerprint: str,
        metrics: Optional[Dict[str, float]] = None,
        aux: Optional[dict] = None,
    ) -> StageArtifact:
        """Record a trained artifact; returns it with its assigned version."""
        with self._lock:
            version = self._next_version.get(stage, 1)
            self._next_version[stage] = version + 1
            artifact = StageArtifact(
                stage=stage,
                version=version,
                table_version=int(table_version),
                fingerprint=str(fingerprint),
                params=params,
                aux=dict(aux or {}),
                metrics={k: float(v) for k, v in (metrics or {}).items()},
                created_at=clock.wall(),
            )
            history = self._artifacts.setdefault(stage, OrderedDict())
            history[version] = artifact
            while len(history) > self.history_limit:
                history.popitem(last=False)
            return artifact

    # ---------------------------------------------------------------- reading
    def stages(self) -> List[str]:
        with self._lock:
            return sorted(self._artifacts)

    def versions(self, stage: str) -> List[int]:
        """Retained versions for a stage, oldest first."""
        with self._lock:
            return list(self._artifacts.get(stage, ()))

    def latest(self, stage: str) -> Optional[StageArtifact]:
        with self._lock:
            history = self._artifacts.get(stage)
            if not history:
                return None
            return history[next(reversed(history))]

    def get(self, stage: str, version: int) -> StageArtifact:
        with self._lock:
            history = self._artifacts.get(stage, OrderedDict())
            if version not in history:
                raise KeyError(
                    f"{stage} artifact v{version} not retained "
                    f"(available: {list(history)})"
                )
            return history[version]

    def discard(self, stage: str, version: int) -> None:
        """Drop one retained artifact (idempotent).

        Used when an activation loses its compare-and-swap race: the
        registered artifact was never deployed, so it must not linger as
        `latest` and shadow the artifact that actually serves."""
        with self._lock:
            self._artifacts.get(stage, OrderedDict()).pop(version, None)

    # --------------------------------------------------------------- rollback
    def rollback(self, stage: str, to_version: Optional[int] = None) -> StageArtifact:
        """Drop artifacts newer than `to_version` (default: drop only the
        newest) and return the artifact that is now `latest` — the registry
        side of a StageGuard demotion, so a re-promotion can never resurrect
        the condemned head version as "latest"."""
        with self._lock:
            history = self._artifacts.get(stage)
            if not history or len(history) < 2 and to_version is None:
                raise RuntimeError(f"no previous {stage} artifact to roll back to")
            if to_version is None:
                newest = next(reversed(history))
                versions = list(history)
                to_version = versions[versions.index(newest) - 1]
            if to_version not in history:
                raise RuntimeError(
                    f"{stage} artifact v{to_version} not retained "
                    f"(available: {list(history)})"
                )
            for v in [v for v in history if v > to_version]:
                del history[v]
            return history[to_version]

    # ------------------------------------------------------------ persistence
    def save(self, directory: str, step: int = 0) -> str:
        """Persist all retained artifacts via repro.checkpoint."""
        with self._lock:
            tree: dict = {}
            meta: dict = {
                "kind": "artifact_registry",
                "history_limit": self.history_limit,
                "next_version": dict(self._next_version),
                "entries": [],
            }
            for stage, history in self._artifacts.items():
                for version, art in history.items():
                    key = f"{stage}/{version}"
                    tree[key] = {"params": art.params, "aux": art.aux}
                    meta["entries"].append({
                        "stage": stage,
                        "version": version,
                        "table_version": art.table_version,
                        "fingerprint": art.fingerprint,
                        "metrics": art.metrics,
                        "created_at": art.created_at,
                    })
        return save_checkpoint(directory, step, tree, meta)

    @classmethod
    def restore(cls, directory: str, step: Optional[int] = None) -> "ArtifactRegistry":
        _, tree, meta = restore_checkpoint(directory, step)
        assert meta.get("kind") == "artifact_registry", (
            f"not an artifact registry: {meta}"
        )
        reg = cls(history_limit=int(meta["history_limit"]))
        for entry in meta["entries"]:
            stage, version = entry["stage"], int(entry["version"])
            blob = tree[f"{stage}/{version}"]
            art = StageArtifact(
                stage=stage,
                version=version,
                table_version=int(entry["table_version"]),
                fingerprint=entry["fingerprint"],
                params=blob["params"],
                aux=blob.get("aux", {}),
                metrics={k: float(v) for k, v in entry["metrics"].items()},
                created_at=float(entry["created_at"]),
            )
            reg._artifacts.setdefault(stage, OrderedDict())[version] = art
        for stage, history in reg._artifacts.items():
            # preserve version order (entries may round-trip out of order)
            reg._artifacts[stage] = OrderedDict(sorted(history.items()))
        reg._next_version = {k: int(v) for k, v in meta["next_version"].items()}
        return reg
