"""StageTrainers: turn an outcome window into trained stage artifacts.

The offline fitting code in `core.adapter` / `core.reranker` consumes dense
benchmark splits; these trainers are the bridge from the control plane's
*streamed* evidence — a `RefinementBatch` built from the `OutcomeStore`
ring — to those same training entry points, run off the hot path by the
`LearningController`:

  * `TrainWindow` freezes everything a training run needs (one table
    snapshot + the window's deduped queries/masks + a train/val split of
    positive-bearing queries) so the run is reproducible and attributable
    to (table_version, window fingerprint);
  * `AdapterTrainer` mines triplets (`mine_triplets`) over the window's
    observed successes and runs `train_adapter` in query-side-only mode
    (`adapt_tools=False`): the product is a pure query-transform whose
    promotion never touches the tool table or any built index;
  * `RerankerTrainer` fits an `OutcomeFeaturizer` on the window, featurizes
    the top-C candidates of every train query, and runs `train_reranker`
    on the *outcome-labelled* (query, candidate) pairs only — unobserved
    pairs carry no label, conflating "not tried" with "failed" is exactly
    the sparse-regime failure §7.3 warns about;
  * `stage_ndcg` is the shared held-out gate metric: NDCG@5 of the ranking
    the serving path would produce under a given `StageSet`, so promotion
    decisions are judged on the exact serving composition (adapter before
    scoring, re-ranker after) rather than a proxy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import adapter as adapter_lib
from repro.core import reranker as reranker_lib
from repro.core.features import OutcomeFeaturizer
from repro.metrics.retrieval import batched_ndcg_at_k
from repro.router.stages import StageSet

__all__ = [
    "TrainWindow",
    "TrainedStage",
    "AdapterTrainer",
    "RerankerTrainer",
    "stage_ndcg",
    "featurizer_to_tree",
    "featurizer_from_tree",
]


@dataclasses.dataclass
class TrainWindow:
    """One frozen training set: table snapshot + outcome-window evidence."""

    table: np.ndarray  # [T, D] snapshot the training set is built on
    table_version: int
    query_emb: np.ndarray  # [Q, D] deduped window queries (batched-encoded)
    query_tokens: List[np.ndarray]
    pos_mask: np.ndarray  # [Q, T] observed successes
    neg_mask: np.ndarray  # [Q, T] observed failures
    tool_category: np.ndarray  # [T]
    train_idx: np.ndarray  # rows used for fitting
    val_idx: np.ndarray  # held-out positive-bearing rows (the gate slice)
    fingerprint: str  # OutcomeStore.window_fingerprint() at build time

    def tokens(self, idx: np.ndarray) -> List[np.ndarray]:
        return [self.query_tokens[i] for i in idx]


@dataclasses.dataclass
class TrainedStage:
    """A trainer's product, ready for the registry + gate."""

    stage: str
    params: dict  # numpy pytree (registry/serving both accept it)
    aux: dict  # extra state the stage needs at serving (featurizer tree)
    info: Dict[str, float]  # training diagnostics for reports/benchmarks

    def apply_to(self, current: StageSet, artifact_version: Optional[int] = None) -> StageSet:
        """Candidate StageSet = `current` with this stage replaced."""
        if self.stage == "adapter":
            return dataclasses.replace(
                current,
                # device-resident params: the hot path applies them per batch
                adapter_params={k: jnp.asarray(v) for k, v in self.params.items()},
                adapter_artifact=artifact_version,
            )
        assert self.stage == "rerank", self.stage
        return dataclasses.replace(
            current,
            mlp_params={k: jnp.asarray(v) for k, v in self.params.items()},
            featurizer=featurizer_from_tree(self.aux),
            rerank_artifact=artifact_version,
        )


# --------------------------------------------------------------------- gate
def stage_ndcg(
    table: np.ndarray,
    query_emb: np.ndarray,
    query_tokens: List[np.ndarray],
    relevance: np.ndarray,
    stages: StageSet,
    k: int = 5,
    candidate_multiplier: int = 5,
) -> float:
    """Held-out NDCG@k of the ranking the serving path produces under
    `stages` — adapter applied to queries before scoring, re-ranker over the
    top-C candidates after, exactly like `SemanticRouter.route_batch`."""
    q = stages.adapt_queries(np.asarray(query_emb, np.float32))
    sims = q @ np.asarray(table, np.float32).T
    if stages.has_reranker:
        c = min(max(k * candidate_multiplier, k), table.shape[0])
        order = np.argsort(-sims, axis=1)[:, :c]
        cand_sims = np.take_along_axis(sims, order, axis=1)
        feats = stages.featurizer.features(q, query_tokens, order, cand_sims)
        topk = np.asarray(
            reranker_lib.rerank_topk(
                stages.mlp_params, jnp.asarray(feats), jnp.asarray(order),
                min(k, c),
            )
        )
    else:
        topk = np.argsort(-sims, axis=1)[:, : min(k, sims.shape[1])]
    return float(batched_ndcg_at_k(jnp.asarray(topk), jnp.asarray(relevance)))


# ------------------------------------------------------------------ trainers
class AdapterTrainer:
    """§4.3 contrastive adapter from streamed outcomes (query-side only)."""

    stage = "adapter"

    def __init__(self, config: Optional[adapter_lib.AdapterConfig] = None):
        # online defaults: adapt_tools=False is the hot-swap contract; a few
        # epochs at a serving-loop-friendly lr (the offline 1e-5/5-epoch
        # schedule assumes many passes over a static corpus, not a bounded
        # window between controller steps) — early stopping on held-out
        # NDCG@5 inside train_adapter keeps the schedule safe
        self.config = config or adapter_lib.AdapterConfig(
            lr=3e-4, epochs=6, adapt_tools=False
        )
        assert not self.config.adapt_tools, (
            "the learning plane serves the adapter query-side only; training "
            "with adapt_tools=True would optimize a different deployment"
        )

    def train(
        self, window: TrainWindow, live_stages: Optional[StageSet] = None
    ) -> TrainedStage:
        # `live_stages` is ignored by design: a trained adapter REPLACES the
        # live one wholesale, so it learns from raw encoder embeddings —
        # composing h(h'(q)) would couple artifacts across generations
        cfg = self.config
        triplets = adapter_lib.mine_triplets(
            window.query_emb[window.train_idx],
            window.table,
            window.pos_mask[window.train_idx],
            n_hard=cfg.n_hard_negatives,
            seed=cfg.seed,
        )
        if len(triplets[0]) == 0:
            raise ValueError(
                "no mineable triplets in the window (every positive-bearing "
                "query lacks enough hard negatives)"
            )
        params, history = adapter_lib.train_adapter(
            window.query_emb[window.train_idx],
            window.table,
            triplets,
            window.query_emb[window.val_idx],
            window.pos_mask[window.val_idx],
            None,
            cfg,
        )
        return TrainedStage(
            stage=self.stage,
            params={k: np.asarray(v) for k, v in params.items()},
            aux={},
            info={
                "n_triplets": float(len(triplets[0])),
                "val_ndcg_first": float(history["val_ndcg"][0]),
                "val_ndcg_best": float(max(history["val_ndcg"])),
            },
        )


class RerankerTrainer:
    """§4.2 MLP re-ranker from outcome-labelled (query, candidate) pairs."""

    stage = "rerank"

    def __init__(
        self,
        config: Optional[reranker_lib.RerankerConfig] = None,
        k: int = 5,
        min_pairs: int = 64,
    ):
        self.config = config or reranker_lib.RerankerConfig(epochs=10)
        self.k = int(k)
        self.min_pairs = int(min_pairs)

    def train(
        self, window: TrainWindow, live_stages: Optional[StageSet] = None
    ) -> TrainedStage:
        cfg = self.config
        tr = window.train_idx
        # the re-ranker runs DOWNSTREAM of the adapter at serving time, so
        # its featurizer and candidate ordering must be fit on the same
        # query representation the serving path scores with — the live
        # adapter's output, when one is active (training/serving skew
        # otherwise: the MLP would score a feature distribution it never saw)
        q = window.query_emb[tr]
        if live_stages is not None:
            q = live_stages.adapt_queries(q)
        c = min(max(self.k * cfg.candidate_multiplier, self.k), window.table.shape[0])
        sims = q @ window.table.T
        order = np.argsort(-sims, axis=1)[:, :c]
        cand_sims = np.take_along_axis(sims, order, axis=1)
        featurizer = OutcomeFeaturizer.fit(
            q,
            window.tokens(tr),
            window.pos_mask[tr],
            order[:, : self.k],
            window.tool_category,
            seed=cfg.seed,
        )
        feats = featurizer.features(q, window.tokens(tr), order, cand_sims)
        labels = np.take_along_axis(window.pos_mask[tr], order, axis=1)
        # train ONLY on observed pairs: an unobserved candidate is unlabelled,
        # not failed (the §7.3 sparse-regime trap)
        observed = np.take_along_axis(
            (window.pos_mask[tr] + window.neg_mask[tr]) > 0, order, axis=1
        )
        n_pairs = int(observed.sum())
        if n_pairs < self.min_pairs:
            raise ValueError(
                f"only {n_pairs} outcome-labelled pairs in the window "
                f"(need >= {self.min_pairs})"
            )
        params, losses = reranker_lib.train_reranker(
            feats[observed], labels[observed], cfg
        )
        return TrainedStage(
            stage=self.stage,
            params={k: np.asarray(v) for k, v in params.items()},
            aux=featurizer_to_tree(featurizer),
            info={
                "n_pairs": float(n_pairs),
                "loss_first": float(losses[0]),
                "loss_last": float(losses[-1]),
            },
        )


# ------------------------------------------- featurizer <-> checkpoint tree
def featurizer_to_tree(f: OutcomeFeaturizer) -> dict:
    """Featurizer state as an array pytree (registry aux / checkpointable)."""
    return {
        "cluster_centroids": np.asarray(f.cluster_centroids),
        "success_rate": np.asarray(f.success_rate),
        "tool_freq": np.asarray(f.tool_freq),
        "tool_category": np.asarray(f.tool_category),
        "cluster_category": np.asarray(f.cluster_category),
        "mean_query_len": np.float64(f.mean_query_len),
    }


def featurizer_from_tree(tree: dict) -> OutcomeFeaturizer:
    return OutcomeFeaturizer(
        cluster_centroids=np.asarray(tree["cluster_centroids"], np.float32),
        success_rate=np.asarray(tree["success_rate"], np.float32),
        tool_freq=np.asarray(tree["tool_freq"], np.float32),
        tool_category=np.asarray(tree["tool_category"], np.int64),
        cluster_category=np.asarray(tree["cluster_category"], np.int64),
        mean_query_len=float(np.asarray(tree["mean_query_len"])),
    )
