"""Retrieval metrics: Recall@K, Precision@K, NDCG@K, MRR (paper §5.2).

All functions operate on a ranked list of tool indices and a set of relevant
tool indices, and are pure numpy (they run in the offline evaluation loop, not
in the serving path). Batched jnp variants are provided for use inside jitted
training/validation code (the Stage-1 validation gate, Stage-3 early stopping).
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "recall_at_k",
    "precision_at_k",
    "ndcg_at_k",
    "mrr",
    "evaluate_ranking",
    "batched_recall_at_k",
    "batched_ndcg_at_k",
]


def recall_at_k(ranked: Sequence[int], relevant: Iterable[int], k: int) -> float:
    rel = set(relevant)
    if not rel:
        return 0.0
    hits = sum(1 for t in list(ranked)[:k] if t in rel)
    return hits / len(rel)


def precision_at_k(ranked: Sequence[int], relevant: Iterable[int], k: int) -> float:
    if k <= 0:
        return 0.0
    rel = set(relevant)
    hits = sum(1 for t in list(ranked)[:k] if t in rel)
    return hits / k


def ndcg_at_k(ranked: Sequence[int], relevant: Iterable[int], k: int) -> float:
    """Binary-gain NDCG@K."""
    rel = set(relevant)
    if not rel:
        return 0.0
    dcg = 0.0
    for pos, t in enumerate(list(ranked)[:k]):
        if t in rel:
            dcg += 1.0 / np.log2(pos + 2.0)
    ideal_hits = min(len(rel), k)
    idcg = sum(1.0 / np.log2(pos + 2.0) for pos in range(ideal_hits))
    return dcg / idcg


def mrr(ranked: Sequence[int], relevant: Iterable[int]) -> float:
    rel = set(relevant)
    for pos, t in enumerate(ranked):
        if t in rel:
            return 1.0 / (pos + 1.0)
    return 0.0


def evaluate_ranking(
    ranked: Sequence[int], relevant: Iterable[int], ks: Sequence[int] = (1, 3, 5)
) -> dict:
    """All paper metrics for one query."""
    out = {}
    for k in ks:
        out[f"recall@{k}"] = recall_at_k(ranked, relevant, k)
        out[f"precision@{k}"] = precision_at_k(ranked, relevant, k)
        out[f"ndcg@{k}"] = ndcg_at_k(ranked, relevant, k)
    out["mrr"] = mrr(ranked, relevant)
    return out


# --------------------------------------------------------------------------
# Batched jnp variants (used inside jit: validation gate / early stopping).
# Relevance is a dense [n_queries, n_tools] 0/1 matrix; rankings are
# [n_queries, k] index matrices. Queries with no relevant tools contribute 0
# and are excluded from the mean via the `valid` mask.
# --------------------------------------------------------------------------


def _gains(rankings: jnp.ndarray, relevance: jnp.ndarray) -> jnp.ndarray:
    # rankings: [Q, k] int32; relevance: [Q, T] {0,1} -> [Q, k] gains
    return jnp.take_along_axis(relevance, rankings, axis=1)


def batched_recall_at_k(rankings: jnp.ndarray, relevance: jnp.ndarray) -> jnp.ndarray:
    """Mean Recall@k over queries that have >=1 relevant tool.

    rankings: [Q, k] indices into the tool axis. relevance: [Q, T] binary.
    """
    gains = _gains(rankings, relevance)
    n_rel = relevance.sum(axis=1)
    valid = n_rel > 0
    rec = jnp.where(valid, gains.sum(axis=1) / jnp.maximum(n_rel, 1), 0.0)
    return rec.sum() / jnp.maximum(valid.sum(), 1)


def batched_ndcg_at_k(rankings: jnp.ndarray, relevance: jnp.ndarray) -> jnp.ndarray:
    """Mean binary-gain NDCG@k, k = rankings.shape[1]."""
    k = rankings.shape[1]
    gains = _gains(rankings, relevance)  # [Q, k]
    discounts = 1.0 / jnp.log2(jnp.arange(k, dtype=jnp.float32) + 2.0)  # [k]
    dcg = (gains * discounts).sum(axis=1)
    n_rel = relevance.sum(axis=1)
    ideal_hits = jnp.minimum(n_rel, k)  # [Q]
    # idcg = sum of first ideal_hits discounts
    cum = jnp.cumsum(discounts)
    idcg = jnp.where(
        ideal_hits > 0, cum[jnp.maximum(ideal_hits.astype(jnp.int32) - 1, 0)], 1.0
    )
    valid = n_rel > 0
    ndcg = jnp.where(valid, dcg / idcg, 0.0)
    return ndcg.sum() / jnp.maximum(valid.sum(), 1)
