"""Model configuration for the backend zoo.

One frozen dataclass covers all six architecture families (dense / moe / ssm /
hybrid / vlm / audio). Family-specific fields are zero/off by default; the
assigned-architecture configs in `repro.configs` set them per the public
sources cited there.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False  # qwen2.5-style QKV bias
    attn_bias: bool = False  # bias on o-proj and MLP (stablelm uses none)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # ---- MoE ----
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # expert hidden dim (defaults to d_ff)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3
    load_balance_weight: float = 1e-2
    # ---- SSM (Mamba-2 / SSD, arXiv:2405.21060) ----
    ssm_state: int = 0  # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # P
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1
    # ---- hybrid (hymba, arXiv:2411.13676): parallel attn + SSM heads ----
    hybrid: bool = False
    # ---- VLM (llama-3.2-vision): gated cross-attn every Nth layer ----
    cross_attn_every: int = 0  # 0 = no cross-attn layers
    n_image_tokens: int = 0  # patch embeddings from the (stubbed) vision tower
    # ---- audio (musicgen): decoder over EnCodec tokens ----
    n_codebooks: int = 0  # frontend codec is stubbed; tokens arrive directly
    # ---- attention variant ----
    sliding_window: int = 0  # 0 = full causal; >0 = ring-buffer window
    # ---- numerics ----
    dtype: str = "bfloat16"
    # ---- remat ----
    remat: bool = False
    # ---- dry-run probes: fully unroll scans so XLA cost analysis is exact ----
    scan_unroll: bool = False
    # ---- MoE dispatch impl: "gspmd" (baseline scatter) | "shard_map" (§Perf) ----
    moe_impl: str = "gspmd"
    # ---- §Perf: repeat KV to all H heads so attention shards over "model"
    # even when kv_heads doesn't divide the axis (costs kv-activation memory) ----
    repeat_kv: bool = False
    # ---- §Perf: decode attention over a seq-sharded KV cache (flash-decoding
    # shard_map; use with sharding policy "tp_kvs") ----
    decode_attn: str = "gspmd"  # gspmd | seq_shard

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def q_groups(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.d_inner % self.ssm_head_dim == 0
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type == "ssm" or self.hybrid

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: native for SSM/hybrid, via window otherwise."""
        return self.has_ssm or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline sanity)."""
        d, v, L = self.d_model, self.vocab_size, self.n_layers
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        kb = self.n_codebooks or 1  # musicgen: K codebook embeddings + heads
        n = kb * v * d  # embed
        if not self.tie_embeddings:
            n += d * kb * v  # lm head
        n += d  # final norm
        if self.arch_type == "ssm":
            per = self._ssm_params() + d
            return n + L * per
        attn = d * H * hd + 2 * d * Hkv * hd + H * hd * d
        if self.qkv_bias:
            attn += (H + 2 * Hkv) * hd
        mlp = 3 * d * self.d_ff  # swiglu
        per = attn + 2 * d  # + norms
        if self.arch_type == "moe":
            moe = self.n_experts * 3 * d * self.expert_ff + d * self.n_experts
            per += moe + (mlp if self.dense_residual else 0)
        else:
            per += mlp
        if self.hybrid:
            per += self._ssm_params()
        n_cross = L // self.cross_attn_every if self.cross_attn_every else 0
        total = n + (L - n_cross) * per
        if n_cross:
            # n_layers counts BOTH self and cross layers (e.g. 100 = 80 + 20);
            # the vision tower itself is stubbed and not counted (DESIGN.md §5)
            cross = (
                d * H * hd + 2 * d * Hkv * hd + H * hd * d + 3 * d * self.d_ff + 2 * d + 2
            )
            total += n_cross * cross
        return total

    def _ssm_params(self) -> int:
        d, di, N = self.d_model, self.d_inner, self.ssm_state
        H = self.ssm_heads
        G = self.ssm_n_groups
        in_proj = d * (2 * di + 2 * G * N + H)
        conv = (di + 2 * G * N) * self.ssm_conv_width
        return in_proj + conv + 3 * H + di * d + di  # + A_log, D, dt_bias, out_proj, norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * self.expert_ff
        return self.param_count() - L * inactive


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    hd = 64
    n_heads = max(d_model // hd, 2)
    n_kv = max(min(cfg.n_kv_heads, n_heads), 1)
    while n_heads % n_kv:
        n_kv -= 1
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=2 if not cfg.cross_attn_every else 2 * cfg.cross_attn_every,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=min(cfg.expert_ff, 256) if cfg.n_experts else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.has_ssm else cfg.ssm_head_dim,
        ssm_chunk=32,
        n_image_tokens=min(cfg.n_image_tokens, 16) if cfg.n_image_tokens else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        dtype="float32",
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
