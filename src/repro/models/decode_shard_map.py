"""Seq-sharded decode attention via shard_map (flash-decoding combine).

For architectures whose kv_heads don't divide the "model" axis (musicgen 24,
command-r/arctic/granite/dbrx/llama-vision 8, qwen 2, hymba 5), the baseline
replicates the decode KV cache across all 16 model shards — e.g. musicgen
decode_32k carries 77 GB/device of replicated cache (memory term 95 ms).

Here the cache's SEQUENCE dim is sharded over "model" (policy `tp_kvs`), and
one-token attention runs as flash-decoding: each shard computes a partial
(max, sum-exp, weighted-V) over its cache slice; the combine is a pmax + two
tiny psums of [B, H, hd]-sized partials. Cache write lands only on the owner
shard of the current ring slot. HBM per device drops ~16x; the added wire is
O(B*H*hd) per layer — microscopic next to the cache it replaces.

The naive alternative (a GSPMD sharding constraint on the cache) measurably
backfires: the partitioner all-gathers the full cache per step (measured
296 ms collective on musicgen decode_32k). Pinning the dataflow with
shard_map is the point of this module.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common import meshctx
from repro.models.config import ModelConfig

__all__ = ["attn_decode_seq_sharded"]

NEG = -2.0**30


def _batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def attn_decode_seq_sharded(
    cfg: ModelConfig,
    q: jnp.ndarray,  # [B, 1, H, hd] (roped)
    k: jnp.ndarray,  # [B, 1, Hkv, hd] (roped)
    v: jnp.ndarray,  # [B, 1, Hkv, hd]
    cache_k: jnp.ndarray,  # [B, W, Hkv, hd], seq dim sharded over "model"
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,  # scalar absolute position
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    mesh = meshctx.current_mesh()
    w_global = cache_k.shape[1]
    hd = q.shape[-1]
    m = meshctx.axis_sizes_dict(mesh).get("model", 1)
    baxes = _batch_axes(mesh)
    bspec = baxes if baxes else None

    def local(q_l, k_l, v_l, ck, cv, pos_s):
        # ck/cv: [B_l, W/m, Hkv, hd] local slice; q_l: [B_l, 1, H, hd]
        w_local = ck.shape[1]
        shard = jax.lax.axis_index("model")
        slot_g = pos_s % w_global if cfg.sliding_window else pos_s
        owner = slot_g // w_local
        slot_l = slot_g % w_local
        upd_k = jax.lax.dynamic_update_slice_in_dim(ck, k_l, slot_l, axis=1)
        upd_v = jax.lax.dynamic_update_slice_in_dim(cv, v_l, slot_l, axis=1)
        is_owner = shard == owner
        ck = jnp.where(is_owner, upd_k, ck)
        cv = jnp.where(is_owner, upd_v, cv)

        # validity in GLOBAL coordinates
        kidx = shard * w_local + jnp.arange(w_local)
        if cfg.sliding_window:
            limit = jnp.minimum(pos_s, w_global - 1)
        else:
            limit = pos_s
        valid = kidx <= limit  # [W/m]

        b, _, h, _ = q_l.shape
        hkv = ck.shape[2]
        g = h // hkv
        qg = q_l.reshape(b, hkv, g, hd)
        logits = jnp.einsum("bkgd,btkd->bkgt", qg, ck).astype(jnp.float32) / np.sqrt(hd)
        logits = jnp.where(valid[None, None, None, :], logits, NEG)
        # flash-decoding combine across seq shards
        lmax = logits.max(axis=-1, keepdims=True)  # [B,Hkv,g,1]
        gmax = jax.lax.pmax(lmax, "model")
        p = jnp.exp(logits - gmax)
        den = jax.lax.psum(p.sum(axis=-1, keepdims=True), "model")
        num = jnp.einsum("bkgt,btkd->bkgd", p.astype(cv.dtype), cv)
        num = jax.lax.psum(num, "model")
        out = (num / jnp.maximum(den, 1e-30).astype(num.dtype)).reshape(b, 1, h, hd)
        return out, ck, cv

    return meshctx.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),  # q (replicated over model)
            P(bspec, None, None, None),  # k
            P(bspec, None, None, None),  # v
            P(bspec, "model", None, None),  # cache_k: seq-sharded
            P(bspec, "model", None, None),  # cache_v
            P(),  # pos
        ),
        out_specs=(
            P(bspec, None, None, None),
            P(bspec, "model", None, None),
            P(bspec, "model", None, None),
        ),
    )(q, k, v, cache_k, cache_v, jnp.asarray(pos).reshape(()))
