"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full causal /
sliding window / decode), SwiGLU MLP, capacity-based MoE, gated cross-attn.

All functions are pure; parameters arrive as sub-dicts created from the spec
trees in `repro.models.model`. Activation sharding uses logical constraints
(`repro.common.sharding`) so the same code lowers on 1 CPU device and on the
(pod, data, model) production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import meshctx
from repro.common.sharding import logical_constraint as shard
from repro.models.config import ModelConfig

__all__ = [
    "rms_norm",
    "rope",
    "gqa_attention",
    "attn_block",
    "attn_decode",
    "swiglu",
    "moe_block",
    "cross_attn_block",
]

NEG_INF = -2.0**30


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [B, S, H, hd]; positions: [B, S] absolute."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def gqa_attention(
    q: jnp.ndarray,  # [B, S, H, hd]
    k: jnp.ndarray,  # [B, T, Hkv, hd]
    v: jnp.ndarray,  # [B, T, Hkv, hd]
    mask: jnp.ndarray,  # [B or 1, S, T] boolean (True = attend)
    repeat_kv: bool = False,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if repeat_kv and g > 1:
        # §Perf: materialize KV per q-head so the score/pv einsums carry a
        # single head dim that shards (possibly unevenly) over "model" —
        # avoids full attention replication when hkv doesn't divide the axis
        k = shard(jnp.repeat(k, g, axis=2), "batch", None, "heads", None)
        v = shard(jnp.repeat(v, g, axis=2), "batch", None, "heads", None)
        logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(hd)
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)
    qg = q.reshape(b, s, hkv, g, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / np.sqrt(hd)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def _causal_mask(s: int, t: int, q_offset, window: int) -> jnp.ndarray:
    """[1, S, T] causal (+optional window) mask; q position i = q_offset + i."""
    qpos = q_offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None]


def _qkv(p: dict, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])  # [B,S,H,hd]
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])  # [B,S,Hkv,hd]
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def attn_block(
    p: dict,
    x: jnp.ndarray,  # [B, S, D] (already normed)
    cfg: ModelConfig,
    positions: jnp.ndarray,  # [B, S]
    return_cache: bool = False,
    max_cache_len: int = 0,
) -> jnp.ndarray | Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence causal attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    mask = _causal_mask(s, s, 0, cfg.sliding_window)
    out = gqa_attention(q, k, v, mask, repeat_kv=cfg.repeat_kv)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    out = shard(out, "batch", "act_seq", None)
    if not return_cache:
        return out
    # prefill: build the decode cache [B, W, Hkv, hd].
    #  * sliding window: keep the last W entries, rolled so that entry for
    #    absolute position p sits at ring slot p % W (decode convention);
    #  * full attention: pad to `max_cache_len` slots (decode budget).
    w = cfg.sliding_window
    if w and w < s:
        k, v = k[:, s - w :], v[:, s - w :]
        if s % w:
            k = jnp.roll(k, s % w, axis=1)
            v = jnp.roll(v, s % w, axis=1)
    elif max_cache_len and max_cache_len > k.shape[1]:
        pad = max_cache_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, (k, v)


def attn_decode(
    p: dict,
    x: jnp.ndarray,  # [B, 1, D] (already normed)
    cfg: ModelConfig,
    cache_k: jnp.ndarray,  # [B, W, Hkv, hd] ring buffer (keys stored roped)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,  # [] or [B] — absolute position of the new token
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against a (possibly ring-buffered) KV cache."""
    b, _, d = x.shape
    w = cache_k.shape[1]
    positions = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1), (b, 1))
    q, k, v = _qkv(p, x, cfg, positions)
    if cfg.decode_attn == "seq_shard":
        mesh = meshctx.current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            from repro.models.decode_shard_map import attn_decode_seq_sharded

            out, cache_k, cache_v = attn_decode_seq_sharded(
                cfg, q, k, v, cache_k, cache_v, pos
            )
            out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            return shard(out, "batch", None, None), cache_k, cache_v
    slot = jnp.asarray(pos).reshape(()) % w if cfg.sliding_window else jnp.asarray(pos).reshape(())
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    # validity: ring slots written so far; keys keep absolute-position RoPE
    kidx = jnp.arange(w)
    if cfg.sliding_window:
        valid = kidx[None, :] <= jnp.minimum(jnp.asarray(pos).reshape(()), w - 1)
    else:
        valid = kidx[None, :] <= jnp.asarray(pos).reshape(())
    mask = valid[:, None, :]  # [1, 1, W]
    out = gqa_attention(q, cache_k, cache_v, mask, repeat_kv=cfg.repeat_kv)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(out, "batch", None, None), cache_k, cache_v


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w_up"]
    )
    h = shard(h, "batch", None, "ff")
    return shard(jnp.einsum("bsf,fd->bsd", h, p["w_down"]), "batch", "act_seq", None)


# --------------------------------------------------------------------------
# Mixture of Experts: capacity-based scatter dispatch (DESIGN.md §6).
# --------------------------------------------------------------------------


def moe_block(
    p: dict, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed experts with capacity; returns (y, aux_loss).

    Dispatch is a scatter into per-expert buffers [E, C, D] (sharded over the
    "experts"->"model" axis), expert FFNs run as one batched einsum, and
    tokens gather their k expert outputs back. GSPMD turns the
    scatter/gather into all-to-all-style collectives across the model axis.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    cap = max(int(np.ceil(t * k / e * cfg.capacity_factor)), 1)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # [T, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) assignment within its expert's buffer
    flat_e = top_e.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot  # pre-count
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*k]
    keep = slot < cap
    target = jnp.where(keep, flat_e * cap + slot, e * cap)  # overflow -> dropped row

    data = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(x.dtype)
    buffers = jnp.zeros((e * cap + 1, d), x.dtype).at[target].add(data)
    buf = buffers[: e * cap].reshape(e, cap, d)
    buf = shard(buf, "experts", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    h = shard(h, "experts", None, "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), x.dtype)], axis=0)
    # The token gather-back uses GLOBAL row ids into the expert-sharded
    # buffer; the 0.4.x SPMD partitioner lowers that gather against the
    # *local* shard without a collective (silently wrong rows). Pin the
    # buffer replicated first — the all-gather this inserts is the same
    # collective a correct partition of the gather would have to emit.
    out_buf = shard(out_buf, None, None)

    gathered = out_buf[target]  # [T*k, D]
    w = (top_w.reshape(-1) * keep).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1).reshape(b, s, d)
    y = shard(y, "batch", "act_seq", None)

    # Switch-style load-balance loss + router z-loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    lb = e * jnp.sum(frac_tokens * frac_probs) * cfg.load_balance_weight
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_weight
    return y, lb + z


# --------------------------------------------------------------------------
# Gated cross-attention (llama-3.2-vision style image layers).
# --------------------------------------------------------------------------


def cross_attn_block(
    p: dict,
    x: jnp.ndarray,  # [B, S, D] text stream
    cfg: ModelConfig,
    img_k: jnp.ndarray,  # [B, I, Hkv, hd] precomputed from patch embeddings
    img_v: jnp.ndarray,
) -> jnp.ndarray:
    """x + tanh(g_a)*xattn + tanh(g_f)*ffn — the vision-conditioning layer."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q = shard(q, "batch", None, "heads", None)
    b, s = x.shape[:2]
    mask = jnp.ones((1, s, img_k.shape[1]), dtype=bool)  # full cross attention
    out = gqa_attention(q, img_k, img_v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    x = x + jnp.tanh(p["gate_attn"]) * out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + jnp.tanh(p["gate_ffn"]) * swiglu(p["mlp"], h)
    return x


def cross_attn_kv(p: dict, img_embeds: jnp.ndarray, cfg: ModelConfig):
    """Project (stubbed) vision-tower patch embeddings to K/V once."""
    k = jnp.einsum("bid,dhk->bihk", img_embeds, p["wk"])
    v = jnp.einsum("bid,dhk->bihk", img_embeds, p["wv"])
    return shard(k, "batch", None, "kv_heads", None), shard(
        v, "batch", None, "kv_heads", None
    )
