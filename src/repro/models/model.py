"""Unified backend model: spec construction + train/prefill/decode programs.

One module covers all six assigned families (dense / moe / ssm / hybrid /
vlm / audio). Layers are scanned with stacked parameters so HLO size is O(1)
in depth (a 100-layer VLM lowers as fast as a 2-layer smoke model) and remat
policy attaches to the scan body.

Program surface (what the launcher lowers):
  train_step(params, opt_state, batch)        — in launch/train.py
  forward / loss_fn(params, batch)            — here
  prefill(params, batch) -> (logits, cache)   — here
  decode_step(params, cache, batch)           — here
Batch layouts are produced by `repro.launch.specs.input_specs`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import logical_constraint as shard
from repro.models import layers as lyr
from repro.models import ssm as ssm_lib
from repro.models.moe_shard_map import moe_block_shard_map
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec as PS
from repro.models.params import init_params

__all__ = [
    "make_specs",
    "init",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "cache_spec",
]


# ============================================================ spec building
def _attn_specs(cfg: ModelConfig, n: int, stack_axis: str = "layers") -> Dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": PS((n, d, h, hd), (stack_axis, "embed", "heads", None)),
        "wk": PS((n, d, hkv, hd), (stack_axis, "embed", "kv_heads", None)),
        "wv": PS((n, d, hkv, hd), (stack_axis, "embed", "kv_heads", None)),
        "wo": PS((n, h, hd, d), (stack_axis, "heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = PS((n, h, hd), (stack_axis, "heads", None), "zeros")
        s["bk"] = PS((n, hkv, hd), (stack_axis, "kv_heads", None), "zeros")
        s["bv"] = PS((n, hkv, hd), (stack_axis, "kv_heads", None), "zeros")
    return s


def _mlp_specs(cfg: ModelConfig, n: int, ff: Optional[int] = None, stack_axis="layers"):
    d = cfg.d_model
    f = ff or cfg.d_ff
    return {
        "w_gate": PS((n, d, f), (stack_axis, "embed", "ff")),
        "w_up": PS((n, d, f), (stack_axis, "embed", "ff")),
        "w_down": PS((n, f, d), (stack_axis, "ff", "embed")),
    }


def _moe_specs(cfg: ModelConfig, n: int):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    return {
        "router": PS((n, d, e), ("layers", "embed", None)),
        "w_gate": PS((n, e, d, f), ("layers", "experts", "embed", "ff")),
        "w_up": PS((n, e, d, f), ("layers", "experts", "embed", "ff")),
        "w_down": PS((n, e, f, d), ("layers", "experts", "ff", "embed")),
    }


def _ssm_specs(cfg: ModelConfig, n: int):
    d, di = cfg.d_model, cfg.d_inner
    gn = cfg.ssm_n_groups * cfg.ssm_state
    h = cfg.ssm_heads
    dproj = 2 * di + 2 * gn + h
    conv_c = di + 2 * gn
    k = cfg.ssm_conv_width
    return {
        "in_proj": PS((n, d, dproj), ("layers", "embed", None)),
        "conv_w": PS((n, k, conv_c), ("layers", None, None)),
        "conv_b": PS((n, conv_c), ("layers", None), "zeros"),
        "a_log": PS((n, h), ("layers", "ssm_heads"), "zeros"),
        "d_skip": PS((n, h), ("layers", "ssm_heads"), "ones"),
        "dt_bias": PS((n, h), ("layers", "ssm_heads"), "zeros"),
        "norm": PS((n, di), ("layers", None), "ones"),
        "out_proj": PS((n, di, d), ("layers", None, "embed")),
    }


def make_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    L = cfg.n_layers
    n_cross = L // cfg.cross_attn_every if cfg.cross_attn_every else 0
    n_self = L - n_cross
    kb = cfg.n_codebooks or 1

    specs: Dict[str, Any] = {
        "embed": PS((kb * v, d), ("vocab", "embed"), "embed"),
        "ln_f": PS((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = PS((d, kb * v), ("embed", "vocab"))

    layer: Dict[str, Any] = {"ln1": PS((n_self, d), ("layers", None), "ones")}
    if cfg.arch_type == "ssm":
        layer["ssm"] = _ssm_specs(cfg, n_self)
    else:
        layer["attn"] = _attn_specs(cfg, n_self)
        layer["ln2"] = PS((n_self, d), ("layers", None), "ones")
        if cfg.arch_type == "moe":
            layer["moe"] = _moe_specs(cfg, n_self)
            if cfg.dense_residual:
                layer["mlp"] = _mlp_specs(cfg, n_self)
        else:
            layer["mlp"] = _mlp_specs(cfg, n_self)
        if cfg.hybrid:
            layer["ssm"] = _ssm_specs(cfg, n_self)
    specs["layers"] = layer

    if n_cross:
        specs["cross"] = {
            **_attn_specs(cfg, n_cross, "stack"),
            "ln1": PS((n_cross, d), ("stack", None), "ones"),
            "ln2": PS((n_cross, d), ("stack", None), "ones"),
            "gate_attn": PS((n_cross,), ("stack",), "zeros"),
            "gate_ffn": PS((n_cross,), ("stack",), "zeros"),
            "mlp": _mlp_specs(cfg, n_cross, stack_axis="stack"),
        }
    return specs


def init(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    return init_params(key, make_specs(cfg), dtype=jnp.dtype(cfg.dtype))


# ============================================================== embedding
def _embed_tokens(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    tokens = batch["tokens"]
    if cfg.n_codebooks:
        # musicgen: sum the K codebook embeddings (tokens [B, S, K])
        offsets = jnp.arange(cfg.n_codebooks, dtype=tokens.dtype) * cfg.vocab_size
        x = jnp.take(params["embed"], tokens + offsets, axis=0).sum(axis=2)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, "batch", "act_seq", None)


def _logits(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    x = lyr.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.n_codebooks:
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    return logits


# =============================================================== layer body
def _self_layer(cfg: ModelConfig, lp, x, positions):
    """One decoder layer (train/prefill, no cache). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = lyr.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.arch_type == "ssm":
        return x + ssm_lib.ssm_block(lp["ssm"], h, cfg), aux
    attn_out = lyr.attn_block(lp["attn"], h, cfg, positions)
    if cfg.hybrid:
        attn_out = 0.5 * (attn_out + ssm_lib.ssm_block(lp["ssm"], h, cfg))
    x = x + attn_out
    h = lyr.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        moe_fn = moe_block_shard_map if cfg.moe_impl == "shard_map" else lyr.moe_block
        y, aux = moe_fn(lp["moe"], h, cfg)
        if cfg.dense_residual:
            y = y + lyr.swiglu(lp["mlp"], h)
        x = x + y
    else:
        x = x + lyr.swiglu(lp["mlp"], h)
    return x, aux


def _scan_layers(cfg: ModelConfig, params, x, positions, img_kv=None):
    """Scan the decoder stack; interleaves cross-attn groups for VLMs."""

    def body(carry, lp):
        y, aux = _self_layer(cfg, lp, carry, positions)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body)

    un = cfg.scan_unroll
    if not cfg.cross_attn_every:
        n_self = cfg.n_layers
        x, auxes = jax.lax.scan(body, x, params["layers"], unroll=n_self if un else 1)
        return x, auxes.sum()

    # VLM: groups of (cross_attn_every - 1) self layers + 1 cross layer
    cae = cfg.cross_attn_every
    n_cross = cfg.n_layers // cae
    per = cae - 1
    grouped = jax.tree.map(
        lambda t: t.reshape((n_cross, per) + t.shape[1:]), params["layers"]
    )
    img_k, img_v = img_kv

    def group_body(carry, inp):
        gp, cp, gk, gv = inp
        y, auxes = jax.lax.scan(body, carry, gp, unroll=per if un else 1)
        y = lyr.cross_attn_block(cp, y, cfg, gk, gv)
        return y, auxes.sum()

    x, auxes = jax.lax.scan(
        group_body, x, (grouped, params["cross"], img_k, img_v),
        unroll=n_cross if un else 1,
    )
    return x, auxes.sum()


def _cross_kv_all(cfg: ModelConfig, params, img_embeds):
    """Project patch embeddings to per-cross-layer K/V: [G, B, I, Hkv, hd]."""
    return jax.vmap(
        lambda wk, wv: lyr.cross_attn_kv({"wk": wk, "wv": wv}, img_embeds, cfg)
    )(params["cross"]["wk"], params["cross"]["wv"])


# ================================================================= programs
def forward(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced forward: logits [B,S,(K,)V], aux loss."""
    x = _embed_tokens(cfg, params, batch)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    img_kv = None
    if cfg.cross_attn_every:
        img_kv = _cross_kv_all(cfg, params, batch["image_embeds"].astype(x.dtype))
    x, aux = _scan_layers(cfg, params, x, positions, img_kv)
    return _logits(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(cfg, params, batch)
    targets = batch["tokens"][:, 1:]
    logits = logits[:, :-1].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = nll.mean()
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ---------------------------------------------------------------- caching
def cache_spec(cfg: ModelConfig, batch_size: int, seq_len: int) -> Dict[str, Any]:
    """Shapes+logical axes of the decode cache for (batch, context length)."""
    w = min(cfg.sliding_window, seq_len) if cfg.sliding_window else seq_len
    n_cross = cfg.n_layers // cfg.cross_attn_every if cfg.cross_attn_every else 0
    n_self = cfg.n_layers - n_cross
    spec: Dict[str, Any] = {}
    if cfg.has_attention:
        spec["k"] = PS(
            (n_self, batch_size, w, cfg.n_kv_heads, cfg.hd),
            ("layers", "batch", "kv_seq", "kv_heads", None),
            "zeros",
        )
        spec["v"] = dataclasses.replace(spec["k"])
    if cfg.has_ssm:
        conv_c = cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
        spec["conv"] = PS(
            (n_self, batch_size, cfg.ssm_conv_width - 1, conv_c),
            ("layers", "batch", None, None),
            "zeros",
        )
        spec["state"] = PS(
            (n_self, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            ("layers", "batch", "ssm_heads", None, "state"),
            "zeros",
        )
    if n_cross:
        spec["img_k"] = PS(
            (n_cross, batch_size, cfg.n_image_tokens, cfg.n_kv_heads, cfg.hd),
            ("stack", "batch", "image", "kv_heads", None),
            "zeros",
        )
        spec["img_v"] = dataclasses.replace(spec["img_k"])
    return spec


def prefill(
    cfg: ModelConfig, params, batch, max_cache_len: int = 0
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Process the full prompt; return last-position logits + decode cache.

    `max_cache_len` sizes the full-attention KV cache for subsequent decode
    steps (defaults to prompt length + 1; windowed/SSM caches are fixed-size).
    """
    x = _embed_tokens(cfg, params, batch)
    b, s = x.shape[:2]
    max_cache_len = max_cache_len or (s + 1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cache: Dict[str, Any] = {}
    img_kv = None
    if cfg.cross_attn_every:
        img_kv = _cross_kv_all(cfg, params, batch["image_embeds"].astype(x.dtype))
        cache["img_k"], cache["img_v"] = img_kv

    def body(carry, lp):
        y = carry
        out_cache = {}
        h = lyr.rms_norm(y, lp["ln1"], cfg.norm_eps)
        if cfg.arch_type == "ssm":
            out, (conv, st) = ssm_lib.ssm_block(lp["ssm"], h, cfg, return_cache=True)
            y = y + out
            out_cache["conv"], out_cache["state"] = conv, st
        else:
            attn_out, (ck, cv) = lyr.attn_block(
                lp["attn"], h, cfg, positions, return_cache=True,
                max_cache_len=max_cache_len,
            )
            out_cache["k"], out_cache["v"] = ck, cv
            if cfg.hybrid:
                s_out, (conv, st) = ssm_lib.ssm_block(lp["ssm"], h, cfg, return_cache=True)
                attn_out = 0.5 * (attn_out + s_out)
                out_cache["conv"], out_cache["state"] = conv, st
            y = y + attn_out
            h2 = lyr.rms_norm(y, lp["ln2"], cfg.norm_eps)
            if cfg.arch_type == "moe":
                moe_fn = (
                    moe_block_shard_map if cfg.moe_impl == "shard_map" else lyr.moe_block
                )
                m, _ = moe_fn(lp["moe"], h2, cfg)
                if cfg.dense_residual:
                    m = m + lyr.swiglu(lp["mlp"], h2)
                y = y + m
            else:
                y = y + lyr.swiglu(lp["mlp"], h2)
        return y, out_cache

    un = cfg.scan_unroll
    if not cfg.cross_attn_every:
        x, layer_cache = jax.lax.scan(
            body, x, params["layers"], unroll=cfg.n_layers if un else 1
        )
    else:
        cae = cfg.cross_attn_every
        n_cross = cfg.n_layers // cae
        grouped = jax.tree.map(
            lambda t: t.reshape((n_cross, cae - 1) + t.shape[1:]), params["layers"]
        )

        def group_body(carry, inp):
            gp, cp, gk, gv = inp
            y, gcache = jax.lax.scan(body, carry, gp, unroll=(cae - 1) if un else 1)
            y = lyr.cross_attn_block(cp, y, cfg, gk, gv)
            return y, gcache

        x, layer_cache = jax.lax.scan(
            group_body, x, (grouped, params["cross"], img_kv[0], img_kv[1]),
            unroll=n_cross if un else 1,
        )
        # [G, per, ...] -> [L_self, ...]
        layer_cache = jax.tree.map(
            lambda t: t.reshape((-1,) + t.shape[2:]), layer_cache
        )
    # attention KV is cached transposed to [L, B, W, Hkv, hd] already
    cache.update(layer_cache)
    logits = _logits(cfg, params, x[:, -1:])
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, batch):
    """One-token decode. batch = {"token": [B,1(,K)], "pos": scalar int32}."""
    x = _embed_tokens(cfg, params, {"tokens": batch["token"]})
    pos = batch["pos"]

    def body(carry, inp):
        y = carry
        lp, lc = inp
        new_cache = {}
        h = lyr.rms_norm(y, lp["ln1"], cfg.norm_eps)
        if cfg.arch_type == "ssm":
            out, conv, st = ssm_lib.ssm_decode(lp["ssm"], h, cfg, lc["conv"], lc["state"])
            y = y + out
            new_cache["conv"], new_cache["state"] = conv, st
        else:
            attn_out, ck, cv = lyr.attn_decode(lp["attn"], h, cfg, lc["k"], lc["v"], pos)
            new_cache["k"], new_cache["v"] = ck, cv
            if cfg.hybrid:
                s_out, conv, st = ssm_lib.ssm_decode(
                    lp["ssm"], h, cfg, lc["conv"], lc["state"]
                )
                attn_out = 0.5 * (attn_out + s_out)
                new_cache["conv"], new_cache["state"] = conv, st
            y = y + attn_out
            h2 = lyr.rms_norm(y, lp["ln2"], cfg.norm_eps)
            if cfg.arch_type == "moe":
                moe_fn = (
                    moe_block_shard_map if cfg.moe_impl == "shard_map" else lyr.moe_block
                )
                m, _ = moe_fn(lp["moe"], h2, cfg)
                if cfg.dense_residual:
                    m = m + lyr.swiglu(lp["mlp"], h2)
                y = y + m
            else:
                y = y + lyr.swiglu(lp["mlp"], h2)
        return y, new_cache

    un = cfg.scan_unroll
    layer_cache = {k: v for k, v in cache.items() if k not in ("img_k", "img_v")}
    if not cfg.cross_attn_every:
        x, new_layer_cache = jax.lax.scan(
            body, x, (params["layers"], layer_cache),
            unroll=cfg.n_layers if un else 1,
        )
    else:
        cae = cfg.cross_attn_every
        n_cross = cfg.n_layers // cae
        grouped = jax.tree.map(
            lambda t: t.reshape((n_cross, cae - 1) + t.shape[1:]), params["layers"]
        )
        gcache = jax.tree.map(
            lambda t: t.reshape((n_cross, cae - 1) + t.shape[1:]), layer_cache
        )

        def group_body(carry, inp):
            gp, cp, gc, gk, gv = inp
            y, new_gc = jax.lax.scan(body, carry, (gp, gc), unroll=(cae - 1) if un else 1)
            y = lyr.cross_attn_block(cp, y, cfg, gk, gv)
            return y, new_gc

        x, new_layer_cache = jax.lax.scan(
            group_body,
            x,
            (grouped, params["cross"], gcache, cache["img_k"], cache["img_v"]),
            unroll=n_cross if un else 1,
        )
        new_layer_cache = jax.tree.map(
            lambda t: t.reshape((-1,) + t.shape[2:]), new_layer_cache
        )
    new_cache = dict(new_layer_cache)
    if cfg.cross_attn_every:
        new_cache["img_k"], new_cache["img_v"] = cache["img_k"], cache["img_v"]
    return _logits(cfg, params, x), new_cache
