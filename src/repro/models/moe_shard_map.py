"""Expert-parallel MoE via shard_map (the §Perf optimized dispatch).

The baseline GSPMD scatter dispatch (layers.moe_block) builds a GLOBAL
[E*C, D] buffer; on the production mesh XLA cannot prove the scatter local
and replicates both the buffer and most of the expert compute across the
"model" axis (measured: arctic-480b train flops/device ~19x the 6*N_active*D
floor). This implementation pins the data flow explicitly:

  * tokens are sharded over ("pod","data") and REPLICATED over "model"
    (standard TP layout of the residual stream);
  * each "model" shard owns E/m experts and scatters only the assignments
    routed to its slice into a LOCAL [E/m, C, D] buffer (no collective);
  * expert FFN runs on the local slice; the combine is a single
    psum over "model" — the same all-reduce the dense TP layer already pays.

Per-device expert FLOPs drop from ~E-replicated to T_local*k/m*3*2*d*ff —
the 6*N_active*D floor.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common import meshctx
from repro.models.config import ModelConfig

__all__ = ["moe_block_shard_map"]


def _batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def moe_block_shard_map(
    p: dict, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for layers.moe_block under an active mesh
    (discovered portably via `repro.common.meshctx.current_mesh`)."""
    mesh = meshctx.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        from repro.models.layers import moe_block  # no TP axis: GSPMD path

        return moe_block(p, x, cfg)

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    sizes = meshctx.axis_sizes_dict(mesh)
    m = sizes["model"]
    assert e % m == 0, f"experts {e} must divide model axis {m}"
    e_local = e // m
    baxes = _batch_axes(mesh)
    dp = int(np.prod([sizes[a] for a in baxes])) or 1
    t_local = (b // dp) * s
    cap = max(int(np.ceil(t_local * k / e * cfg.capacity_factor)), 1)

    # aux losses from a (cheap) global router pass — keeps shard_map output
    # replicated-scalar free (see module docstring)
    xt = x.reshape(b * s, d)
    logits_g = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs_g = jax.nn.softmax(logits_g, axis=-1)
    top1 = jnp.argmax(probs_g, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs_g, axis=0)
    lb = e * jnp.sum(frac_tokens * frac_probs) * cfg.load_balance_weight
    z = jnp.mean(jax.nn.logsumexp(logits_g, axis=-1) ** 2) * cfg.router_z_weight
    aux = lb + z

    def local(x_l, router, wg, wu, wd):
        # x_l: [B_l, S, D] (replicated over "model"); wg/wu/wd: [E/m, ...]
        bl = x_l.shape[0]
        tl = bl * s
        xt_l = x_l.reshape(tl, d)
        logits = jnp.einsum("td,de->te", xt_l, router).astype(jnp.float32)
        top_w, top_e = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        shard = jax.lax.axis_index("model")
        lo = shard * e_local
        flat_e = top_e.reshape(-1)  # [T_l*k] global expert ids
        mine = (flat_e >= lo) & (flat_e < lo + e_local)
        local_e = jnp.where(mine, flat_e - lo, 0)
        # position within the expert's buffer (count only my assignments)
        onehot = jax.nn.one_hot(local_e, e_local, dtype=jnp.int32) * mine[:, None]
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        slot = jnp.take_along_axis(pos, local_e[:, None], axis=1)[:, 0]
        keep = mine & (slot < cap)
        target = jnp.where(keep, local_e * cap + slot, e_local * cap)

        data = jnp.repeat(xt_l, k, axis=0) * keep[:, None].astype(x_l.dtype)
        buf = jnp.zeros((e_local * cap + 1, d), x_l.dtype).at[target].add(data)
        buf = buf[: e_local * cap].reshape(e_local, cap, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
            "ecd,edf->ecf", buf, wu
        )
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_local * cap, d)
        out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), x_l.dtype)], axis=0)

        gathered = out_buf[target]
        w = (top_w.reshape(-1) * keep).astype(x_l.dtype)
        y = (gathered * w[:, None]).reshape(tl, k, d).sum(axis=1)
        # combine partial contributions from every expert shard
        y = jax.lax.psum(y, "model")
        return y.reshape(bl, s, d)

    y = meshctx.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(baxes or None, None, None),  # x: batch-sharded, model-replicated
            P(None, None),  # router replicated
            P("model", None, None),  # experts sharded
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=P(baxes or None, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
