"""Spec-driven parameter construction.

Every model declares its parameters as a nested dict of `ParamSpec`s
(shape + logical axes + init kind). From one spec tree we derive:
  * initialized parameters (`init_params`),
  * NamedShardings for pjit in_shardings (`param_shardings`),
  * ShapeDtypeStructs for AOT lowering without allocation (`param_structs`).

This keeps init, sharding, and dry-run shapes provably consistent — the
divergence bugs a hand-maintained trio invites are structurally impossible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import named_sharding, spec_for

__all__ = [
    "ParamSpec",
    "init_params",
    "param_shardings",
    "param_structs",
    "param_count",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (see common.sharding)
    init: str = "normal"  # normal | zeros | ones | embed | small
    fan_in_dims: Tuple[int, ...] = (-2,)  # dims whose product scales init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = Dict[str, Any]  # nested dicts of ParamSpec


def _init_leaf(key: jax.Array, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02).astype(dtype)
    if spec.init == "small":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 1e-4).astype(dtype)
    fan_in = float(np.prod([spec.shape[d] for d in spec.fan_in_dims])) or 1.0
    scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_params(key: jax.Array, specs: SpecTree, dtype=jnp.float32) -> Dict[str, Any]:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten(
        [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    )


def param_shardings(mesh, specs: SpecTree):
    return jax.tree.map(
        lambda s: named_sharding(mesh, s.axes, s.shape),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_structs(specs: SpecTree, dtype=jnp.float32, mesh=None):
    def leaf(s: ParamSpec):
        sharding = named_sharding(mesh, s.axes, s.shape) if mesh is not None else None
        return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sharding)

    return jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(specs: SpecTree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(s.shape) for s in leaves))
