"""Int8 weight quantization for serving pools (beyond-paper §Perf).

Measured problem: llama-3.2-vision-90b decode_32k needs 19.9 GB/device even
after the §Perf cache fix (13 GB of bf16 weights at 16-way TP + ~5 GB cache)
— over the v5e's 16 GB HBM. Per-channel symmetric int8 halves the resident
weight bytes AND the per-token weight-read traffic (decode's memory floor).

Boundary design: quantization wraps the *program*, not the layers — the
dry-run lowers `decode_step(cfg, dequant(qparams), cache, batch)` and XLA
fuses the dequant (convert+scale) into each consumer matmul, so HBM reads
stay int8 while the model code is untouched. Matrix weights (ndim >= 2,
both trailing dims >= 64) quantize per-output-channel; norms/biases/small
tensors stay bf16.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamSpec

__all__ = [
    "should_quantize",
    "quantize_tree",
    "dequantize_tree",
    "quantized_structs",
    "quantized_bytes",
]


def should_quantize(shape: Tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] >= 64 and shape[-2] >= 64


def _quant_leaf(w: jnp.ndarray):
    if not should_quantize(w.shape):
        return w
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.bfloat16)}


def _dequant_leaf(leaf, dtype):
    if isinstance(leaf, dict) and "q" in leaf:
        return (leaf["q"].astype(jnp.float32) * leaf["scale"].astype(jnp.float32)).astype(dtype)
    return leaf


def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def quantize_tree(params: Dict[str, Any]) -> Dict[str, Any]:
    return jax.tree.map(_quant_leaf, params)


def dequantize_tree(qparams: Dict[str, Any], dtype=jnp.bfloat16) -> Dict[str, Any]:
    return jax.tree.map(
        lambda l: _dequant_leaf(l, dtype), qparams, is_leaf=_is_qleaf
    )


def quantized_structs(specs, mesh=None, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the quantized param tree (dry-run input)."""
    from repro.common.sharding import named_sharding

    def leaf(s: ParamSpec):
        def struct(shape, axes, dt):
            sh = named_sharding(mesh, axes, shape) if mesh is not None else None
            return jax.ShapeDtypeStruct(shape, dt, sharding=sh)

        if should_quantize(s.shape):
            scale_shape = s.shape[:-2] + (1,) + s.shape[-1:]
            return {
                "q": struct(s.shape, s.axes, jnp.int8),
                "scale": struct(scale_shape, s.axes, jnp.bfloat16),
            }
        return struct(s.shape, s.axes, dtype)

    return jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def quantized_bytes(specs) -> int:
    """Analytic resident weight bytes after int8 quantization."""
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = int(np.prod(s.shape))
        if should_quantize(s.shape):
            total += n + 2 * n // s.shape[-2]  # int8 + bf16 scales
        else:
            total += 2 * n
    return total
