"""Mamba-2 SSD (state-space duality) blocks — arXiv:2405.21060.

Chunked SSD for train/prefill (intra-chunk quadratic on the MXU + inter-chunk
recurrence over nc = S/chunk steps), exact O(1)-state recurrent decode. This
is the TPU-native adaptation (DESIGN.md §4): the chunk size is the MXU tile
knob, the inter-chunk scan is `lax.scan` over stacked chunk states, and heads
shard over the "model" mesh axis.

Shapes follow the paper: x [B,S,H,P], dt [B,S,H], A [H] (log-parametrized),
B/C [B,S,G,N] with G groups broadcast over heads.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import logical_constraint as shard
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm

__all__ = ["ssd_chunked", "ssm_block", "ssm_decode", "ssm_conv_decode"]


def _repeat_groups(t: jnp.ndarray, h: int) -> jnp.ndarray:
    """[B,S,G,N] -> [B,S,H,N] broadcasting groups over heads."""
    g = t.shape[2]
    if g == h:
        return t
    return jnp.repeat(t, h // g, axis=2)


def ssd_chunked(
    x: jnp.ndarray,  # [B, S, H, P] (pre-discretization input)
    dt: jnp.ndarray,  # [B, S, H] softplus'd step sizes
    a_log: jnp.ndarray,  # [H]
    b_mat: jnp.ndarray,  # [B, S, G, N]
    c_mat: jnp.ndarray,  # [B, S, G, N]
    chunk: int,
    init_state: jnp.ndarray | None = None,  # [B, H, P, N]
    unroll: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))  # [H], negative

    xd = (x * dt[..., None]).astype(jnp.float32)  # discretized input
    adt = (a * dt.astype(jnp.float32)).reshape(bsz, nc, chunk, h)  # log decays
    xd = xd.reshape(bsz, nc, chunk, h, p)
    bh = _repeat_groups(b_mat, h).reshape(bsz, nc, chunk, h, n).astype(jnp.float32)
    ch = _repeat_groups(c_mat, h).reshape(bsz, nc, chunk, h, n).astype(jnp.float32)

    a_cum = jnp.cumsum(adt, axis=2)  # [B,nc,l,H] within-chunk cumulative decay

    # ---- intra-chunk (diagonal blocks): quadratic attention-like form
    li = a_cum[:, :, :, None, :]  # query position l
    lj = a_cum[:, :, None, :, :]  # key position s
    causal = (
        jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
    )[None, None, :, :, None]
    # exponent is <=0 in the causal region; clamp to avoid inf in masked slots
    l_mat = jnp.where(causal, jnp.exp(jnp.minimum(li - lj, 0.0)), 0.0)  # [B,nc,l,s,H]
    scores = jnp.einsum("bclhn,bcshn->bclsh", ch, bh)
    y_diag = jnp.einsum("bclsh,bcshp->bclhp", scores * l_mat, xd)

    # ---- chunk summary states: contribution of each chunk to the carried state
    seg_decay = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # [B,nc,l,H]
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, seg_decay, xd)

    # ---- inter-chunk recurrence (lax.scan over nc chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # [B,nc,H]
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)

    def step(carry, inp):
        st, dec = inp  # [B,H,P,N], [B,H]
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* this chunk

    final, prev = jax.lax.scan(
        step,
        init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=nc if unroll else 1,
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # ---- off-diagonal: carried state read out at each position
    state_decay = jnp.exp(a_cum)  # [B,nc,l,H]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", ch, prev, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc: [B,S,C]; w: [K,C]; b: [C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _split_zxbcdt(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    di = cfg.d_inner
    gn = cfg.ssm_n_groups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * gn]
    dt = zxbcdt[..., 2 * di + 2 * gn :]
    return z, xbc, dt


def ssm_block(
    p: dict,
    x: jnp.ndarray,  # [B, S, D] (already normed)
    cfg: ModelConfig,
    return_cache: bool = False,
):
    """Full-sequence Mamba-2 block (train / prefill)."""
    bsz, s, d = x.shape
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_groups
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_zxbcdt(zxbcdt, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(bsz, s, h, pdim)
    xs = shard(xs, "batch", None, "ssm_heads", None)
    b_mat = xbc[..., di : di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n :].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]

    # pad to a chunk multiple with dt=0 positions: exp(0)=1 decay and zero
    # input make padding an exact identity on the carried state
    pad = (-s) % cfg.ssm_chunk
    xs_p, b_p, c_p, dt_p = xs, b_mat, c_mat, dt
    if pad:
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_p = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_p = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    y, final_state = ssd_chunked(
        xs_p, dt_p, p["a_log"], b_p, c_p, cfg.ssm_chunk, unroll=cfg.scan_unroll
    )
    if pad:
        y = y[:, :s]
    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    out = shard(out, "batch", "act_seq", None)
    if not return_cache:
        return out
    conv_state = xbc_raw_tail(zxbcdt, cfg, s)
    return out, (conv_state, final_state.astype(x.dtype))


def xbc_raw_tail(zxbcdt: jnp.ndarray, cfg: ModelConfig, s: int) -> jnp.ndarray:
    """Last (conv_width-1) pre-conv xBC rows — the decode conv cache."""
    _, xbc, _ = _split_zxbcdt(zxbcdt, cfg)
    k = cfg.ssm_conv_width
    return xbc[:, s - (k - 1) :, :]


def ssm_decode(
    p: dict,
    x: jnp.ndarray,  # [B, 1, D] (already normed)
    cfg: ModelConfig,
    conv_state: jnp.ndarray,  # [B, K-1, C]
    ssd_state: jnp.ndarray,  # [B, H, P, N]
):
    """One-token recurrent decode: O(1) in sequence length."""
    bsz = x.shape[0]
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_groups
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_new, dt = _split_zxbcdt(zxbcdt, cfg)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)  # [B, K, C]
    new_conv_state = window[:, 1:]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    xs = conv_out[..., :di].reshape(bsz, h, pdim)
    b_mat = conv_out[..., di : di + g * n].reshape(bsz, g, n)
    c_mat = conv_out[..., di + g * n :].reshape(bsz, g, n)
    rep = h // g
    b_h = jnp.repeat(b_mat, rep, axis=1)  # [B,H,N]
    c_h = jnp.repeat(c_mat, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).reshape(bsz, h)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # [B,H]

    st = ssd_state.astype(jnp.float32)
    st = st * da[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, b_h.astype(jnp.float32), xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", c_h.astype(jnp.float32), st)
    y = y.astype(x.dtype) + xs * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_conv_state, st.astype(ssd_state.dtype)
