"""Telemetry plane: low-overhead metrics, route tracing, events, health.

The paper's pitch is a latency budget ("all mechanisms run within
single-digit millisecond CPU budgets", §5.5); this package makes that
budget *observable at serve time* instead of only in offline benches, at a
cost `benchmarks/obs_bench.py` bounds in CI (<5 % of bare `route_batch`
qps). Four surfaces:

* `repro.obs.metrics` — process-wide `MetricsRegistry` of counters, gauges,
  and preallocated log-spaced-bucket histograms (O(1) record, bounded
  memory); Prometheus text exposition + JSON snapshot.
* `repro.obs.trace` — seeded ~1-in-N sampled `RouteTracer`: per-batch phase
  spans stamped with versions, JSONL export, rendered by ``repro-obs``
  (`repro.obs.report`).
* `repro.obs.events` — bounded `EventBus` the control/learn/index planes
  publish lifecycle transitions into (replacing scattered prints and
  write-only attributes).
* `repro.obs.health` — `HealthMonitor` JSON snapshot (ok/degraded/error)
  + `ObsServer` HTTP exposition (``/metrics``, ``/health``, ``/events``),
  wired into `launch/serve.py` behind ``--metrics-port``.

`repro.obs.clock` is the canonical timing module for `router/` and
`index/` (the `obs-discipline` lint rule enforces it), and
`repro.obs.summary` is the one percentile implementation
(`percentile_stats` re-exported from `repro.router.latency` for compat).

Metric catalog (gateway + index layer)
======================================

route_requests_total (counter)
    Queries routed, summed over batches.
route_batches_total (counter)
    `route_batch` calls served.
route_phase_ms{phase=embed|adapter|score|rerank|assemble} (histogram)
    Per-batch wall duration of each serving phase, monotonic clock.
route_batch_ms (histogram)
    End-to-end per-batch duration (sum of phases + overhead).
route_batch_size (histogram)
    Raw batch sizes (pre pow2 padding).
route_table_version / route_stage_version (gauge)
    Versions stamped on the most recent batch.
route_outcomes_dropped_total (counter)
    Outcome-ring overwrites in `record_outcome` (undrained router).
index_served_total{path=index|exact} (counter)
    Batches served by the built backend vs the exact dense fallback
    (fallback-serving windows during rebuilds).
index_rebuilds_total / index_build_failures_total (counter)
    Index lifecycle outcomes, mirroring `ToolIndexManager.stats`.
index_build_ms (histogram)
    Build durations (k-means rebuilds dominate).

Event catalog (kind / plane / required detail stamps)
=====================================================

swap / control — version
    Any `ToolsDatabase` version change (via `EventBus.watch_db`): gated
    controller swaps, guard rollbacks, out-of-band deploys.
stage_swap / learn — version
    Any router StageSet change (promotion, demotion, out-of-band).
rollback / control — condemned_version, restored_version, ndcg, baseline
    `TableGuard` condemned the live table and restored a retained one.
demotion / learn — condemned_version, restored_version, ndcg, baseline
    `StageGuard` condemned the live StageSet.
promotion / learn — stage, from_version, to_version, artifact_version
    `LearningController` activated a gated artifact.
gate_reject / control|learn — stage (learn), reason
    A trained candidate failed its held-out gate.
cooldown / control|learn — purged
    Post-rollback/demotion window purge + trigger reset.
rebuild_start, rebuild_finish / index — version, backend (+build_ms)
    Index rebuild lifecycle for one table version.
rebuild_failure / index — version, backend, error
    Build raised; the exact fallback keeps serving.
loop_error / control|learn — controller, error
    A daemon `step()` raised (`last_loop_error` set).
loop_recovered / control|learn — controller
    The next step succeeded (`last_loop_error` cleared).
outcomes_dropping / serve — dropped
    A router's outcome ring overflowed for the first time.
"""
from repro.obs import clock
from repro.obs.events import Event, EventBus
from repro.obs.health import HealthMonitor, ObsServer
from repro.obs.metrics import (
    Counter,
    Gauge,
    LogHistogram,
    MetricsRegistry,
    default_edges,
    get_registry,
)
from repro.obs.summary import LatencyStats, percentile_stats, stats_from_histogram
from repro.obs.trace import RouteTrace, RouteTracer, TraceSampler

__all__ = [
    "clock",
    "Event",
    "EventBus",
    "HealthMonitor",
    "ObsServer",
    "Counter",
    "Gauge",
    "LogHistogram",
    "MetricsRegistry",
    "default_edges",
    "get_registry",
    "LatencyStats",
    "percentile_stats",
    "stats_from_histogram",
    "RouteTrace",
    "RouteTracer",
    "TraceSampler",
]
