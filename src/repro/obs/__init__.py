"""Telemetry plane: low-overhead metrics, route tracing, events, health.

The paper's pitch is a latency budget ("all mechanisms run within
single-digit millisecond CPU budgets", §5.5); this package makes that
budget *observable at serve time* instead of only in offline benches, at a
cost `benchmarks/obs_bench.py` bounds in CI (<5 % of bare `route_batch`
qps). Four surfaces:

* `repro.obs.metrics` — process-wide `MetricsRegistry` of counters, gauges,
  and preallocated log-spaced-bucket histograms (O(1) record, bounded
  memory); Prometheus text exposition + JSON snapshot.
* `repro.obs.trace` — seeded ~1-in-N sampled `RouteTracer`: per-batch phase
  spans stamped with versions, JSONL export, rendered by ``repro-obs``
  (`repro.obs.report`).
* `repro.obs.events` — bounded `EventBus` the control/learn/index planes
  publish lifecycle transitions into (replacing scattered prints and
  write-only attributes).
* `repro.obs.health` — `HealthMonitor` JSON snapshot (ok/degraded/error)
  + `ObsServer` HTTP exposition (``/metrics``, ``/health``, ``/events``,
  ``/slo``, ``/traces``), wired into `launch/serve.py` behind
  ``--metrics-port``.

On top of those recorders sits the judgement layer (PR 7):

* `repro.obs.timeseries` — `TimeSeriesRing`, a bounded in-process ring of
  periodic registry snapshots; windowed rates, deltas, and quantiles with
  no external Prometheus (`window_hist`, `rate`, `delta`).
* `repro.obs.slo` — declarative `SLO`s (`default_slos()`: route p99 vs the
  10 ms budget, exact-fallback ratio, guard-rollback rate, drop rate)
  evaluated by `SLOEngine` with multi-window burn rates; transitions
  publish ``slo_burn``/``slo_recovered``, `HealthMonitor` degrades while
  burning, `/slo` serves the snapshot.
* `repro.obs.quality` — `QualityMonitor`: rolling NDCG@5/Recall@5 on
  labelled traffic (via `RollingWindows`, the machinery the guards share),
  top-1/top-2 score-gap confidence, and a label-free query-embedding drift
  detector that publishes ``quality_drift`` *before* the guards have
  enough labels to act.
* exemplars — `LogHistogram.record(value, exemplar=trace_id)` tags the
  bucket with the most recent sampled trace; `percentile_exemplar(99)`
  links a p99 reading to a concrete `RouteTrace` (rendered by
  ``repro-obs watch`` and the `/slo` snapshot).

And on top of the judges sits the memory layer (PR 9) — record → judge →
**remember**:

* `repro.obs.flightrec` — `FlightRecorder`: on a trigger event
  (``slo_burn``, ``quality_drift``, ``loop_error``, ``rollback``,
  ``demotion``) or a fatal crash (`record_crash`, hooked into
  `launch/serve.py` and both controller daemon loops) it freezes the whole
  telemetry state — event ring, sampled traces, metrics snapshot,
  `TimeSeriesRing` window, health/SLO state, version stamps — into one
  atomic, debounced, retention-capped dump directory. ``/dumps`` lists
  them live; ``repro-obs replay <dump-dir>`` renders the postmortem
  timeline offline.
* `repro.obs.profile` — `JitProfiler`: the live twin of PR 5's retrace CI
  leg. Polls the hot-path jits' compile caches
  (`repro.router.gateway.hot_path_jits`) on the ring cadence —
  first collect baselines warmup, after that every cache growth counts as
  ``jit_compiles_total{fn=}`` (feeding `default_slos()`'s
  ``jit_retrace_rate``) — and stamps per-program FLOPs / bytes-accessed
  via XLA ``cost_analysis`` (`stamp_router_costs`), all exported at
  ``/profile``. `SamplingProfiler` adds an opt-in wall-clock sampler over
  the cadence daemons (``--profile-daemons``).

`repro.obs.clock` is the canonical timing module for `router/`, `index/`,
`control/`, and `learn/` (the `obs-discipline` lint rule enforces it), and
`repro.obs.summary` is the one percentile implementation
(`percentile_stats` re-exported from `repro.router.latency` for compat).

Metric catalog (gateway + index layer)
======================================

route_requests_total (counter)
    Queries routed, summed over batches.
route_batches_total (counter)
    `route_batch` calls served.
route_phase_ms{phase=embed|cache|adapter|score|rerank|assemble} (histogram)
    Per-batch wall duration of each serving phase, monotonic clock.
route_batch_ms (histogram)
    End-to-end per-batch duration (sum of phases + overhead).
route_batch_size (histogram)
    Raw batch sizes (pre pow2 padding).
route_table_version / route_stage_version (gauge)
    Versions stamped on the most recent batch.
route_outcomes_dropped_total (counter)
    Outcome-ring overwrites in `record_outcome` (undrained router).
route_cache_hits_total / route_cache_misses_total (counter)
    `SemanticRouteCache` lookup outcomes (a hit = cosine >= threshold on
    a live-stamped entry); hit ratio also exported directly.
route_cache_hit_ratio (gauge)
    Lifetime hits / (hits + misses) — the runbook's headline cache dial.
route_cache_size (gauge)
    Retained key slots (one decision occupies `n_tables` slots).
route_cache_evictions_total (counter)
    LRU slots dropped past `capacity`.
route_cache_invalidated_total (counter)
    Entries purged on version-stamp mismatch (swap/rollback/stage churn).
route_cache_stale_served_total (counter)
    Gateway-tripwire demotions: a cache hit whose stamps no longer match
    the live `(table_version, stage_version)` at serve time. MUST stay 0
    (the ``cache_staleness`` SLO and cache_bench's churn gate enforce it).
index_served_total{path=index|exact} (counter)
    Batches served by the built backend vs the exact dense fallback
    (fallback-serving windows during rebuilds).
index_rebuilds_total / index_build_failures_total (counter)
    Index lifecycle outcomes, mirroring `ToolIndexManager.stats`.
index_build_ms (histogram)
    Build durations (k-means rebuilds dominate).
route_score_gap (histogram)
    Per-query top-1 minus top-2 score (routing confidence; one vectorized
    `record_many` pass, sampled 1-in-4 batches).
quality_ndcg{k=} / quality_recall{k=} (gauge)
    `QualityMonitor`'s rolling labelled-traffic means.
quality_drift_score (gauge)
    RMS z-score of the query-mean EWMA vs the live table's population
    stats (the label-free drift signal).
slo_burning{slo=} / slo_burn_rate{slo=} (gauge)
    Per-SLO breach state (0/1) and worst long-window burn rate, updated
    on every `SLOEngine.evaluate`.
jit_compiles_total{fn=} (counter)
    Post-warmup XLA compiles per hot-path jit (`JitProfiler.collect`
    cache-growth deltas; fn names from `hot_path_jits()`) — the live
    retrace signal behind the ``jit_retrace_rate`` SLO.
jit_cache_size{fn=} (gauge)
    Absolute compile-cache size per hot-path jit (warmup included).
flightrec_dumps_total / flightrec_suppressed_total (counter)
    Black-box dumps written vs suppressed by the debounce window.

Event catalog (kind / plane / required detail stamps)
=====================================================

swap / control — version
    Any `ToolsDatabase` version change (via `EventBus.watch_db`): gated
    controller swaps, guard rollbacks, out-of-band deploys.
stage_swap / learn — version
    Any router StageSet change (promotion, demotion, out-of-band).
rollback / control — condemned_version, restored_version, ndcg, baseline
    `TableGuard` condemned the live table and restored a retained one.
demotion / learn — condemned_version, restored_version, ndcg, baseline
    `StageGuard` condemned the live StageSet.
promotion / learn — stage, from_version, to_version, artifact_version
    `LearningController` activated a gated artifact.
gate_reject / control|learn — stage (learn), reason
    A trained candidate failed its held-out gate.
cooldown / control|learn — purged
    Post-rollback/demotion window purge + trigger reset.
rebuild_start, rebuild_finish / index — version, backend (+build_ms)
    Index rebuild lifecycle for one table version.
rebuild_failure / index — version, backend, error
    Build raised; the exact fallback keeps serving.
loop_error / control|learn — controller, error
    A daemon `step()` raised (`last_loop_error` set).
loop_recovered / control|learn — controller
    The next step succeeded (`last_loop_error` cleared).
outcomes_dropping / serve — dropped
    A router's outcome ring overflowed for the first time.
slo_burn / serve — slo, sli, burn (+threshold_ms, p99_ms, p99_exemplar)
    An SLO entered breach: burn > factor over both windows of some pair
    (``sli`` is the SLI kind — latency|ratio|rate).
slo_recovered / serve — slo, sli
    The SLO's next evaluation saw the breach gone.
cache_invalidated / serve — table_version, stage_version, purged, reason
    `SemanticRouteCache` purged >=1 version-stamp-mismatched entries
    (eager path via `cache.watch(bus)`; lazy lookup purges count in
    ``route_cache_invalidated_total`` without an event).
quality_drift / serve — score, threshold, table_version
    The query-population EWMA left the live table's population stats
    (rising edge only; re-arms when the score falls back under).

The flight recorder consumes (never publishes) bus events: its trigger
set is exactly {slo_burn, quality_drift, loop_error, rollback, demotion}
plus out-of-band crashes, and a dump only reads latched judgement state
(`SLOEngine.burning`), so recording can never cause the transitions it
records.
"""
from repro.obs import clock
from repro.obs.events import Event, EventBus
from repro.obs.flightrec import (
    FlightRecorder,
    list_dumps,
    load_dump,
    render_replay,
)
from repro.obs.health import HealthMonitor, ObsServer
from repro.obs.metrics import (
    Counter,
    Gauge,
    LogHistogram,
    MetricsRegistry,
    default_edges,
    get_registry,
)
from repro.obs.profile import JitProfiler, SamplingProfiler, stamp_router_costs
from repro.obs.quality import QualityConfig, QualityMonitor, RollingWindows
from repro.obs.slo import SLO, BurnWindow, SLOEngine, default_slos
from repro.obs.summary import LatencyStats, percentile_stats, stats_from_histogram
from repro.obs.timeseries import HistWindow, TimeSeriesRing
from repro.obs.trace import RouteTrace, RouteTracer, TraceSampler

__all__ = [
    "clock",
    "Event",
    "EventBus",
    "HealthMonitor",
    "ObsServer",
    "Counter",
    "Gauge",
    "LogHistogram",
    "MetricsRegistry",
    "default_edges",
    "get_registry",
    "LatencyStats",
    "percentile_stats",
    "stats_from_histogram",
    "RouteTrace",
    "RouteTracer",
    "TraceSampler",
    "HistWindow",
    "TimeSeriesRing",
    "SLO",
    "BurnWindow",
    "SLOEngine",
    "default_slos",
    "QualityConfig",
    "QualityMonitor",
    "RollingWindows",
    "FlightRecorder",
    "list_dumps",
    "load_dump",
    "render_replay",
    "JitProfiler",
    "SamplingProfiler",
    "stamp_router_costs",
]
