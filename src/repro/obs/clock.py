"""Canonical clocks for the serving/index hot paths.

The `obs-discipline` lint rule forbids `time.time()` / `time.perf_counter()`
/ `time.monotonic()` (and `print()`) inside `router/` and `index/`: phase
timing and deadlines must flow through this module so (a) every recorded
duration uses the same monotonic source — wall-clock steps from NTP slew
would otherwise corrupt latency histograms — and (b) tests and the overhead
benchmark can reason about every timing call site from one file.

Three clocks, three jobs:

* ``perf()`` — high-resolution monotonic, for phase durations
  (``duration_ms`` pairs a start with it);
* ``monotonic()`` — monotonic deadline clock, for timeouts/poll loops;
* ``wall()`` — wall-clock epoch seconds, ONLY for event timestamps that
  leave the process (outcome events, bus events, trace records).
"""
from __future__ import annotations

import time

__all__ = ["perf", "monotonic", "wall", "duration_ms"]

perf = time.perf_counter
monotonic = time.monotonic
wall = time.time


def duration_ms(t0: float, t1: float | None = None) -> float:
    """Milliseconds elapsed from ``t0`` (a ``perf()`` stamp) to ``t1``/now."""
    return ((perf() if t1 is None else t1) - t0) * 1e3
