"""EventBus: the planes' lifecycle events as one bounded, queryable stream.

Before this module, lifecycle transitions were scattered prints and
write-only attributes: a swap was visible only in a `ControllerReport`
someone kept a reference to, a guard rollback only in `guard.rollbacks`, an
index rebuild not at all. The bus gives every plane one `publish()` call
and every consumer (health endpoint, examples, the lifecycle smoke in
`benchmarks/obs_bench.py`) one ordered stream with version stamps.

Design constraints, in the same spirit as `OutcomeStore`:

* **bounded** — events live in a ring of `capacity`; when full the oldest
  event is overwritten and `dropped` counts it (a stalled consumer can
  never OOM the serving process);
* **cheap** — `publish` is a dataclass construction + deque append under a
  lock; no formatting, no I/O;
* **monotone** — every event carries a process-unique `seq`, so a poller
  asks for `events(since_seq=...)` and never re-reads or misses inside the
  retained window;
* **subscribable** — `subscribe(fn)` callbacks run synchronously *after*
  the ring append and outside the bus lock (a subscriber may publish or
  read without deadlock; a slow subscriber slows its publisher, which is
  the honest contract for in-process hooks).

Event kinds are an open vocabulary; the catalog the repo's planes publish
is documented in `repro.obs.__init__`. `watch_db(db)` wires a
`ToolsDatabase` so *every* table version change (controller swap, guard
rollback, out-of-band deploy) lands on the bus even when the mover did not
carry a bus reference.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from repro.obs import clock

__all__ = ["Event", "EventBus"]


@dataclasses.dataclass(frozen=True)
class Event:
    seq: int  # process-unique, monotone publication order
    ts: float  # wall-clock epoch seconds (exported records)
    kind: str  # e.g. "swap", "rollback", "rebuild_start" (see obs catalog)
    plane: str  # "serve" | "control" | "learn" | "index"
    details: Dict[str, object] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "seq": self.seq,
            "ts": self.ts,
            "kind": self.kind,
            "plane": self.plane,
            **self.details,
        }


class EventBus:
    def __init__(self, capacity: int = 4096):
        assert capacity >= 1
        self.capacity = int(capacity)
        self._ring: Deque[Event] = deque()
        self._seq = 0
        self.dropped = 0  # ring overwrites (oldest evicted first)
        self._counts: Dict[str, int] = {}  # per-kind lifetime counts
        self._lock = threading.Lock()
        self._subscribers: List[Callable[[Event], None]] = []

    # ------------------------------------------------------------ publishing
    def publish(self, kind: str, plane: str = "serve", **details) -> Event:
        with self._lock:
            event = Event(self._seq, clock.wall(), kind, plane, details)
            self._seq += 1
            if len(self._ring) >= self.capacity:
                self._ring.popleft()
                self.dropped += 1
            self._ring.append(event)
            self._counts[kind] = self._counts.get(kind, 0) + 1
            subscribers = list(self._subscribers)
        for fn in subscribers:  # outside the lock: subscribers may publish
            fn(event)
        return event

    def subscribe(self, fn: Callable[[Event], None]) -> None:
        with self._lock:
            self._subscribers.append(fn)

    def unsubscribe(self, fn: Callable[[Event], None]) -> None:
        """Detach a subscriber; a no-op if it was never (or already no
        longer) attached, so teardown paths can call it unconditionally."""
        with self._lock:
            try:
                self._subscribers.remove(fn)
            except ValueError:
                pass

    def watch_db(self, db) -> Callable[[], None]:
        """Publish a "swap" event for every table version change on `db`.

        Registered as a `ToolsDatabase` swap listener, so controller swaps,
        guard rollbacks, and out-of-band deploys all surface — the listener
        fires after the database lock is released, like index rebuilds.

        Returns a zero-arg detach handle that unregisters the listener, so
        long-lived tests and `launch/serve.py` shutdown don't leak
        listeners across database instances. Idempotent.
        """
        listener = lambda version: self.publish("swap", plane="control",
                                                version=version)
        db.add_swap_listener(listener)
        return lambda: db.remove_swap_listener(listener)

    # --------------------------------------------------------------- reading
    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def events(
        self, since_seq: int = -1, kind: Optional[str] = None
    ) -> List[Event]:
        """Retained events with seq > since_seq (optionally one kind)."""
        with self._lock:
            evs = [e for e in self._ring if e.seq > since_seq]
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        return evs

    def last(self, kind: str) -> Optional[Event]:
        with self._lock:
            for e in reversed(self._ring):
                if e.kind == kind:
                    return e
        return None

    def counts(self) -> Dict[str, int]:
        """Lifetime per-kind publication counts (evictions don't decrement)."""
        with self._lock:
            return dict(self._counts)
