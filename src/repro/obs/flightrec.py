"""FlightRecorder: postmortem black-box dumps for the serving process.

The telemetry plane's recorders (PR 6) and judges (PR 7) are all *bounded
in-process buffers* — the trace ring, the event ring, the `TimeSeriesRing`
— which is exactly right for a healthy process and exactly wrong for a
3 a.m. incident: the moment an alert fires is also the moment the evidence
starts being overwritten. The flight recorder closes that gap the way an
aircraft black box does: when something goes wrong, freeze everything the
process knows into a durable artifact and keep serving.

One `FlightRecorder` subscribes to the `EventBus` and, on a trigger event
(``slo_burn``, ``quality_drift``, ``loop_error``, guard ``rollback`` /
``demotion`` by default) or an explicit crash report
(`record_crash(exc)` — wired into `launch/serve.py`'s fatal path and both
controller daemon loops), writes one **dump directory** containing:

* ``manifest.json`` — trigger, wall/monotonic stamps, per-router
  (table_version, stage_version) version stamps, dump format version,
  and the artifact inventory;
* ``events.jsonl`` — the full event ring at dump time;
* ``traces.jsonl`` — the last N sampled `RouteTrace`s;
* ``metrics.json`` — the registry snapshot (counters/gauges/histogram
  summaries);
* ``timeseries.json`` — the `TimeSeriesRing` window (per-point counters,
  gauges, and histogram count/sum — the burn-rate evidence);
* ``health.json`` / ``slo.json`` — the health snapshot and the SLO
  engine's last-evaluated state (``burning()`` — no re-judgement, so a
  dump can never publish fresh transitions into the bus it subscribes to);
* ``profile.json`` — the `JitProfiler` snapshot when one is attached
  (compile counters, cache sizes, per-program FLOPs/bytes).

Crash consistency: every dump is staged under ``.tmp-<name>`` and
published with one atomic ``os.rename`` — a reader (``repro-obs replay``,
``/dumps``) never observes a half-written dump, and a crash mid-dump
leaves only a ``.tmp-`` directory the next retention sweep removes.

Noise discipline: triggers are **debounced** (one dump per
``debounce_s``; an incident that fires slo_burn + quality_drift +
rollback in one window produces ONE dump whose manifest names the first
trigger) and **bounded** (``max_dumps`` retained, oldest deleted), so a
flapping alert can neither fill the disk nor turn the recorder into the
incident. `dumps_written` / `dumps_suppressed` count both sides, mirrored
as ``flightrec_dumps_total`` / ``flightrec_suppressed_total`` when a
registry is attached.

Offline, ``repro-obs replay <dump-dir>`` renders the postmortem timeline:
bus events interleaved with the sampled trace spans around the trigger,
plus the SLO/health state at dump time (`render_replay`).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import clock

__all__ = [
    "DEFAULT_TRIGGERS",
    "DUMP_FORMAT_VERSION",
    "FlightRecorder",
    "list_dumps",
    "load_dump",
    "render_replay",
]

DUMP_FORMAT_VERSION = 1

# the transitions that mean "evidence is about to evaporate": alerts from
# the judgement layer, enforcement actions from the guards, daemon failures
DEFAULT_TRIGGERS = (
    "slo_burn",
    "quality_drift",
    "loop_error",
    "rollback",
    "demotion",
)


def _json_default(o):
    """Best-effort JSON for numpy scalars/arrays and exceptions in details."""
    if hasattr(o, "item"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return repr(o)


def _write_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=_json_default)


@dataclasses.dataclass(frozen=True)
class DumpRecord:
    """One retained dump, as `list_dumps` reports it."""

    name: str
    path: str
    manifest: dict


class FlightRecorder:
    """Black-box dumper: bus-triggered, debounced, bounded, crash-consistent."""

    def __init__(
        self,
        out_dir: str,
        bus=None,  # repro.obs.events.EventBus
        registry=None,  # repro.obs.metrics.MetricsRegistry
        tracer=None,  # repro.obs.trace.RouteTracer
        ring=None,  # repro.obs.timeseries.TimeSeriesRing
        slo=None,  # repro.obs.slo.SLOEngine
        health=None,  # repro.obs.health.HealthMonitor
        profiler=None,  # repro.obs.profile.JitProfiler
        routers: Sequence = (),
        trigger_kinds: Sequence[str] = DEFAULT_TRIGGERS,
        debounce_s: float = 30.0,
        max_dumps: int = 16,
        max_traces: int = 256,
    ):
        self.out_dir = str(out_dir)
        self.bus = bus
        self.registry = registry
        self.tracer = tracer
        self.ring = ring
        self.slo = slo
        self.health = health
        self.profiler = profiler
        self.routers = list(routers)
        self.trigger_kinds = frozenset(trigger_kinds)
        self.debounce_s = float(debounce_s)
        self.max_dumps = int(max_dumps)
        self.max_traces = int(max_traces)
        assert self.max_dumps >= 1 and self.max_traces >= 1
        self.dumps_written = 0
        self.dumps_suppressed = 0
        self.last_dump_path: Optional[str] = None
        self._last_dump_mono: Optional[float] = None
        self._seq = 0  # per-process dump counter (unique names)
        self._lock = threading.Lock()
        self._c_dumps = self._c_suppressed = None
        if registry is not None:
            self._c_dumps = registry.counter("flightrec_dumps_total")
            self._c_suppressed = registry.counter("flightrec_suppressed_total")
        os.makedirs(self.out_dir, exist_ok=True)
        self._subscribed = False
        if bus is not None:
            bus.subscribe(self._on_event)
            self._subscribed = True

    def stop(self) -> None:
        """Detach from the bus (idempotent). The first step of an orderly
        shutdown: after this, draining daemons can publish freely without
        triggering dumps from a half-torn-down process."""
        if self._subscribed and self.bus is not None:
            self.bus.unsubscribe(self._on_event)
        self._subscribed = False

    # ------------------------------------------------------------- triggering
    def _on_event(self, event) -> None:
        """Bus subscriber: trigger events become dumps (debounced).

        Runs synchronously on the publisher's thread *after* the publisher
        released its own locks (the bus contract), so a dump here can read
        every surface without deadlock — but it must never publish back into
        the bus, which `dump()` guarantees by only reading latched state
        (`slo.burning()`, never `slo.evaluate()`).
        """
        if event.kind in self.trigger_kinds:
            self.dump(reason=event.kind, trigger=event.as_dict())

    def record_crash(self, exc: BaseException, source: str = "unknown") -> Optional[str]:
        """Dump on a fatal exception (the serve launcher / daemon-loop hook).

        Crash dumps share the trigger debounce: a daemon loop crashing on
        every iteration produces one dump per window, not one per step.
        """
        trigger = {
            "kind": "crash",
            "source": source,
            "error": repr(exc),
            "error_type": type(exc).__name__,
        }
        return self.dump(reason="crash", trigger=trigger)

    # ----------------------------------------------------------------- dumping
    def dump(self, reason: str, trigger: Optional[dict] = None) -> Optional[str]:
        """Write one black-box dump; returns its path (None if debounced).

        The debounce check, name allocation, and publish are serialized
        under the recorder lock; the artifact writes happen outside any
        other plane's lock (everything read here is a snapshot API).
        """
        now = clock.monotonic()
        with self._lock:
            if (
                self._last_dump_mono is not None
                and now - self._last_dump_mono < self.debounce_s
            ):
                self.dumps_suppressed += 1
                if self._c_suppressed is not None:
                    self._c_suppressed.inc()
                return None
            self._last_dump_mono = now
            self._seq += 1
            seq = self._seq
            wall = clock.wall()
            name = f"dump-{int(wall)}-{seq:04d}-{reason}"
            final = os.path.join(self.out_dir, name)
            tmp = os.path.join(self.out_dir, f".tmp-{name}")
            try:
                self._write_dump(tmp, name, reason, trigger, wall, now)
                os.rename(tmp, final)  # atomic publish: all-or-nothing
            except Exception:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self.dumps_written += 1
            self.last_dump_path = final
            if self._c_dumps is not None:
                self._c_dumps.inc()
            self._retain()
        return final

    def _write_dump(
        self,
        tmp: str,
        name: str,
        reason: str,
        trigger: Optional[dict],
        wall: float,
        mono: float,
    ) -> None:
        os.makedirs(tmp, exist_ok=True)
        artifacts: List[str] = []
        # routers' version stamps are the dump's identity: which (table,
        # stage) composition was serving when the trigger fired
        serving: List[dict] = []
        for r in self.routers:
            stage_version, stages = r.stage_set()
            serving.append({
                "table_version": r.db.table_version,
                "stage_version": stage_version,
                "active_stages": sorted(stages.active),
            })
        if self.bus is not None:
            events = [e.as_dict() for e in self.bus.events()]
            with open(os.path.join(tmp, "events.jsonl"), "w") as f:
                for e in events:
                    f.write(json.dumps(e, default=_json_default) + "\n")
            artifacts.append("events.jsonl")
        n_traces = 0
        if self.tracer is not None:
            traces = self.tracer.traces()[-self.max_traces:]
            n_traces = len(traces)
            with open(os.path.join(tmp, "traces.jsonl"), "w") as f:
                for t in traces:
                    f.write(json.dumps(t.as_dict(), default=_json_default) + "\n")
            artifacts.append("traces.jsonl")
        if self.registry is not None:
            _write_json(os.path.join(tmp, "metrics.json"),
                        self.registry.snapshot())
            artifacts.append("metrics.json")
        if self.ring is not None:
            _write_json(os.path.join(tmp, "timeseries.json"),
                        _ring_points_dict(self.ring))
            artifacts.append("timeseries.json")
        if self.health is not None:
            _write_json(os.path.join(tmp, "health.json"),
                        self.health.snapshot())
            artifacts.append("health.json")
        if self.slo is not None:
            # latched state only — evaluate() would publish transitions into
            # the very bus this recorder subscribes to (dump-from-a-dump)
            _write_json(os.path.join(tmp, "slo.json"),
                        {"burning": self.slo.burning()})
            artifacts.append("slo.json")
        if self.profiler is not None:
            _write_json(os.path.join(tmp, "profile.json"),
                        self.profiler.snapshot())
            artifacts.append("profile.json")
        manifest = {
            "format_version": DUMP_FORMAT_VERSION,
            "name": name,
            "reason": reason,
            "trigger": trigger,
            "wall_ts": wall,
            "mono_ts": mono,
            "serving": serving,
            "n_traces": n_traces,
            "artifacts": artifacts,
        }
        _write_json(os.path.join(tmp, "manifest.json"), manifest)

    def _retain(self) -> None:
        """Keep the newest `max_dumps` dumps; sweep stale .tmp- staging."""
        try:
            entries = sorted(os.listdir(self.out_dir))
        except OSError:
            return
        for e in entries:
            if e.startswith(".tmp-"):
                path = os.path.join(self.out_dir, e)
                # a .tmp- dir whose final name exists (or that was simply
                # abandoned by a crash) is garbage either way
                if path != self.last_dump_path:
                    shutil.rmtree(path, ignore_errors=True)
        dumps = [e for e in entries if e.startswith("dump-")]
        for e in dumps[: max(0, len(dumps) - self.max_dumps)]:
            shutil.rmtree(os.path.join(self.out_dir, e), ignore_errors=True)

    # ----------------------------------------------------------------- reading
    def list(self) -> List[DumpRecord]:
        """Retained dumps, oldest first (what ``/dumps`` serves)."""
        return list_dumps(self.out_dir)

    def summary(self) -> dict:
        with self._lock:
            return {
                "out_dir": self.out_dir,
                "dumps_written": self.dumps_written,
                "dumps_suppressed": self.dumps_suppressed,
                "last_dump": self.last_dump_path,
                "debounce_s": self.debounce_s,
                "max_dumps": self.max_dumps,
                "triggers": sorted(self.trigger_kinds),
            }


def _ring_points_dict(ring) -> dict:
    """The TimeSeriesRing's window as JSON: per-point counters/gauges and
    histogram (count, sum) — bucket vectors stay in-process, the replay
    only needs the windowed activity totals."""
    points = []
    for p in ring.points():
        points.append({
            "mono": p.mono,
            "wall": p.wall,
            "counters": dict(p.counters),
            "gauges": dict(p.gauges),
            "hists": {
                k: {"count": int(h.count), "sum": float(h.sum)}
                for k, h in p.hists.items()
            },
        })
    return {"interval_s": ring.interval_s, "points": points}


# ------------------------------------------------------------------ offline


def list_dumps(out_dir: str) -> List[DumpRecord]:
    """Published dumps under `out_dir`, oldest first (manifest attached).

    Staging dirs (``.tmp-``) and dirs without a readable manifest are
    skipped — the atomic-rename protocol means those are not dumps.
    """
    out: List[DumpRecord] = []
    try:
        entries = sorted(os.listdir(out_dir))
    except OSError:
        return out
    for e in entries:
        if not e.startswith("dump-"):
            continue
        path = os.path.join(out_dir, e)
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            continue
        out.append(DumpRecord(name=e, path=path, manifest=manifest))
    return out


def load_dump(path: str) -> dict:
    """Load one dump directory into a dict keyed by artifact."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    out: dict = {"manifest": manifest}
    for art in manifest.get("artifacts", ()):
        fp = os.path.join(path, art)
        key = art.split(".")[0]
        if art.endswith(".jsonl"):
            records = []
            with open(fp) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        records.append(json.loads(line))
            out[key] = records
        else:
            with open(fp) as f:
                out[key] = json.load(f)
    return out


def render_replay(path: str, window_s: float = 60.0) -> str:
    """Postmortem timeline of one dump: what happened, in order.

    Interleaves the event ring with the sampled trace spans inside the
    trailing `window_s` before the dump, marks the trigger, and closes with
    the SLO/health/version state at dump time — the offline answer to
    "what happened at 3 a.m.?".
    """
    d = load_dump(path)
    m = d["manifest"]
    lines = [
        f"flight dump {m['name']} (format v{m['format_version']})",
        f"reason: {m['reason']}"
        + (f" | trigger: {json.dumps(m['trigger'], default=_json_default)}"
           if m.get("trigger") else ""),
    ]
    for s in m.get("serving", ()):
        lines.append(
            f"serving: table v{s['table_version']} stage v{s['stage_version']}"
            f" stages={s['active_stages'] or '(none)'}"
        )
    slo = d.get("slo")
    if slo is not None:
        lines.append(f"slo burning at dump: {slo.get('burning') or '(none)'}")
    health = d.get("health")
    if health is not None:
        lines.append(f"health at dump: {health.get('status', '?')}")

    cutoff = float(m["wall_ts"]) - float(window_s)
    timeline: List[Tuple[float, str]] = []
    for e in d.get("events", ()):
        if e["ts"] < cutoff:
            continue
        detail = {k: v for k, v in e.items()
                  if k not in ("seq", "ts", "kind", "plane")}
        mark = " <-- trigger" if (
            m.get("trigger") and e.get("seq") == m["trigger"].get("seq")
        ) else ""
        timeline.append((
            e["ts"],
            f"event [{e['seq']:5d}] {e['plane']:8s} {e['kind']:16s} "
            + " ".join(f"{k}={v}" for k, v in sorted(detail.items()))
            + mark,
        ))
    for t in d.get("traces", ()):
        if t["ts"] < cutoff:
            continue
        spans = ", ".join(f"{n} {ms:.2f}ms" for n, ms in t["spans"].items())
        timeline.append((
            t["ts"],
            f"trace #{t['trace_id']} total={t['total_ms']:.2f}ms "
            f"[{spans}] batch={t['batch_size']} path={t['path']} "
            f"table=v{t['table_version']} stage=v{t['stage_version']}",
        ))
    timeline.sort(key=lambda x: x[0])
    t0 = float(m["wall_ts"])
    lines.append(f"timeline (trailing {window_s:g}s, {len(timeline)} entries):")
    for ts, text in timeline:
        lines.append(f"  {ts - t0:+8.2f}s {text}")
    n_older = len(d.get("events", ())) + len(d.get("traces", ())) - len(timeline)
    if n_older:
        lines.append(f"  ({n_older} older record(s) outside the window; "
                     f"widen with --window)")
    metrics = d.get("metrics")
    if metrics:
        hist = metrics.get("histograms", {}).get("route_batch_ms")
        if hist:
            lines.append(
                f"route_batch_ms at dump: n={hist['count']} "
                f"p50={hist['p50']:.2f}ms p99={hist['p99']:.2f}ms"
            )
    profile = d.get("profile")
    if profile:
        for fn, row in sorted(profile.get("jits", {}).items()):
            lines.append(
                f"jit {fn}: cache={row['cache_size']} "
                f"compiles_post_warmup={row['compiles_total']}"
            )
    return "\n".join(lines) + "\n"
