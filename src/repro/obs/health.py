"""Live health surface: one JSON snapshot + HTTP exposition for all planes.

`HealthMonitor` aggregates the health signals the planes already maintain
but that were previously write-only attributes someone had to know to poll:

* serving — per-router (table_version, stage_version, active stages,
  `outcomes_dropped`);
* control/learn — each controller's `last_loop_error` (set by a failing
  daemon step, cleared by the next good one) and step/report counts;
* index — per-manager freshness (False = exact-fallback serving while a
  rebuild is in flight) and build/serve counters;
* stores — OutcomeStore window size and ring drops;
* events — bus per-kind counts + ring drops.

`status` folds those into one tri-state: ``"error"`` when any daemon loop
is failing (`last_loop_error` set), ``"degraded"`` when serving is correct
but not nominal (stale index serving the exact fallback, outcome events
dropped, an SLO currently burning — see `repro.obs.slo`), ``"ok"``
otherwise. Clear-on-recovery is inherited from the controllers: the next
successful step clears `last_loop_error` and the snapshot goes back to
"ok" with no monitor-side state (SLO state clears when the engine's next
evaluation sees the burn gone).

`ObsServer` exposes the snapshot over HTTP for scrapers and humans:
``/metrics`` (Prometheus text exposition from the registry), ``/health``
(this snapshot as JSON; 503 on "error" so load-balancer checks fail over),
``/events?since=N`` (bus tail), ``/slo`` (the SLO engine's burn-rate
snapshot), ``/traces?since=N`` / ``/traces?id=N`` (the tracer ring — how
`repro-obs watch` resolves a p99 exemplar id into its RouteTrace),
``/dumps`` (the flight recorder's retained black-box dumps: manifests +
recorder counters, the live half of ``repro-obs replay``), and
``/profile`` (the JitProfiler's per-program compile counters, cache sizes,
and stamped FLOPs/bytes, plus the sampling profiler's stacks when one is
attached). It is a daemon-threaded stdlib server — zero deps, good for
one scraper and a curl, not a public ingress.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Sequence
from urllib.parse import parse_qs, urlparse

from repro.obs.events import EventBus
from repro.obs.metrics import MetricsRegistry, get_registry

__all__ = ["HealthMonitor", "ObsServer"]


class HealthMonitor:
    def __init__(
        self,
        routers: Sequence = (),
        controllers: Sequence = (),  # Refinement/LearningControllers mixed
        indexes: Sequence = (),  # ToolIndexManagers
        stores: Sequence = (),  # OutcomeStores
        bus: Optional[EventBus] = None,
        slo: Optional["SLOEngine"] = None,  # repro.obs.slo
    ):
        self.routers = list(routers)
        self.controllers = list(controllers)
        self.indexes = list(indexes)
        self.stores = list(stores)
        self.bus = bus
        self.slo = slo

    def snapshot(self) -> dict:
        serving = []
        for r in self.routers:
            stage_version, stages = r.stage_set()
            serving.append({
                "table_version": r.db.table_version,
                "stage_version": stage_version,
                "active_stages": sorted(stages.active),
                "outcomes_dropped": r.outcomes_dropped,
            })
        control = []
        for c in self.controllers:
            err = getattr(c, "last_loop_error", None)
            control.append({
                "controller": type(c).__name__,
                "last_loop_error": repr(err) if err is not None else None,
                "n_reports": len(getattr(c, "reports", ())),
            })
        index = [
            {"fresh": m.is_fresh(), "backend": m.backend_kind,
             "stats": dict(m.stats)}
            for m in self.indexes
        ]
        stores = [
            {"n_events": len(s), "dropped": s.dropped,
             "total_ingested": s.total_ingested}
            for s in self.stores
        ]
        loop_errors = [c for c in control if c["last_loop_error"] is not None]
        # a burning SLO is "degraded", not "error": serving is still correct,
        # it is just out of objective — same class as fallback-serving
        burning = self.slo.burning() if self.slo is not None else []
        degraded = (
            any(not m["fresh"] for m in index)
            or any(r["outcomes_dropped"] for r in serving)
            or any(s["dropped"] for s in stores)
            or bool(burning)
        )
        status = "error" if loop_errors else ("degraded" if degraded else "ok")
        snap = {
            "status": status,
            "ok": status != "error",
            "serving": serving,
            "control": control,
            "index": index,
            "stores": stores,
        }
        if self.slo is not None:
            snap["slo"] = {"burning": burning}
        if self.bus is not None:
            snap["events"] = {
                "counts": self.bus.counts(),
                "retained": len(self.bus),
                "dropped": self.bus.dropped,
            }
        return snap


class ObsServer:
    """Daemon-threaded HTTP exposition of metrics/health/events."""

    def __init__(
        self,
        monitor: Optional[HealthMonitor] = None,
        registry: Optional[MetricsRegistry] = None,
        bus: Optional[EventBus] = None,
        host: str = "127.0.0.1",
        port: int = 0,  # 0 = ephemeral; read `.port` after construction
        slo: Optional["SLOEngine"] = None,  # repro.obs.slo
        tracer: Optional["RouteTracer"] = None,  # repro.obs.trace
        recorder: Optional["FlightRecorder"] = None,  # repro.obs.flightrec
        profiler: Optional["JitProfiler"] = None,  # repro.obs.profile
        sampler: Optional["SamplingProfiler"] = None,  # repro.obs.profile
    ):
        self.monitor = monitor or HealthMonitor()
        self.registry = registry or get_registry()
        self.bus = bus
        self.slo = slo
        self.tracer = tracer
        self.recorder = recorder
        self.profiler = profiler
        self.sampler = sampler
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *args):  # no stderr chatter per scrape
                pass

            def _send(self, code: int, body: str, ctype: str):
                data = body.encode()
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                url = urlparse(self.path)
                if url.path == "/metrics":
                    self._send(200, server.registry.render_prometheus(),
                               "text/plain; version=0.0.4")
                elif url.path == "/health":
                    snap = server.monitor.snapshot()
                    self._send(200 if snap["ok"] else 503,
                               json.dumps(snap, indent=2), "application/json")
                elif url.path == "/events" and server.bus is not None:
                    since = int(
                        parse_qs(url.query).get("since", ["-1"])[0]
                    )
                    evs = [e.as_dict() for e in server.bus.events(since)]
                    self._send(200, json.dumps(evs, indent=2),
                               "application/json")
                elif url.path == "/slo" and server.slo is not None:
                    # snapshot() evaluates — a scrape is also a judgement,
                    # and the engine's transition latch keeps events single
                    snap = server.slo.snapshot()
                    self._send(200, json.dumps(snap, indent=2),
                               "application/json")
                elif url.path == "/traces" and server.tracer is not None:
                    qs = parse_qs(url.query)
                    if "id" in qs:
                        t = server.tracer.get(int(qs["id"][0]))
                        if t is None:
                            self._send(404, "trace not retained\n",
                                       "text/plain")
                            return
                        self._send(200, json.dumps(t.as_dict(), indent=2),
                                   "application/json")
                        return
                    since = int(qs.get("since", ["-1"])[0])
                    recs = [t.as_dict() for t in server.tracer.traces()
                            if t.trace_id > since]
                    self._send(200, json.dumps(recs, indent=2),
                               "application/json")
                elif url.path == "/dumps" and server.recorder is not None:
                    body = {
                        "recorder": server.recorder.summary(),
                        "dumps": [
                            {"name": d.name, "path": d.path,
                             "manifest": d.manifest}
                            for d in server.recorder.list()
                        ],
                    }
                    self._send(200, json.dumps(body, indent=2),
                               "application/json")
                elif url.path == "/profile" and server.profiler is not None:
                    body = server.profiler.snapshot()
                    if server.sampler is not None:
                        body["sampling"] = server.sampler.snapshot()
                    self._send(200, json.dumps(body, indent=2),
                               "application/json")
                else:
                    self._send(404, "not found\n", "text/plain")

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "ObsServer":
        assert self._thread is None, "obs server already running"
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-server", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, timeout_s: float = 5.0) -> None:
        """Idempotent shutdown: stop accepting, join with a bounded wait,
        release the socket. Safe to call from a signal path and again from
        an atexit/finally path — the second call is a no-op."""
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._thread.join(timeout=timeout_s)
        self._httpd.server_close()
        self._thread = None
