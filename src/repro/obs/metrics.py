"""MetricsRegistry: bounded-memory counters, gauges, log-spaced histograms.

The serve-time metrics substrate (paper §5.5's budget, made observable):
every instrument is preallocated — a histogram is a fixed numpy int64 bin
vector over log-spaced edges, a counter/gauge one float — so the hot path
never appends to a list and memory is bounded no matter how long the
process serves. `record`/`inc` are O(1): one `bisect` over ~80 edges
plus a few scalar updates under a per-instrument lock (uncontended CPython
locks are ~100 ns; `route_batch` records ~10 values per *batch*, so the
instrumentation budget is microseconds against a millisecond batch —
`benchmarks/obs_bench.py` enforces the <5 % overhead bound in CI).

Instruments are get-or-create by (name, labels) — calling
``registry.histogram("route_phase_ms", phase="embed")`` twice returns the
same object, so planes can resolve instruments at construction time and
share them across threads. ``render_prometheus()`` emits the standard text
exposition (cumulative ``_bucket{le=...}`` + ``_sum``/``_count``);
``snapshot()`` returns the JSON-friendly view the health surface and
examples use.

A process-wide default registry (`get_registry()`) backs instruments in
code that cannot plumb one through (the gateway defaults to it); tests pass
their own `MetricsRegistry()` for isolation.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import clock

__all__ = [
    "Counter",
    "Gauge",
    "LogHistogram",
    "MetricsRegistry",
    "default_edges",
    "get_registry",
]


def default_edges(
    lo: float = 1e-3, hi: float = 1e4, per_decade: int = 10
) -> np.ndarray:
    """Log-spaced bucket upper edges: `per_decade` buckets per decade of
    [lo, hi]. The default (1 µs .. 10 s in ms units, 10/decade) resolves
    percentiles to ~26 % relative error worst-case — plenty against a
    10 ms budget — with 71 preallocated bins."""
    n = int(round(per_decade * math.log10(hi / lo)))
    return np.geomspace(lo, hi, n + 1)


class Counter:
    """Monotone event counter. `inc` is thread-safe (per-instrument lock)."""

    kind = "counter"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins scalar (versions, freshness flags, queue depths)."""

    kind = "gauge"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def value(self) -> float:
        with self._lock:
            return self._value


class LogHistogram:
    """Fixed log-spaced-bucket histogram with O(1) bounded-memory record.

    Bucket i counts values <= edges[i] (first bucket catches everything
    below `lo`, one overflow bucket everything above `hi`). Exact count,
    sum, min, and max are tracked alongside, so `mean()` is exact and
    `percentile()` clamps its bucket-interpolated estimate to the observed
    range — a one-sample histogram reports that sample, not a bucket edge.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        labels: Tuple[Tuple[str, str], ...] = (),
        edges: Optional[np.ndarray] = None,
    ):
        self.name = name
        self.labels = labels
        self.edges = np.asarray(edges if edges is not None else default_edges(),
                                dtype=np.float64)
        assert self.edges.ndim == 1 and len(self.edges) >= 2
        assert bool(np.all(np.diff(self.edges) > 0)), "edges must be ascending"
        # scalar bucket lookup uses bisect over this plain list: ~20x less
        # per-call overhead than numpy's scalar searchsorted (~2 µs), which
        # obs_bench's profile showed dominating the per-record cost at ~7
        # records per batch
        self._edges_list: List[float] = self.edges.tolist()
        self._counts = np.zeros(len(self.edges) + 1, dtype=np.int64)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        # per-bucket most-recent exemplar slots, allocated lazily on the
        # first record(..., exemplar=) so histograms that never attach
        # exemplars pay nothing (no flag needed at get-or-create time)
        self._exemplars: Optional[List[Optional[Tuple[object, float, float]]]] = None
        self._lock = threading.Lock()

    def record(self, value: float, exemplar: Optional[object] = None) -> None:
        """Record one value; `exemplar` optionally tags its bucket with an
        opaque id (a sampled trace id) — most-recent-wins per bucket."""
        v = float(value)
        # bucket index outside the lock: bisect is pure computation (and
        # matches searchsorted side="left" exactly)
        i = bisect.bisect_left(self._edges_list, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if exemplar is not None:
                if self._exemplars is None:
                    self._exemplars = [None] * len(self._counts)
                self._exemplars[i] = (exemplar, v, clock.wall())

    def record_many(self, values) -> None:
        """Bulk record: one vectorized bucket pass + one lock acquisition.

        The per-batch cost is one `searchsorted` + `bincount` over the whole
        array, so per-query instruments (score gaps: batch-size values per
        route_batch) stay inside the telemetry overhead budget.
        """
        v = np.asarray(values)
        if v.ndim != 1:
            v = v.ravel()
        if v.size == 0:
            return
        idx = np.searchsorted(self.edges, v, side="left")
        binned = np.bincount(idx, minlength=len(self._counts)).astype(
            np.int64, copy=False
        )
        total, s = int(v.size), float(v.sum())
        lo, hi = float(v.min()), float(v.max())
        with self._lock:
            self._counts += binned
            self._count += total
            self._sum += s
            if lo < self._min:
                self._min = lo
            if hi > self._max:
                self._max = hi

    # ---------------------------------------------------------------- reading
    def count(self) -> int:
        with self._lock:
            return self._count

    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def bucket_counts(self) -> np.ndarray:
        with self._lock:
            return self._counts.copy()

    def percentile(self, q: float) -> float:
        """Bucket-interpolated percentile estimate (exact to one bucket).

        Finds the bucket holding the q-th sample and interpolates linearly
        inside it; the estimate is clamped to the exact observed [min, max]
        so it can never leave the data range.
        """
        with self._lock:
            counts = self._counts.copy()
            total, lo, hi = self._count, self._min, self._max
        if total == 0:
            return 0.0
        rank = q / 100.0 * total
        cum = np.cumsum(counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        i = min(i, len(counts) - 1)
        left = self.edges[i - 1] if 0 < i <= len(self.edges) else lo
        right = self.edges[i] if i < len(self.edges) else hi
        prev = cum[i - 1] if i > 0 else 0
        in_bucket = counts[i]
        frac = (rank - prev) / in_bucket if in_bucket else 0.0
        est = left + (right - left) * min(max(frac, 0.0), 1.0)
        return float(min(max(est, lo), hi))

    def exemplars(self) -> Dict[int, Tuple[object, float, float]]:
        """{bucket_index: (exemplar_id, value, wall_ts)} for tagged buckets."""
        with self._lock:
            if self._exemplars is None:
                return {}
            return {i: e for i, e in enumerate(self._exemplars) if e is not None}

    def percentile_exemplar(self, q: float) -> Optional[Tuple[object, float, float]]:
        """The exemplar nearest the q-th percentile's bucket, or None.

        Prefers the percentile bucket itself, then higher buckets (the tail
        the percentile summarizes), then lower ones — so "your p99 bucket →
        this trace" degrades gracefully when sampling missed that bucket.
        """
        with self._lock:
            if self._exemplars is None or self._count == 0:
                return None
            counts = self._counts.copy()
            total = self._count
            slots = list(self._exemplars)
        rank = q / 100.0 * total
        cum = np.cumsum(counts)
        i = min(int(np.searchsorted(cum, rank, side="left")), len(counts) - 1)
        for j in list(range(i, len(slots))) + list(range(i - 1, -1, -1)):
            if slots[j] is not None:
                return slots[j]
        return None

    def summary(self) -> Dict[str, float]:
        with self._lock:
            total, lo, hi = self._count, self._min, self._max
            has_exemplars = self._exemplars is not None
        out = {
            "count": total,
            "mean": self.mean(),
            "p50": self.percentile(50.0),
            "p90": self.percentile(90.0),
            "p99": self.percentile(99.0),
            "min": lo if total else 0.0,
            "max": hi if total else 0.0,
        }
        if has_exemplars:
            ex = self.percentile_exemplar(99.0)
            if ex is not None:
                out["p99_exemplar"] = ex[0]
        return out


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label_value(v: str) -> str:
    """Prometheus text-format label-value escaping: backslash first (so the
    escapes it introduces are not re-escaped), then quote and newline."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(labels: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape_label_value(v)}"' for k, v in labels]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class MetricsRegistry:
    """Process-wide instrument store: get-or-create by (name, labels)."""

    def __init__(self):
        self._instruments: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], object] = {}
        self._kinds: Dict[str, str] = {}  # name -> kind (one kind per name)
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                if self._kinds.setdefault(name, cls.kind) != cls.kind:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{self._kinds[name]}, not {cls.kind}"
                    )
                inst = self._instruments[key] = cls(name, key[1], **kw)
            elif not isinstance(inst, cls):
                raise ValueError(f"metric {name!r}{labels} is a {inst.kind}")
            return inst

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(
        self, name: str, edges: Optional[np.ndarray] = None, **labels: str
    ) -> LogHistogram:
        return self._get(LogHistogram, name, labels, edges=edges)

    def instruments(self) -> List[object]:
        with self._lock:
            return list(self._instruments.values())

    # ---------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, dict]:
        """JSON-friendly view: {kind: {"name{labels}": value-or-summary}}."""
        out: Dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for inst in self.instruments():
            key = inst.name + _label_str(inst.labels)
            if inst.kind == "counter":
                out["counters"][key] = inst.value()
            elif inst.kind == "gauge":
                out["gauges"][key] = inst.value()
            else:
                out["histograms"][key] = inst.summary()
        return out

    def render_prometheus(self) -> str:
        """Standard Prometheus text exposition (one scrape = one call)."""
        by_name: Dict[str, List[object]] = {}
        for inst in self.instruments():
            by_name.setdefault(inst.name, []).append(inst)
        lines: List[str] = []
        for name in sorted(by_name):
            insts = by_name[name]
            lines.append(f"# TYPE {name} {insts[0].kind}")
            for inst in insts:
                if inst.kind in ("counter", "gauge"):
                    lines.append(f"{name}{_label_str(inst.labels)} {inst.value()}")
                    continue
                counts = inst.bucket_counts()
                cum = np.cumsum(counts)
                for i, edge in enumerate(inst.edges):
                    le = f'le="{edge:g}"'
                    lines.append(
                        f"{name}_bucket{_label_str(inst.labels, le)} {cum[i]}"
                    )
                inf = 'le="+Inf"'
                lines.append(
                    f"{name}_bucket{_label_str(inst.labels, inf)} {cum[-1]}"
                )
                with inst._lock:
                    s, c = inst._sum, inst._count
                lines.append(f"{name}_sum{_label_str(inst.labels)} {s}")
                lines.append(f"{name}_count{_label_str(inst.labels)} {c}")
        return "\n".join(lines) + "\n"


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (the gateway's fallback)."""
    return _DEFAULT
