"""Continuous profiling: live compile/cost telemetry for the hot path.

PR 5's analysis pass checks the repo's compile discipline *offline*: the
retrace CI leg fails a build whose hot jits trace beyond the power-of-two
bucket set, and the jit-lint rules catch construction-time hazards. None of
that sees a *production* retrace — a novel batch shape, a silently changed
dtype, a stage promotion that invalidates a cache — which lands as a
multi-ms stall against the 10 ms p99 budget with no metric to alert on.
This module turns those invariants into live telemetry:

* `JitProfiler` — polls each tracked jitted callable's compile-cache size
  (`fn._cache_size()`, the same private-but-stable probe
  `analysis/retrace.py` uses). The **first** `collect()` establishes a
  baseline so warmup compiles are not counted as incidents; after that,
  every cache growth increments ``jit_compiles_total{fn=...}`` and the
  absolute size is mirrored to ``jit_cache_size{fn=...}``. With the
  counters in the registry, the `TimeSeriesRing` windows them like any
  other signal and `default_slos()`'s ``jit_retrace_rate`` SLO alerts on a
  sustained post-warmup compile rate — an in-production retrace is now an
  alertable event, not a CI-only invariant.

* Cost stamping — `stamp_cost(name, *args)` lowers + compiles the tracked
  jit against representative arguments and records XLA's
  ``cost_analysis()`` FLOPs / bytes-accessed for that program
  (`stamp_router_costs` derives representative shapes from a live router).
  Lowering is out-of-band of the jit call cache — it never grows
  `_cache_size` — so stamping cannot show up as a retrace. The result is
  exported at ``/profile``: per-program static cost next to per-program
  compile activity.

* `SamplingProfiler` — an opt-in wall-clock sampler for the controller
  daemons: a daemon thread snapshots ``sys._current_frames()`` at a fixed
  interval, filters to the registered thread idents, and aggregates
  collapsed stacks into counts. Self-time is attributed to whatever frame
  is on top when the sample lands — the classic statistical profile, at
  ~zero cost to the profiled threads (no tracing hook is installed). Off
  by default; `launch/serve.py` enables it behind ``--profile-daemons``.
"""
from __future__ import annotations

import sys
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.retrace import supports_cache_size

__all__ = ["JitProfiler", "SamplingProfiler", "stamp_router_costs"]


def _cost_analysis_dict(compiled) -> dict:
    """Normalize XLA's cost_analysis across jax versions (list-of-dict or
    dict) into {"flops": float, "bytes_accessed": float}."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 — backend may not implement it
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    out = {}
    if "flops" in ca:
        out["flops"] = float(ca["flops"])
    for k in ("bytes accessed", "bytes_accessed"):
        if k in ca:
            out["bytes_accessed"] = float(ca[k])
            break
    return out


class JitProfiler:
    """Compile-cache poller + cost stamper over named jitted callables.

    `collect()` is cheap (one attribute read per fn) and is meant to run on
    the `TimeSeriesRing` tick cadence; the first call only baselines.
    """

    def __init__(
        self,
        jits: Optional[Dict[str, Callable]] = None,
        registry=None,  # repro.obs.metrics.MetricsRegistry
    ):
        if jits is None:
            from repro.router.gateway import hot_path_jits

            jits = hot_path_jits()
        self._fns: Dict[str, Callable] = {}
        self.unsupported: List[str] = []
        for name, fn in jits.items():
            if supports_cache_size(fn):
                self._fns[name] = fn
            else:
                self.unsupported.append(name)
        self.registry = registry
        # last observed cache size per fn; None until the baseline collect
        self._last: Dict[str, Optional[int]] = {n: None for n in self._fns}
        self._compiles: Dict[str, int] = {n: 0 for n in self._fns}
        self._costs: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self._counters = self._gauges = None
        if registry is not None:
            self._counters = {
                n: registry.counter("jit_compiles_total", fn=n) for n in self._fns
            }
            self._gauges = {
                n: registry.gauge("jit_cache_size", fn=n) for n in self._fns
            }

    def names(self) -> List[str]:
        return sorted(self._fns)

    # ------------------------------------------------------------- collecting
    def collect(self) -> Dict[str, int]:
        """Poll every cache size; count post-baseline growth as compiles.

        Returns {fn: cache_size}. The first call per fn records the
        baseline without incrementing — warmup compiles are expected, only
        growth *after* the profiler is watching is a retrace signal.
        """
        sizes = {n: int(f._cache_size()) for n, f in self._fns.items()}
        with self._lock:
            for n, size in sizes.items():
                last = self._last[n]
                if last is not None and size > last:
                    delta = size - last
                    self._compiles[n] += delta
                    if self._counters is not None:
                        self._counters[n].inc(delta)
                self._last[n] = size
                if self._gauges is not None:
                    self._gauges[n].set(size)
        return sizes

    # --------------------------------------------------------------- stamping
    def stamp_cost(self, name: str, *args, **kwargs) -> dict:
        """Lower + compile `name` against `args` and record FLOPs/bytes.

        Lowering is out-of-band of the jit call cache — it does not grow
        `_cache_size` (asserted in the tests) — so stamping never
        manufactures the retrace signal it exists to watch for.
        """
        fn = self._fns[name]
        cost = _cost_analysis_dict(fn.lower(*args, **kwargs).compile())
        cost["arg_shapes"] = [
            list(np.shape(a)) for a in args if hasattr(a, "shape")
        ]
        with self._lock:
            self._costs[name] = cost
        return cost

    # ---------------------------------------------------------------- reading
    def snapshot(self) -> dict:
        """The ``/profile`` payload: per-jit cache/compile/cost state."""
        with self._lock:
            jits = {
                n: {
                    "cache_size": self._last[n] if self._last[n] is not None else 0,
                    "compiles_total": self._compiles[n],
                    "baselined": self._last[n] is not None,
                    "cost": self._costs.get(n),
                }
                for n in self._fns
            }
        return {"jits": jits, "unsupported": list(self.unsupported)}


def stamp_router_costs(
    profiler: JitProfiler, router, batch_size: int = 1
) -> Dict[str, dict]:
    """Stamp the profiler's hot jits with shapes a live `router` serves.

    Derives one representative program per active entry point — the scoring
    path always, the adapter/reranker only when their stages are live (an
    inactive stage has no compiled program to cost). Batch size is padded to
    the same power-of-two bucket `route_batch` would use, so the stamped
    program IS the serving program.
    """
    import jax.numpy as jnp

    from repro.common.bucketing import pad_amount

    q = int(batch_size)
    q_pad = q + pad_amount(q)
    _, emb = router.db.snapshot()
    emb = np.asarray(emb)
    n_t = emb.shape[0]
    qblock = jnp.asarray(emb[:1].repeat(q_pad, axis=0))
    stamped: Dict[str, dict] = {}
    _, stages = router.stage_set()
    rerank = stages.has_reranker
    c = (
        min(router.k * router.candidate_multiplier, n_t)
        if rerank
        else min(router.k, n_t)
    )
    if "topk_dense" in profiler.names():
        stamped["topk_dense"] = profiler.stamp_cost(
            "topk_dense", qblock, jnp.asarray(emb), c
        )
    if "adapter_apply" in profiler.names() and stages.has_adapter:
        stamped["adapter_apply"] = profiler.stamp_cost(
            "adapter_apply", stages.adapter_params, qblock,
            scale=stages.adapter_scale,
        )
    if "rerank_topk_scored" in profiler.names() and rerank:
        from repro.core.features import N_FEATURES

        feats = jnp.zeros((q_pad, c, N_FEATURES), jnp.float32)
        cand = jnp.zeros((q_pad, c), jnp.int32)
        stamped["rerank_topk_scored"] = profiler.stamp_cost(
            "rerank_topk_scored", stages.mlp_params, feats, cand, router.k
        )
    return stamped


class SamplingProfiler:
    """Opt-in statistical wall-clock profiler over chosen threads.

    Samples `sys._current_frames()` on a daemon thread and aggregates
    collapsed call stacks (outermost;...;innermost) per registered thread.
    The profiled threads pay nothing — no trace hook, no instrumentation —
    and the profile's resolution is the sampling interval.
    """

    def __init__(self, interval_s: float = 0.05, max_depth: int = 24):
        self.interval_s = float(interval_s)
        self.max_depth = int(max_depth)
        self._targets: Dict[int, str] = {}  # thread ident -> display name
        self._samples: Dict[str, Dict[str, int]] = {}  # name -> stack -> n
        self._n_ticks = 0
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.last_loop_error: Optional[str] = None

    def watch_thread(self, thread: threading.Thread, name: Optional[str] = None):
        """Register a (started) thread for sampling."""
        assert thread.ident is not None, "watch_thread needs a started thread"
        with self._lock:
            self._targets[thread.ident] = name or thread.name
        return self

    def sample_once(self) -> int:
        """Take one sample of every watched thread; returns threads seen."""
        frames = sys._current_frames()
        seen = 0
        with self._lock:
            targets = dict(self._targets)
        collapsed: List[Tuple[str, str]] = []
        for ident, name in targets.items():
            frame = frames.get(ident)
            if frame is None:
                continue  # thread exited; keep the accumulated profile
            stack: List[str] = []
            depth = 0
            while frame is not None and depth < self.max_depth:
                code = frame.f_code
                stack.append(f"{code.co_name}@{code.co_filename.rsplit('/', 1)[-1]}")
                frame = frame.f_back
                depth += 1
            collapsed.append((name, ";".join(reversed(stack))))
            seen += 1
        with self._lock:
            self._n_ticks += 1
            for name, stack in collapsed:
                per = self._samples.setdefault(name, {})
                per[stack] = per.get(stack, 0) + 1
        return seen

    def start(self) -> "SamplingProfiler":
        assert self._thread is None, "sampling profiler already running"
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                try:
                    self.sample_once()
                    self.last_loop_error = None
                except Exception as exc:  # noqa: BLE001 — daemon must survive
                    self.last_loop_error = f"{type(exc).__name__}: {exc}"
                self._stop.wait(self.interval_s)

        self._thread = threading.Thread(
            target=_loop, name="sampling-profiler", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, timeout_s: float = 5.0) -> None:
        """Idempotent; joins the sampler with a bounded wait."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=timeout_s)
        self._thread = None

    def snapshot(self, top: int = 10) -> dict:
        """Per-thread top collapsed stacks by sample count."""
        with self._lock:
            n_ticks = self._n_ticks
            threads = {
                name: sorted(per.items(), key=lambda kv: -kv[1])[:top]
                for name, per in self._samples.items()
            }
        return {
            "interval_s": self.interval_s,
            "n_samples": n_ticks,
            "threads": {
                name: [{"stack": s, "samples": n} for s, n in stacks]
                for name, stacks in threads.items()
            },
        }
