"""Streaming quality observability: rolling retrieval quality, confidence,
and query-embedding drift — the *leading* indicators for the guards.

`TableGuard`/`StageGuard` judge versions on labelled traffic and act
(rollback/demotion); that is the enforcement arm, and labels arrive
minutes-to-hours after serving (§4.1). This module is the observation arm,
and it adds one signal the guards cannot have: **label-free drift**. A bad
table swap or a query-population shift moves the geometry between queries
and the live table *immediately*, long before enough labels accumulate for
the guard's `min_samples` judgement — so a `quality_drift` event fires
while the guard is still collecting evidence.

Three signals:

* rolling NDCG@k / Recall@k over labelled traffic (`observe`), published
  as ``quality_ndcg`` / ``quality_recall`` gauges — the same rolling
  machinery the guards use, extracted here as `RollingWindows` so all
  three stay numerically identical;
* routing confidence: the gateway records per-query top-1/top-2 score gaps
  into the ``route_score_gap`` histogram; `confidence()` summarizes it (a
  collapsing gap means the router is guessing between tools);
* query-embedding drift: `observe_queries` keeps an EWMA of the per-dim
  query mean and compares it against the live table's per-dim population
  stats (`set_reference`, refreshed on every swap via `watch_db`); the RMS
  z-score is the ``quality_drift_score`` gauge, and crossing
  ``drift_threshold`` publishes a rising-edge ``quality_drift`` event.

Telemetry discipline: `observe_queries` is called from `route_batch` but
does O(batch * dim) numpy work outside any router lock, and the whole
monitor is optional — a gateway without one pays a single None check.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, Dict, Iterable, List, Optional

import numpy as np

__all__ = ["QualityConfig", "QualityMonitor", "RollingWindows"]


class RollingWindows:
    """Per-key bounded rolling windows of floats (the guards' machinery).

    A plain data structure, NOT thread-safe by itself: every user
    (`TableGuard`, `StageGuard`, `QualityMonitor`) already serializes its
    observation path under its own lock, and layering a second lock here
    would only add nesting the lock-order checker must then prove safe.
    """

    def __init__(self, maxlen: int):
        assert maxlen >= 1
        self.maxlen = int(maxlen)
        self._windows: Dict[object, Deque[float]] = {}

    def push(self, key, value: float) -> None:
        w = self._windows.get(key)
        if w is None:
            w = self._windows[key] = deque(maxlen=self.maxlen)
        w.append(float(value))

    def n(self, key) -> int:
        w = self._windows.get(key)
        return len(w) if w is not None else 0

    def mean(self, key) -> Optional[float]:
        w = self._windows.get(key)
        return float(np.mean(w)) if w else None

    def values(self, key) -> List[float]:
        return list(self._windows.get(key, ()))

    def keys(self) -> List[object]:
        return list(self._windows)

    def prune(self, keep: Iterable[object]) -> None:
        """Drop every window whose key is not in `keep` (dead versions)."""
        alive = set(keep)
        for k in [k for k in self._windows if k not in alive]:
            del self._windows[k]


@dataclasses.dataclass(frozen=True)
class QualityConfig:
    k: int = 5  # NDCG@k / Recall@k cutoff
    window: int = 256  # rolling labelled observations kept
    drift_ewma: float = 0.1  # per-batch EWMA weight for the query mean
    drift_threshold: float = 0.5  # RMS z-score that counts as drift
    drift_min_batches: int = 5  # judge drift only after this many batches
    # fold only every Nth batch into the drift EWMA (1 = every batch). The
    # EWMA's horizon is tens of folds, so a small stride changes detection
    # latency by a few batches while cutting the per-route_batch cost by
    # ~1/stride — serve.py and obs_bench run stride 4 as the production
    # shape; the default keeps every-batch semantics for tests and guards
    drift_every: int = 1


class QualityMonitor:
    """Streaming quality signals over live traffic (label-free + labelled)."""

    def __init__(
        self,
        config: QualityConfig = QualityConfig(),
        registry: Optional["MetricsRegistry"] = None,  # repro.obs.metrics
        bus: Optional["EventBus"] = None,  # repro.obs.events
    ):
        self.config = config
        self.bus = bus
        self._rolling = RollingWindows(config.window)
        self._lock = threading.Lock()
        # drift state: reference = live table population stats (per-dim);
        # current = EWMA of per-dim query batch means
        self._ref_mean: Optional[np.ndarray] = None
        self._ref_inv_std: Optional[np.ndarray] = None
        self._ref_version: Optional[int] = None
        self._ew_mean: Optional[np.ndarray] = None
        self._z_scratch: Optional[np.ndarray] = None
        self._seen = 0  # all observe_queries calls (drift_every stride base)
        self._last_score: Optional[float] = None
        self._n_batches = 0
        self._drifting = False  # rising-edge latch for quality_drift
        self.drift_events = 0
        self._g_ndcg = self._g_recall = self._g_drift = None
        self._score_gap_hist = None
        if registry is not None:
            k = str(config.k)
            self._g_ndcg = registry.gauge("quality_ndcg", k=k)
            self._g_recall = registry.gauge("quality_recall", k=k)
            self._g_drift = registry.gauge("quality_drift_score")
            self._score_gap_hist = registry.histogram("route_score_gap")

    # ---------------------------------------------------------- labelled path
    def observe(self, ranked_tools: Iterable[int], relevant: Iterable[int]) -> None:
        """Record one labelled result into the rolling NDCG/Recall windows.

        Unlike the guards this is not per-version — it is the *serving
        stream's* quality, whatever versions produced it; the guards keep
        the per-version attribution needed for rollback judgement.
        """
        from repro.metrics.retrieval import ndcg_at_k, recall_at_k

        ranked, rel = list(ranked_tools), list(relevant)
        nd = ndcg_at_k(ranked, rel, self.config.k)
        rc = recall_at_k(ranked, rel, self.config.k)
        with self._lock:
            self._rolling.push("ndcg", nd)
            self._rolling.push("recall", rc)
            nd_mean = self._rolling.mean("ndcg")
            rc_mean = self._rolling.mean("recall")
        if self._g_ndcg is not None:
            self._g_ndcg.set(nd_mean)
            self._g_recall.set(rc_mean)

    # -------------------------------------------------------- label-free path
    def set_reference(self, table: np.ndarray, version: Optional[int] = None) -> None:
        """Freeze per-dim population stats of the live table as the drift
        reference (refreshed on every swap via `watch_db`)."""
        t = np.asarray(table, dtype=np.float64)
        # stats in float64 (one-time), stored float32 with the division
        # pre-inverted: the per-batch z-score is then two float32 vector ops
        mean = t.mean(axis=0).astype(np.float32)
        inv_std = (1.0 / np.maximum(t.std(axis=0), 1e-6)).astype(np.float32)
        with self._lock:
            self._ref_mean, self._ref_inv_std = mean, inv_std
            self._ref_version = version

    def watch_db(self, db) -> "Callable[[], None]":
        """Track `db`'s live table as the drift reference across swaps.

        Sets the reference now and re-freezes it after every swap/rollback
        (listeners fire outside the database lock). Returns a zero-arg
        detach handle, mirroring `EventBus.watch_db`.
        """
        version, table = db.snapshot()
        self.set_reference(table, version=version)

        def _on_swap(new_version: int) -> None:
            v, t = db.snapshot()
            self.set_reference(t, version=v)

        db.add_swap_listener(_on_swap)
        return lambda: db.remove_swap_listener(_on_swap)

    def observe_queries(self, queries: np.ndarray) -> Optional[float]:
        """Fold one batch of raw query embeddings into the drift estimate.

        Returns the current RMS z-score (None until a reference exists).
        Publishes ``quality_drift`` on the rising edge only — the event
        re-arms once the score falls back under the threshold, so a
        persistently drifted population produces one event, not one per
        batch (the EventBus transitions-only discipline).
        """
        stride = self.config.drift_every
        if stride > 1:
            with self._lock:
                self._seen += 1
                if self._seen % stride:
                    return self._last_score
        q = np.asarray(queries)
        if q.ndim == 1:
            q = q[None, :]
        if q.size == 0:
            return None
        # float32 throughout: this runs on every route_batch, and a drift
        # z-score of O(1) magnitude needs no double precision. The column
        # mean runs as a BLAS matvec — several times faster than
        # `q.mean(axis=0)`'s strided reduction on the [Q, D] row-major block
        if q.dtype != np.float32:
            q = q.astype(np.float32)
        batch_mean = np.dot(
            np.full(q.shape[0], 1.0 / q.shape[0], dtype=np.float32), q
        )
        a = np.float32(self.config.drift_ewma)
        fire = False
        with self._lock:
            if self._ew_mean is None:
                self._ew_mean = batch_mean.copy()
                self._z_scratch = np.empty_like(batch_mean)
            else:
                # in-place fold (and scratch reuse below): this runs on every
                # route_batch, and the allocation-free form halves the
                # cache-cold per-batch cost obs_bench's profile attributed
                # here (temporaries dominate, not flops)
                self._ew_mean *= np.float32(1.0) - a
                batch_mean *= a
                self._ew_mean += batch_mean
            self._n_batches += 1
            if self._ref_mean is None:
                return None
            z = self._z_scratch
            if z.shape != self._ref_mean.shape:
                z = self._z_scratch = np.empty_like(self._ref_mean)
            np.subtract(self._ew_mean, self._ref_mean, out=z)
            z *= self._ref_inv_std
            score = float(np.sqrt(np.dot(z, z) / z.shape[0]))
            self._last_score = score
            ref_version = self._ref_version
            if self._n_batches >= self.config.drift_min_batches:
                if score > self.config.drift_threshold and not self._drifting:
                    self._drifting = True
                    self.drift_events += 1
                    fire = True
                elif score <= self.config.drift_threshold:
                    self._drifting = False
        if self._g_drift is not None:
            self._g_drift.set(score)
        if fire and self.bus is not None:  # outside the lock, like the guards
            self.bus.publish(
                "quality_drift", plane="serve",
                score=score, threshold=self.config.drift_threshold,
                table_version=ref_version,
            )
        return score

    # --------------------------------------------------------------- reading
    @property
    def drifting(self) -> bool:
        with self._lock:
            return self._drifting

    def drift_score(self) -> Optional[float]:
        with self._lock:
            if self._ref_mean is None or self._ew_mean is None:
                return None
            z = (self._ew_mean - self._ref_mean) * self._ref_inv_std
            return float(np.sqrt(np.mean(z * z)))

    def confidence(self) -> Optional[dict]:
        """Summary of the gateway's top-1/top-2 score-gap histogram."""
        if self._score_gap_hist is None or self._score_gap_hist.count() == 0:
            return None
        return self._score_gap_hist.summary()

    def summary(self) -> dict:
        with self._lock:
            out = {
                "ndcg": self._rolling.mean("ndcg"),
                "recall": self._rolling.mean("recall"),
                "n_labelled": self._rolling.n("ndcg"),
                "k": self.config.k,
                "drifting": self._drifting,
                "drift_events": self.drift_events,
                "n_batches": self._n_batches,
                "ref_table_version": self._ref_version,
            }
        out["drift_score"] = self.drift_score()
        out["confidence"] = self.confidence()
        return out
