"""repro-obs: render route traces (and live health surfaces) for humans.

  PYTHONPATH=src python -m repro.obs.report trace.jsonl
  repro-obs trace.jsonl                    # installed entry point
  repro-obs --health http://127.0.0.1:9100 # pretty-print a live /health

Reads the JSONL a `RouteTracer.export_jsonl` wrote (one RouteTrace per
line) and prints per-phase latency percentiles, the path/bucket mix, and
the version span of the traced traffic — the offline twin of the
`/metrics` histograms, with exact per-batch samples instead of bucket
estimates.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.obs.summary import percentile_stats

__all__ = ["render_trace_report", "main"]


def _load_jsonl(path: str) -> List[dict]:
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def render_trace_report(records: List[dict]) -> str:
    if not records:
        return "no traces\n"
    lines = [f"{len(records)} traces"]
    tvs = sorted({r["table_version"] for r in records})
    svs = sorted({r["stage_version"] for r in records})
    lines.append(
        f"table versions {tvs[0]}..{tvs[-1]} | stage versions "
        f"{svs[0]}..{svs[-1]}"
    )
    paths: Dict[str, int] = {}
    buckets: Dict[int, int] = {}
    for r in records:
        paths[r["path"]] = paths.get(r["path"], 0) + 1
        buckets[r["bucket"]] = buckets.get(r["bucket"], 0) + 1
    lines.append(
        "paths: " + ", ".join(f"{p}={n}" for p, n in sorted(paths.items()))
    )
    lines.append(
        "buckets: " + ", ".join(f"{b}={n}" for b, n in sorted(buckets.items()))
    )
    by_phase: Dict[str, List[float]] = {}
    for r in records:
        for name, ms in r["spans"].items():
            by_phase.setdefault(name, []).append(float(ms))
    by_phase["total"] = [float(r["total_ms"]) for r in records]
    lines.append(f"{'phase':10s} {'n':>6s} {'p50_ms':>9s} {'p99_ms':>9s} "
                 f"{'mean_ms':>9s}")
    for name, samples in sorted(by_phase.items()):
        s = percentile_stats(samples)
        lines.append(
            f"{name:10s} {s.n:6d} {s.p50_ms:9.3f} {s.p99_ms:9.3f} "
            f"{s.mean_ms:9.3f}"
        )
    return "\n".join(lines) + "\n"


def _render_health(url: str) -> str:
    from urllib.request import urlopen

    try:
        with urlopen(url.rstrip("/") + "/health", timeout=5) as resp:
            snap = json.loads(resp.read())
    except Exception as exc:  # includes 503 (HTTPError) — still health info
        resp = getattr(exc, "fp", None)
        if resp is None:
            return f"unreachable: {exc}\n"
        snap = json.loads(resp.read())
    return json.dumps(snap, indent=2) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("trace", nargs="?", help="JSONL file from RouteTracer.export_jsonl")
    ap.add_argument("--health", metavar="URL",
                    help="pretty-print a live ObsServer /health instead")
    args = ap.parse_args(argv)
    if args.health:
        sys.stdout.write(_render_health(args.health))
        return 0
    if not args.trace:
        ap.error("pass a trace JSONL file or --health URL")
    sys.stdout.write(render_trace_report(_load_jsonl(args.trace)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
