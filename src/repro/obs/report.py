"""repro-obs: render route traces (and live health surfaces) for humans.

  PYTHONPATH=src python -m repro.obs.report trace.jsonl
  repro-obs trace.jsonl                    # installed entry point
  repro-obs trace.jsonl --since 1754600000 # only records at/after that ts
  repro-obs --health http://127.0.0.1:9100 # pretty-print a live /health
  repro-obs --follow http://127.0.0.1:9100 # tail the live event bus
  repro-obs --watch  http://127.0.0.1:9100 # live health+SLO+exemplar panel
  repro-obs replay dumps/dump-...-slo_burn # postmortem a flight-recorder dump
  repro-obs replay dumps/                  # ...or the newest dump under a root

Reads the JSONL a `RouteTracer.export_jsonl` wrote (one RouteTrace per
line) and prints per-phase latency percentiles, the path/bucket mix, and
the version span of the traced traffic — the offline twin of the
`/metrics` histograms, with exact per-batch samples instead of bucket
estimates. Against a live `ObsServer`, ``--follow`` tails ``/events``
using the bus's monotone ``since=`` cursor (every retained event exactly
once), and ``--watch`` renders a periodic panel of ``/health`` + ``/slo``,
resolving any burning latency SLO's p99 exemplar through ``/traces?id=``
into the actual RouteTrace spans.

``replay`` is the offline postmortem surface: given a FlightRecorder dump
directory (or a dump root, where it picks the newest), it renders the
recorded timeline — bus events interleaved with sampled trace spans around
the trigger, plus the SLO/health/version state frozen at dump time
(`repro.obs.flightrec.render_replay`). It needs no live server: the dump
is self-contained, which is the point of a black box.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Callable, Dict, List, Optional

from repro.obs.summary import percentile_stats

__all__ = [
    "follow_events",
    "main",
    "render_trace_report",
    "render_watch_panel",
    "replay",
    "watch",
]


def _load_jsonl(path: str) -> List[dict]:
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def render_trace_report(records: List[dict]) -> str:
    if not records:
        return "no traces\n"
    lines = [f"{len(records)} traces"]
    tvs = sorted({r["table_version"] for r in records})
    svs = sorted({r["stage_version"] for r in records})
    lines.append(
        f"table versions {tvs[0]}..{tvs[-1]} | stage versions "
        f"{svs[0]}..{svs[-1]}"
    )
    paths: Dict[str, int] = {}
    buckets: Dict[int, int] = {}
    for r in records:
        paths[r["path"]] = paths.get(r["path"], 0) + 1
        buckets[r["bucket"]] = buckets.get(r["bucket"], 0) + 1
    lines.append(
        "paths: " + ", ".join(f"{p}={n}" for p, n in sorted(paths.items()))
    )
    lines.append(
        "buckets: " + ", ".join(f"{b}={n}" for b, n in sorted(buckets.items()))
    )
    by_phase: Dict[str, List[float]] = {}
    for r in records:
        for name, ms in r["spans"].items():
            by_phase.setdefault(name, []).append(float(ms))
    by_phase["total"] = [float(r["total_ms"]) for r in records]
    lines.append(f"{'phase':10s} {'n':>6s} {'p50_ms':>9s} {'p99_ms':>9s} "
                 f"{'mean_ms':>9s}")
    for name, samples in sorted(by_phase.items()):
        s = percentile_stats(samples)
        lines.append(
            f"{name:10s} {s.n:6d} {s.p50_ms:9.3f} {s.p99_ms:9.3f} "
            f"{s.mean_ms:9.3f}"
        )
    return "\n".join(lines) + "\n"


def _fetch_json(url: str, timeout: float = 5.0):
    from urllib.request import urlopen

    with urlopen(url, timeout=timeout) as resp:
        return json.loads(resp.read())


def _format_event(e: dict) -> str:
    extra = {k: v for k, v in e.items() if k not in ("seq", "ts", "kind", "plane")}
    detail = " ".join(f"{k}={v}" for k, v in sorted(extra.items()))
    return f"[{e['seq']:5d}] {e['plane']:8s} {e['kind']:18s} {detail}".rstrip()


def follow_events(
    url: str,
    interval: float = 1.0,
    max_polls: int = 0,
    out=None,
) -> int:
    """Tail a live ObsServer's event bus (``/events?since=``).

    The bus's monotone seq is the cursor: each poll asks only for events
    past the last seen seq, so every retained event prints exactly once.
    ``max_polls=0`` follows until interrupted (the CLI default); tests pass
    a bound. Returns the number of events printed.
    """
    out = out or sys.stdout
    base = url.rstrip("/")
    since, polls, printed = -1, 0, 0
    while True:
        try:
            evs = _fetch_json(f"{base}/events?since={since}")
        except Exception as exc:
            out.write(f"unreachable: {exc}\n")
            evs = []
        for e in evs:
            out.write(_format_event(e) + "\n")
            printed += 1
            since = max(since, int(e["seq"]))
        out.flush()
        polls += 1
        if max_polls and polls >= max_polls:
            return printed
        time.sleep(interval)


def render_watch_panel(
    health: dict,
    slo: Optional[dict],
    trace_lookup: Optional[Callable[[int], Optional[dict]]] = None,
) -> str:
    """One frame of the live panel: status line, per-SLO burn table, and
    the p99 exemplar link for latency SLOs ("your p99 bucket → this
    RouteTrace") when the tracer sampled one."""
    lines = [f"health: {health.get('status', '?')}"]
    if slo is None:
        lines.append("slo: (engine not wired)")
        return "\n".join(lines) + "\n"
    burning = slo.get("burning", [])
    lines.append(
        f"slo: {slo.get('status', '?')}"
        + (f" — burning: {', '.join(burning)}" if burning else "")
    )
    lines.append(f"{'slo':24s} {'state':8s} {'burn':>8s}  detail")
    for name, s in sorted(slo.get("slos", {}).items()):
        burn = s.get("burn")
        burn_s = f"{burn:8.2f}" if burn is not None else f"{'—':>8s}"
        if s["kind"] == "latency" and s.get("p99_ms") is not None:
            detail = f"p99={s['p99_ms']:.2f}ms vs {s['threshold_ms']:g}ms"
        else:
            detail = s.get("description", "")
        state = "BURNING" if s.get("burning") else "ok"
        lines.append(f"{name:24s} {state:8s} {burn_s}  {detail}")
        ex = s.get("p99_exemplar")
        if ex is not None:
            trace = trace_lookup(int(ex)) if trace_lookup is not None else None
            if trace is not None:
                spans = ", ".join(
                    f"{n} {ms:.2f}ms" for n, ms in trace["spans"].items()
                )
                lines.append(
                    f"{'':24s} p99 exemplar → trace #{ex} "
                    f"[{spans}] (batch={trace['batch_size']}, "
                    f"path={trace['path']}, table=v{trace['table_version']})"
                )
            else:
                lines.append(f"{'':24s} p99 exemplar → trace #{ex} "
                             f"(not retained)")
    return "\n".join(lines) + "\n"


def watch(
    url: str,
    interval: float = 2.0,
    iterations: int = 0,
    out=None,
) -> int:
    """Periodic ``/health`` + ``/slo`` panel against a live ObsServer.

    ``iterations=0`` runs until interrupted; tests pass a bound. Returns
    the number of frames rendered.
    """
    out = out or sys.stdout
    base = url.rstrip("/")
    frames = 0
    while True:
        try:
            health = _fetch_json(f"{base}/health")
        except Exception as exc:
            fp = getattr(exc, "fp", None)  # 503 still carries the snapshot
            health = json.loads(fp.read()) if fp is not None else {
                "status": f"unreachable: {exc}"
            }
        try:
            slo = _fetch_json(f"{base}/slo")
        except Exception:
            slo = None

        def _lookup(trace_id: int) -> Optional[dict]:
            try:
                return _fetch_json(f"{base}/traces?id={trace_id}")
            except Exception:
                return None

        out.write(f"== repro-obs watch @ {time.strftime('%H:%M:%S')} ==\n")
        out.write(render_watch_panel(health, slo, _lookup))
        out.flush()
        frames += 1
        if iterations and frames >= iterations:
            return frames
        time.sleep(interval)


def _render_health(url: str) -> str:
    from urllib.request import urlopen

    try:
        with urlopen(url.rstrip("/") + "/health", timeout=5) as resp:
            snap = json.loads(resp.read())
    except Exception as exc:  # includes 503 (HTTPError) — still health info
        resp = getattr(exc, "fp", None)
        if resp is None:
            return f"unreachable: {exc}\n"
        snap = json.loads(resp.read())
    return json.dumps(snap, indent=2) + "\n"


def replay(dump_path: str, window_s: float = 60.0, out=None) -> int:
    """Render a flight-recorder dump (or the newest under a dump root).

    Returns 0 on success, 2 when the path holds no readable dump.
    """
    import os

    from repro.obs.flightrec import list_dumps, render_replay

    out = out or sys.stdout
    path = dump_path.rstrip("/")
    if not os.path.exists(os.path.join(path, "manifest.json")):
        dumps = list_dumps(path)
        if not dumps:
            out.write(f"no flight dumps under {dump_path}\n")
            return 2
        out.write(f"{len(dumps)} dump(s) under {path}; replaying newest\n")
        path = dumps[-1].path
    out.write(render_replay(path, window_s=window_s))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("trace", nargs="?",
                    help="JSONL file from RouteTracer.export_jsonl, or the "
                         "literal 'replay' to postmortem a flight dump")
    ap.add_argument("dump", nargs="?",
                    help="flight-recorder dump directory (with 'replay')")
    ap.add_argument("--window", type=float, default=60.0, metavar="S",
                    help="replay timeline span before the dump (seconds)")
    ap.add_argument("--since", type=float, metavar="TS", default=None,
                    help="only report JSONL traces with ts >= TS "
                         "(wall-clock epoch seconds)")
    ap.add_argument("--health", metavar="URL",
                    help="pretty-print a live ObsServer /health instead")
    ap.add_argument("--follow", metavar="URL",
                    help="tail a live ObsServer's /events (ctrl-C to stop)")
    ap.add_argument("--watch", metavar="URL",
                    help="periodic /health + /slo panel with p99 exemplar "
                         "links (ctrl-C to stop)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="poll interval for --follow/--watch (seconds)")
    ap.add_argument("--max-polls", type=int, default=0,
                    help="stop --follow after N polls (0 = forever)")
    ap.add_argument("--iterations", type=int, default=0,
                    help="stop --watch after N frames (0 = forever)")
    args = ap.parse_args(argv)
    if args.trace == "replay":
        if not args.dump:
            ap.error("replay needs a dump directory")
        return replay(args.dump, window_s=args.window)
    if args.health:
        sys.stdout.write(_render_health(args.health))
        return 0
    if args.follow:
        try:
            follow_events(args.follow, interval=args.interval,
                          max_polls=args.max_polls)
        except KeyboardInterrupt:
            pass
        return 0
    if args.watch:
        try:
            watch(args.watch, interval=args.interval,
                  iterations=args.iterations)
        except KeyboardInterrupt:
            pass
        return 0
    if not args.trace:
        ap.error("pass a trace JSONL file, or --health/--follow/--watch URL")
    records = _load_jsonl(args.trace)
    if args.since is not None:
        records = [r for r in records if float(r.get("ts", 0.0)) >= args.since]
    sys.stdout.write(render_trace_report(records))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
