"""SLOEngine: declarative SLOs evaluated with multi-window burn rates.

The paper commits to an SLO — "all mechanisms run within single-digit
millisecond CPU budgets" (§5.5) — and PR 6 made the raw signals visible;
this module *watches* them. Each `SLO` declares an objective over signals
the `TimeSeriesRing` can window, and the engine evaluates it SRE-style:
the **burn rate** is how fast the error budget is being spent relative to
the rate that would exactly exhaust it over the SLO period (burn 1.0 =
on-budget; burn 14.4 over an hour = the 30-day budget gone in ~2 days),
and an alert requires the burn to exceed the window's ``factor`` over BOTH
the long window (evidence) and the short window (still happening) — the
classic construction that is simultaneously fast on cliffs and quiet on
blips.

Three SLI kinds cover the repo's signals:

* ``latency`` — fraction of histogram samples above ``threshold_ms``
  (exact when the threshold sits on a bucket edge; 10 ms does);
* ``ratio`` — bad/total from counter deltas (exact-fallback serving);
* ``rate`` — events per hour vs an allowed ``max_per_hour`` (guard
  rollbacks, ring drops) — for signals whose budget is "rarely", not
  "a fraction of traffic".

State transitions are events, not logs: entering breach publishes
``slo_burn`` and leaving it publishes ``slo_recovered`` on the EventBus
(at most one per transition — the bus's transitions-only discipline).
`HealthMonitor` folds `burning()` into the process status and `ObsServer`
serves `snapshot()` at ``/slo``. A windowed query that returns None
(insufficient ring data, no traffic) never alerts — an engine with two
ticks of history stays quiet rather than guessing.

For latency SLOs the snapshot carries the live histogram's p99 *exemplar*
(the most recent sampled trace id in the p99 bucket, see
`LogHistogram.record`), closing the loop from "the SLO is burning" to
"here is a RouteTrace from the offending bucket".
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from repro.obs import clock
from repro.obs.metrics import MetricsRegistry, _label_str
from repro.obs.timeseries import TimeSeriesRing

__all__ = ["SLO", "BurnWindow", "SLOEngine", "default_slos"]


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One long/short window pair with its alerting burn factor."""

    long_s: float
    short_s: float
    factor: float  # alert when burn > factor over BOTH windows


# Google SRE's two fastest pairs for a 30-day period: page on a budget
# burning in ~2 days (14.4x over 1h, confirmed over 5m) or in ~5 days
# (6x over 6h, confirmed over 30m). Smoke benches substitute second-scale
# pairs — the math is window-agnostic.
DEFAULT_WINDOWS: Tuple[BurnWindow, ...] = (
    BurnWindow(long_s=3600.0, short_s=300.0, factor=14.4),
    BurnWindow(long_s=21600.0, short_s=1800.0, factor=6.0),
)


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective over ring-windowable signals."""

    name: str
    kind: str  # "latency" | "ratio" | "rate"
    description: str = ""
    windows: Tuple[BurnWindow, ...] = DEFAULT_WINDOWS
    objective: float = 0.99  # latency/ratio: target good fraction
    # latency ---------------------------------------------------------------
    hist_key: Optional[str] = None  # histogram key in ring points
    threshold_ms: Optional[float] = None  # sample is bad above this
    # ratio -----------------------------------------------------------------
    bad_keys: Tuple[str, ...] = ()  # counters counting bad outcomes
    total_keys: Tuple[str, ...] = ()  # counters summing to the denominator
    # rate ------------------------------------------------------------------
    event_keys: Tuple[str, ...] = ()  # counters counting the events
    max_per_hour: Optional[float] = None  # allowed sustained event rate

    def __post_init__(self):
        assert self.kind in ("latency", "ratio", "rate"), self.kind
        if self.kind == "latency":
            assert self.hist_key and self.threshold_ms is not None
        elif self.kind == "ratio":
            assert self.bad_keys and self.total_keys
        else:
            assert self.event_keys and self.max_per_hour


def default_slos() -> Tuple[SLO, ...]:
    """The repo's serving objectives, over PR 6's metric catalog."""
    served = tuple(
        f'index_served_total{{path="{p}"}}' for p in ("exact", "index")
    )
    return (
        SLO(
            name="route_p99_budget",
            kind="latency",
            description="99% of route batches inside the paper's 10 ms budget",
            hist_key="route_batch_ms",
            threshold_ms=10.0,
            objective=0.99,
        ),
        SLO(
            name="exact_fallback_ratio",
            kind="ratio",
            description="fallback-serving windows (exact dense scan instead "
                        "of the built index) stay under 5% of batches",
            bad_keys=(served[0],),
            total_keys=served,
            objective=0.95,
        ),
        SLO(
            name="guard_rollback_rate",
            kind="rate",
            description="table rollbacks + stage demotions stay rare",
            event_keys=(
                'events_total{kind="rollback"}',
                'events_total{kind="demotion"}',
            ),
            max_per_hour=2.0,
        ),
        SLO(
            name="drop_rate",
            kind="rate",
            description="outcome-ring and event-bus overwrites stay rare "
                        "(a sustained rate means a stalled drainer)",
            event_keys=("route_outcomes_dropped_total", "bus_dropped_total"),
            max_per_hour=60.0,
        ),
        SLO(
            name="jit_retrace_rate",
            kind="rate",
            description="post-warmup XLA compiles on the hot path stay rare "
                        "(a sustained rate means batches escaping the "
                        "power-of-two buckets or churning generations); "
                        "counters come from obs.profile.JitProfiler.collect",
            # keys mirror repro.router.gateway.hot_path_jits() — the
            # profiler labels its counters with those names
            event_keys=(
                'jit_compiles_total{fn="topk_dense"}',
                'jit_compiles_total{fn="adapter_apply"}',
                'jit_compiles_total{fn="rerank_topk_scored"}',
            ),
            max_per_hour=12.0,
        ),
        SLO(
            name="cache_staleness",
            kind="rate",
            description="route-cache entries served from a dead snapshot "
                        "stay at zero (the gateway tripwire re-checks every "
                        "hit's (table_version, stage_version) stamps against "
                        "the live pair and demotes mismatches to misses, so "
                        "any count here means the stamp discipline broke)",
            event_keys=("route_cache_stale_served_total",),
            max_per_hour=1.0,
        ),
    )


class SLOEngine:
    """Evaluates SLOs against a TimeSeriesRing, publishing transitions.

    `evaluate()` is the single judgement entry point (the ring's ``on_tick``
    cadence, the health monitor, and the ``/slo`` endpoint all route through
    it) so `slo_burn`/`slo_recovered` fire exactly once per state change no
    matter how many surfaces poll.
    """

    def __init__(
        self,
        ring: TimeSeriesRing,
        slos: Optional[Tuple[SLO, ...]] = None,
        bus=None,
        registry: Optional[MetricsRegistry] = None,
    ):
        self.ring = ring
        self.slos: Tuple[SLO, ...] = tuple(slos) if slos is not None else default_slos()
        names = [s.name for s in self.slos]
        assert len(set(names)) == len(names), f"duplicate SLO names: {names}"
        self.bus = bus
        self.registry = registry
        self._burning: Dict[str, bool] = {s.name: False for s in self.slos}
        self._lock = threading.Lock()

    # ---------------------------------------------------------------- burn math
    def _burn(self, slo: SLO, window_s: float, now: Optional[float]) -> Optional[float]:
        """Burn rate of `slo` over one trailing window (None = no data)."""
        if slo.kind == "latency":
            wh = self.ring.window_hist(slo.hist_key, window_s, now=now)
            if wh is None:
                return None
            bad = wh.fraction_gt(slo.threshold_ms)
            if bad is None:
                return None
            return bad / max(1.0 - slo.objective, 1e-9)
        if slo.kind == "ratio":
            deltas = [self.ring.delta(k, window_s, now=now) for k in slo.total_keys]
            if all(d is None for d in deltas):
                return None
            total = sum(d for d in deltas if d is not None)
            if total <= 0:
                return None
            bad = sum(
                d for d in (self.ring.delta(k, window_s, now=now)
                            for k in slo.bad_keys)
                if d is not None
            )
            return (bad / total) / max(1.0 - slo.objective, 1e-9)
        # rate: events per hour over the actual covered span
        pair = self.ring.window(window_s, now=now)
        if pair is None:
            return None
        start, end = pair
        span = end.mono - start.mono
        if span <= 0:
            return None
        deltas = [self.ring.delta(k, window_s, now=now) for k in slo.event_keys]
        if all(d is None for d in deltas):
            return None
        events = sum(d for d in deltas if d is not None)
        per_hour = events * 3600.0 / span
        return per_hour / slo.max_per_hour

    def _latency_detail(self, slo: SLO) -> dict:
        """Live p99 + exemplar trace id for a latency SLO's histogram."""
        out: dict = {"threshold_ms": slo.threshold_ms}
        for inst in self.ring.registry.instruments():
            if inst.kind != "histogram":
                continue
            if inst.name + _label_str(inst.labels) != slo.hist_key:
                continue
            if inst.count():
                out["p99_ms"] = inst.percentile(99.0)
                ex = inst.percentile_exemplar(99.0)
                if ex is not None:
                    out["p99_exemplar"] = ex[0]
            break
        return out

    # ---------------------------------------------------------------- judging
    def evaluate(self, now: Optional[float] = None) -> dict:
        """Judge every SLO; publish transitions; return the full snapshot."""
        slos: Dict[str, dict] = {}
        transitions: List[Tuple[str, dict]] = []
        with self._lock:
            for slo in self.slos:
                windows = []
                breaching = False
                worst: Optional[float] = None
                for w in slo.windows:
                    burn_long = self._burn(slo, w.long_s, now)
                    burn_short = self._burn(slo, w.short_s, now)
                    hit = (
                        burn_long is not None
                        and burn_short is not None
                        and burn_long > w.factor
                        and burn_short > w.factor
                    )
                    breaching = breaching or hit
                    if burn_long is not None:
                        worst = burn_long if worst is None else max(worst, burn_long)
                    windows.append({
                        "long_s": w.long_s,
                        "short_s": w.short_s,
                        "factor": w.factor,
                        "burn_long": burn_long,
                        "burn_short": burn_short,
                        "breaching": hit,
                    })
                was = self._burning[slo.name]
                self._burning[slo.name] = breaching
                entry = {
                    "kind": slo.kind,
                    "description": slo.description,
                    "objective": slo.objective if slo.kind != "rate" else None,
                    "max_per_hour": slo.max_per_hour,
                    "burning": breaching,
                    "burn": worst,
                    "windows": windows,
                }
                if slo.kind == "latency":
                    entry.update(self._latency_detail(slo))
                slos[slo.name] = entry
                if breaching and not was:
                    # "sli", not "kind": the bus reserves `kind` for the
                    # event kind itself
                    details = {
                        "slo": slo.name, "sli": slo.kind, "burn": worst,
                    }
                    details.update({
                        k: entry[k] for k in ("threshold_ms", "p99_ms",
                                              "p99_exemplar")
                        if k in entry
                    })
                    transitions.append(("slo_burn", details))
                elif was and not breaching:
                    transitions.append(
                        ("slo_recovered", {"slo": slo.name, "sli": slo.kind})
                    )
                if self.registry is not None:
                    self.registry.gauge("slo_burning", slo=slo.name).set(
                        1.0 if breaching else 0.0
                    )
                    if worst is not None:
                        self.registry.gauge("slo_burn_rate", slo=slo.name).set(worst)
        # publish outside the engine lock: subscribers may read the engine
        if self.bus is not None:
            for kind, details in transitions:
                self.bus.publish(kind, plane="serve", **details)
        return {
            "status": "burning" if any(s["burning"] for s in slos.values()) else "ok",
            "burning": [n for n, s in slos.items() if s["burning"]],
            "evaluated_at": clock.wall(),
            "slos": slos,
        }

    def snapshot(self, now: Optional[float] = None) -> dict:
        """Alias for `evaluate` — every read surface judges through it."""
        return self.evaluate(now=now)

    def burning(self) -> List[str]:
        """Names currently in breach (last evaluation's state, no re-judge)."""
        with self._lock:
            return [n for n, b in self._burning.items() if b]
