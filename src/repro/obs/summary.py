"""One percentile-summary implementation for every latency consumer.

`LatencyStats` + `percentile_stats` lived in `repro.router.latency` and were
re-implemented ad hoc by the benches; they now live here (the telemetry
plane is the layer every plane already reports into) and are re-exported
from `repro.router.latency` for compatibility. `stats_from_histogram` gives
the same `LatencyStats` shape from a live `LogHistogram`, so offline exact
summaries and serve-time histogram estimates are interchangeable
downstream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

__all__ = ["LatencyStats", "percentile_stats", "stats_from_histogram"]


@dataclasses.dataclass
class LatencyStats:
    p50_ms: float
    p99_ms: float
    mean_ms: float
    n: int

    def as_dict(self) -> Dict[str, float]:
        return {
            "p50_ms": self.p50_ms,
            "p99_ms": self.p99_ms,
            "mean_ms": self.mean_ms,
            "n": self.n,
        }


def percentile_stats(samples_ms: Sequence[float]) -> LatencyStats:
    """Exact p50/p99/mean over a sample list (offline benches, harnesses)."""
    arr = np.asarray(samples_ms, dtype=np.float64)
    return LatencyStats(
        p50_ms=float(np.percentile(arr, 50)),
        p99_ms=float(np.percentile(arr, 99)),
        mean_ms=float(arr.mean()),
        n=len(arr),
    )


def stats_from_histogram(hist) -> LatencyStats:
    """`LatencyStats` estimated from a `repro.obs.metrics.LogHistogram`.

    Percentiles are bucket-resolution estimates (exact to within one
    log-spaced bucket width — the tradeoff that makes serve-time recording
    O(1) and bounded); mean is exact (the histogram tracks the true sum).
    """
    return LatencyStats(
        p50_ms=hist.percentile(50.0),
        p99_ms=hist.percentile(99.0),
        mean_ms=hist.mean(),
        n=hist.count(),
    )
