"""TimeSeriesRing: bounded in-process history for windowed metric queries.

The registry's counters and histograms are *cumulative* — perfect for a
Prometheus scrape, useless for "what was the p99 over the last minute"
without an external TSDB. This module closes that gap in-process: `tick()`
snapshots every counter value and histogram bucket vector into a bounded
ring, and windowed queries (`rate`, `delta`, `window_hist`) are computed
from the difference between the newest point and the oldest point inside
the window. Memory is bounded by ``capacity`` points regardless of uptime,
in the same spirit as `EventBus` and `OutcomeStore`.

Two-sample semantics: every windowed query needs *two* points (a start and
an end) to form a difference, so with fewer than two ticks in the window
the query returns ``None`` rather than a fabricated zero — callers (the
SLO engine) treat None as "insufficient data", which never alerts.

`start(interval_s)` runs the cadence on a daemon thread that stamps
`last_loop_error` on failure (the thread-discipline contract every daemon
loop in this repo follows); an optional ``on_tick`` hook lets the SLO
engine evaluate on the same cadence without a second thread. When a ``bus``
is attached, per-kind event counts and the bus drop counter are mirrored
into each point as synthetic counters (``events_total{kind="..."}``,
``bus_dropped_total``) so event *rates* — rollbacks per hour, drops per
hour — are windowable like any other counter.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import clock
from repro.obs.metrics import MetricsRegistry, _label_str

__all__ = ["HistPoint", "HistWindow", "TimeSeriesRing", "TsPoint"]


@dataclasses.dataclass(frozen=True)
class HistPoint:
    """Cumulative histogram state at one tick."""

    count: int
    sum: float
    buckets: np.ndarray  # cumulative per-bucket counts (len(edges) + 1)
    edges: np.ndarray


@dataclasses.dataclass(frozen=True)
class TsPoint:
    """One snapshot of the registry (+ synthetic bus counters)."""

    mono: float  # monotonic seconds (window arithmetic)
    wall: float  # epoch seconds (display)
    counters: Dict[str, float]
    gauges: Dict[str, float]
    hists: Dict[str, HistPoint]


@dataclasses.dataclass(frozen=True)
class HistWindow:
    """Histogram activity between two ticks: bucket deltas + exact count/sum.

    `quantile` interpolates inside the log-spaced buckets exactly like
    `LogHistogram.percentile`, but clamped to the nonzero bucket span (the
    window has no exact min/max — those are cumulative).
    """

    count: int
    sum: float
    buckets: np.ndarray
    edges: np.ndarray
    span_s: float  # elapsed monotonic seconds between the two ticks

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> Optional[float]:
        if self.count <= 0:
            return None
        rank = q / 100.0 * self.count
        cum = np.cumsum(self.buckets)
        i = min(int(np.searchsorted(cum, rank, side="left")),
                len(self.buckets) - 1)
        left = self.edges[i - 1] if 0 < i <= len(self.edges) else self.edges[0]
        right = self.edges[i] if i < len(self.edges) else self.edges[-1]
        prev = cum[i - 1] if i > 0 else 0
        in_bucket = self.buckets[i]
        frac = (rank - prev) / in_bucket if in_bucket else 0.0
        return float(left + (right - left) * min(max(frac, 0.0), 1.0))

    def fraction_gt(self, threshold: float) -> Optional[float]:
        """Fraction of window samples above `threshold` (the latency SLI).

        Exact when `threshold` lies on a bucket edge (the 10 ms budget does,
        on the default edges); otherwise the straddling bucket counts as
        *above* — the conservative direction for an alert.
        """
        if self.count <= 0:
            return None
        n_le = int(np.searchsorted(self.edges, threshold, side="right"))
        good = int(self.buckets[:n_le].sum())
        return float(self.count - good) / float(self.count)


class TimeSeriesRing:
    """Bounded ring of registry snapshots + windowed queries over them."""

    def __init__(
        self,
        registry: MetricsRegistry,
        bus=None,
        capacity: int = 512,
    ):
        assert capacity >= 2
        self.registry = registry
        self.bus = bus
        self.capacity = int(capacity)
        self._ring: Deque[TsPoint] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.last_loop_error: Optional[str] = None
        self.interval_s: Optional[float] = None

    # ---------------------------------------------------------------- ticking
    def tick(self, now: Optional[float] = None) -> TsPoint:
        """Snapshot every instrument (and bus counts) into one ring point.

        `now` is injectable (monotonic seconds) so tests and benches can
        drive deterministic windows without sleeping.
        """
        mono = clock.monotonic() if now is None else float(now)
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, HistPoint] = {}
        for inst in self.registry.instruments():
            key = inst.name + _label_str(inst.labels)
            if inst.kind == "counter":
                counters[key] = inst.value()
            elif inst.kind == "gauge":
                gauges[key] = inst.value()
            else:
                with inst._lock:
                    count, total = inst._count, inst._sum
                    buckets = inst._counts.copy()
                hists[key] = HistPoint(count, total, buckets, inst.edges)
        if self.bus is not None:
            for kind, n in self.bus.counts().items():
                counters[f'events_total{{kind="{kind}"}}'] = float(n)
            counters["bus_dropped_total"] = float(self.bus.dropped)
        point = TsPoint(mono, clock.wall(), counters, gauges, hists)
        with self._lock:
            self._ring.append(point)
        return point

    # ---------------------------------------------------------------- daemon
    def start(
        self,
        interval_s: float = 1.0,
        on_tick: Optional[Callable[["TimeSeriesRing"], None]] = None,
    ) -> "TimeSeriesRing":
        """Tick on a daemon thread every `interval_s`; `on_tick(self)` runs
        after each snapshot (the SLO engine's evaluation cadence)."""
        assert self._thread is None, "ring already started"
        self.interval_s = float(interval_s)
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                try:
                    self.tick()
                    if on_tick is not None:
                        on_tick(self)
                    self.last_loop_error = None
                except Exception as exc:  # noqa: BLE001 — daemon must survive
                    self.last_loop_error = f"{type(exc).__name__}: {exc}"
                self._stop.wait(self.interval_s)

        self._thread = threading.Thread(
            target=_loop, name="timeseries-ring", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, timeout_s: float = 5.0) -> None:
        """Idempotent: signals the ticker and joins with a bounded wait."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=timeout_s)
        self._thread = None

    def thread(self) -> Optional[threading.Thread]:
        """The cadence daemon (None unless started) — what the opt-in
        sampling profiler watches."""
        return self._thread

    # ---------------------------------------------------------------- reading
    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def points(self) -> List[TsPoint]:
        with self._lock:
            return list(self._ring)

    def last_point(self) -> Optional[TsPoint]:
        with self._lock:
            return self._ring[-1] if self._ring else None

    def window(
        self, seconds: float, now: Optional[float] = None
    ) -> Optional[Tuple[TsPoint, TsPoint]]:
        """(start, end) pair spanning the trailing window, or None.

        `end` is the newest point; `start` is the oldest point still inside
        the window. None when fewer than two points fall in the window —
        a single sample cannot form a rate or a quantile delta.
        """
        with self._lock:
            pts = list(self._ring)
        if not pts:
            return None
        end = pts[-1]
        cutoff = (end.mono if now is None else float(now)) - float(seconds)
        inside = [p for p in pts if p.mono >= cutoff]
        if len(inside) < 2:
            return None
        return inside[0], end

    def delta(
        self, counter_key: str, seconds: float, now: Optional[float] = None
    ) -> Optional[float]:
        """Counter increase across the window (None = insufficient data)."""
        pair = self.window(seconds, now=now)
        if pair is None:
            return None
        start, end = pair
        if counter_key not in end.counters:
            return None
        return end.counters[counter_key] - start.counters.get(counter_key, 0.0)

    def rate(
        self, counter_key: str, seconds: float, now: Optional[float] = None
    ) -> Optional[float]:
        """Counter increase per second over the *actual* covered span."""
        pair = self.window(seconds, now=now)
        if pair is None:
            return None
        start, end = pair
        span = end.mono - start.mono
        if span <= 0 or counter_key not in end.counters:
            return None
        d = end.counters[counter_key] - start.counters.get(counter_key, 0.0)
        return d / span

    def window_hist(
        self, hist_key: str, seconds: float, now: Optional[float] = None
    ) -> Optional[HistWindow]:
        """Histogram activity inside the window, as bucket-count deltas."""
        pair = self.window(seconds, now=now)
        if pair is None:
            return None
        start, end = pair
        h1 = end.hists.get(hist_key)
        if h1 is None:
            return None
        h0 = start.hists.get(hist_key)
        if h0 is None or len(h0.buckets) != len(h1.buckets):
            buckets = h1.buckets.copy()
            count, total = h1.count, h1.sum
        else:
            buckets = h1.buckets - h0.buckets
            count, total = h1.count - h0.count, h1.sum - h0.sum
        return HistWindow(
            count=int(count),
            sum=float(total),
            buckets=buckets,
            edges=h1.edges,
            span_s=end.mono - start.mono,
        )
