"""Sampled route tracing: structured per-batch span records for ~1-in-N.

Histograms answer "what is p99"; traces answer "where did *this* slow batch
spend it". The tracer samples ~1-in-N `route_batch` calls (seeded Bernoulli
sampler — deterministic for a given seed and call sequence, so tests and
replayed traffic produce identical trace sets) and records one `RouteTrace`
per sampled batch: phase spans (embed/adapter/score/rerank/assemble with
millisecond durations), the batch size and its power-of-two bucket, the
index path that served it (backend vs exact fallback), and the
(table_version, stage_version) stamp that fully determines the scores.

Traces live in a bounded ring (`dropped` counts evictions) and export as
JSONL — one object per line, streamable — rendered by `repro-obs`
(`repro.obs.report` / `scripts/obs_report.py`).
"""
from __future__ import annotations

import dataclasses
import json
import random
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.obs import clock

__all__ = ["RouteTrace", "TraceSampler", "RouteTracer"]


@dataclasses.dataclass(frozen=True)
class RouteTrace:
    trace_id: int  # tracer-unique, in sampled order
    ts: float  # wall-clock at batch entry
    batch_size: int
    bucket: int  # pow2 bucket the batch padded into
    path: str  # "index:<backend>" | "exact" — which scorer served it
    table_version: int
    stage_version: int
    spans: Tuple[Tuple[str, float], ...]  # ordered (phase, duration_ms)
    total_ms: float

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["spans"] = {name: ms for name, ms in self.spans}
        return d


class TraceSampler:
    """Seeded ~1-in-N Bernoulli sampler (deterministic per seed + sequence).

    A modulo counter would sample deterministically too, but phase-locks to
    periodic traffic (every sampled batch is the same position in a
    scheduler cycle); the seeded PRNG keeps determinism without the
    aliasing. `sample_every <= 1` samples everything (tests, debugging).
    """

    def __init__(self, sample_every: int = 64, seed: int = 0):
        self.sample_every = max(int(sample_every), 1)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def sample(self) -> bool:
        if self.sample_every == 1:
            return True
        with self._lock:  # Random() is not thread-safe under free-threading
            return self._rng.random() < 1.0 / self.sample_every


class RouteTracer:
    """Bounded ring of sampled `RouteTrace` records + JSONL export."""

    def __init__(
        self,
        sample_every: int = 64,
        capacity: int = 1024,
        seed: int = 0,
    ):
        assert capacity >= 1
        self.sampler = TraceSampler(sample_every, seed)
        self.capacity = int(capacity)
        self._ring: Deque[RouteTrace] = deque()
        self._next_id = 0
        self.dropped = 0
        self._lock = threading.Lock()

    def sample(self) -> bool:
        """Decide at batch entry; the gateway only stamps spans when True."""
        return self.sampler.sample()

    def record(
        self,
        batch_size: int,
        bucket: int,
        path: str,
        table_version: int,
        stage_version: int,
        spans: List[Tuple[str, float]],
        total_ms: float,
    ) -> RouteTrace:
        with self._lock:
            trace = RouteTrace(
                trace_id=self._next_id,
                ts=clock.wall(),
                batch_size=int(batch_size),
                bucket=int(bucket),
                path=path,
                table_version=int(table_version),
                stage_version=int(stage_version),
                spans=tuple((str(n), float(ms)) for n, ms in spans),
                total_ms=float(total_ms),
            )
            self._next_id += 1
            if len(self._ring) >= self.capacity:
                self._ring.popleft()
                self.dropped += 1
            self._ring.append(trace)
            return trace

    # --------------------------------------------------------------- reading
    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def traces(self) -> List[RouteTrace]:
        with self._lock:
            return list(self._ring)

    def get(self, trace_id: int) -> Optional[RouteTrace]:
        """Retained trace by id, or None (evicted / never sampled) — the
        lookup behind exemplar links ("your p99 bucket → this trace")."""
        with self._lock:
            for t in reversed(self._ring):
                if t.trace_id == trace_id:
                    return t
        return None

    def export_jsonl(self, path: str) -> int:
        """Write retained traces as JSONL; returns the number written."""
        traces = self.traces()
        with open(path, "w") as f:
            for t in traces:
                f.write(json.dumps(t.as_dict()) + "\n")
        return len(traces)

    def phase_summaries(self) -> Dict[str, dict]:
        """Per-phase {count, mean, p50, p99} over the retained traces —
        the exact-sample view (`repro.obs.summary.percentile_stats`) the
        `repro-obs` report renders."""
        from repro.obs.summary import percentile_stats

        by_phase: Dict[str, List[float]] = {}
        for t in self.traces():
            for name, ms in t.spans:
                by_phase.setdefault(name, []).append(ms)
        return {
            name: percentile_stats(samples).as_dict()
            for name, samples in sorted(by_phase.items())
        }
