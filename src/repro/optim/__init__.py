"""Pure-JAX pytree optimizers (no optax in the offline container).

Interface mirrors the familiar gradient-transformation style:

    opt = adamw(lr=1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from repro.optim.base import Optimizer, apply_updates, global_norm, clip_by_global_norm
from repro.optim.adamw import adam, adamw
from repro.optim.adafactor import adafactor
from repro.optim.sgd import sgd
from repro.optim.schedules import constant, cosine_decay, warmup_cosine, linear_warmup

__all__ = [
    "Optimizer",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "adam",
    "adamw",
    "adafactor",
    "sgd",
    "constant",
    "cosine_decay",
    "warmup_cosine",
    "linear_warmup",
]
