"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

Used for the very large assigned architectures (arctic-480b, dbrx-132b,
command-r-plus-104b) where full Adam state does not fit the pod's HBM; the
factored statistics cut optimizer memory from 2x params (fp32) to ~1/row+col.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, PyTree, as_schedule


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    # per-leaf: either (vr, vc) factored or (v,) full, stored as dicts
    stats: PyTree


def _should_factor(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor(
    lr,
    decay_rate: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 2,
) -> Optimizer:
    sched = as_schedule(lr)

    def _init_leaf(p):
        if _should_factor(p.shape):
            vr = jnp.zeros(p.shape[:-1], dtype=jnp.float32)  # row stats
            vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], dtype=jnp.float32)  # col stats
            return {"vr": vr, "vc": vc}
        return {"v": jnp.zeros(p.shape, dtype=jnp.float32)}

    def init(params: PyTree) -> AdafactorState:
        stats = jax.tree.map(_init_leaf, params)
        return AdafactorState(step=jnp.zeros((), jnp.int32), stats=stats)

    def update(grads: PyTree, state: AdafactorState, params: PyTree):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay_rate)
        lr_t = sched(step)

        def upd_leaf(g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                # factored preconditioner
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                precond = (
                    g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] + eps)
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                precond = g / (jnp.sqrt(v) + eps)
                new_s = {"v": v}
            # update clipping (RMS of update <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + eps)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * precond, new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state.stats)
        out = [upd_leaf(g, s) for g, s in zip(flat_g, flat_s)]
        updates = treedef.unflatten([u for u, _ in out])
        stats = treedef.unflatten([s for _, s in out])
        return updates, AdafactorState(step=step, stats=stats)

    return Optimizer(init=init, update=update)
