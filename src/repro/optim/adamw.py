"""Adam / AdamW with decoupled weight decay (Loshchilov & Hutter)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, PyTree, as_schedule


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mu_dtype=jnp.float32,
) -> Optimizer:
    sched = as_schedule(lr)

    def init(params: PyTree) -> AdamState:
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dtype), params)
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads: PyTree, state: AdamState, params: PyTree):
        step = state.step + 1
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(mu_dtype), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )

        def upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v / bc2
            u = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(jnp.float32)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)
