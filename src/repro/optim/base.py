"""Optimizer base types and pytree helpers."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A gradient transformation: (grads, state, params) -> (updates, state)."""

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), norm


def as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)
