"""SGD with (Nesterov) momentum."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, PyTree, as_schedule


class SgdState(NamedTuple):
    step: jnp.ndarray
    momentum: PyTree


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = as_schedule(lr)

    def init(params: PyTree) -> SgdState:
        m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return SgdState(step=jnp.zeros((), jnp.int32), momentum=m)

    def update(grads: PyTree, state: SgdState, params: PyTree):
        step = state.step + 1
        lr_t = sched(step)
        m = jax.tree.map(
            lambda m_, g: momentum * m_ + g.astype(jnp.float32), state.momentum, grads
        )
        if nesterov:
            upd = jax.tree.map(
                lambda m_, g: -lr_t * (momentum * m_ + g.astype(jnp.float32)), m, grads
            )
        else:
            upd = jax.tree.map(lambda m_: -lr_t * m_, m)
        return upd, SgdState(step=step, momentum=m)

    return Optimizer(init=init, update=update)
