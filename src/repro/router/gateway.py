"""SemanticRouter: the serving-plane gateway (paper Fig. 1b / Fig. 2 top).

Per request: embed the query (CPU), score against the ToolsDatabase
(similarity (+ optional lexical blend) (+ optional MLP re-rank)), attach the
top-K tools, and dispatch to a backend model pool. All learning lives in the
offline control plane (`repro.core`); this module never touches a gradient.

The router is deliberately stateless across requests (production routers are
horizontally-scaled proxies); the only mutable state is the swappable
embedding table inside ToolsDatabase and the outcome log sink.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import reranker as reranker_lib
from repro.core.features import OutcomeFeaturizer
from repro.router.tooldb import ToolsDatabase

__all__ = ["RouteResult", "OutcomeEvent", "SemanticRouter"]


@dataclasses.dataclass
class RouteResult:
    tools: List[int]  # selected tool ids (top-K)
    scores: List[float]
    latency_ms: float
    pool: str  # backend pool the request was dispatched to
    table_version: int


@dataclasses.dataclass
class OutcomeEvent:
    """A logged outcome tuple (q_j, t_i, o_j) (§4.1 step 1)."""

    query_tokens: np.ndarray
    tool_id: int
    outcome: int  # {0, 1}
    timestamp: float


class SemanticRouter:
    def __init__(
        self,
        db: ToolsDatabase,
        embed_fn: Callable[[np.ndarray], np.ndarray],  # tokens -> [384]
        k: int = 5,
        mlp_params: Optional[dict] = None,
        featurizer: Optional[OutcomeFeaturizer] = None,
        candidate_multiplier: int = 5,
        pool_selector: Optional[Callable[[np.ndarray, List[int]], str]] = None,
    ):
        self.db = db
        self.embed_fn = embed_fn
        self.k = k
        self.mlp_params = mlp_params
        self.featurizer = featurizer
        self.candidate_multiplier = candidate_multiplier
        self.pool_selector = pool_selector or (lambda q, tools: "default")
        self.outcome_log: List[OutcomeEvent] = []

    # ---------------------------------------------------------- serving path
    def route(self, query_tokens: np.ndarray) -> RouteResult:
        t0 = time.perf_counter()
        q = self.embed_fn(query_tokens)  # [384]
        table = self.db.embeddings
        sims = table @ q  # [T]
        if self.mlp_params is not None and self.featurizer is not None:
            c = min(self.k * self.candidate_multiplier, len(self.db))
            order = np.argpartition(-sims, c - 1)[:c]
            order = order[np.argsort(-sims[order], kind="stable")]
            feats = self.featurizer.features(
                q[None], [query_tokens], order[None], sims[order][None]
            )
            top = np.asarray(
                reranker_lib.rerank_topk(
                    self.mlp_params, jnp.asarray(feats), jnp.asarray(order[None]), self.k
                )
            )[0]
        else:
            top = np.argpartition(-sims, min(self.k, len(sims) - 1))[: self.k]
            top = top[np.argsort(-sims[top], kind="stable")]
        latency_ms = (time.perf_counter() - t0) * 1e3
        pool = self.pool_selector(q, [int(t) for t in top])
        return RouteResult(
            tools=[int(t) for t in top],
            scores=[float(sims[t]) for t in top],
            latency_ms=latency_ms,
            pool=pool,
            table_version=self.db.table_version,
        )

    # ------------------------------------------------------------ feedback
    def record_outcome(self, query_tokens: np.ndarray, tool_id: int, outcome: int):
        self.outcome_log.append(
            OutcomeEvent(
                query_tokens=query_tokens,
                tool_id=tool_id,
                outcome=int(outcome),
                timestamp=time.time(),
            )
        )

    def drain_outcomes(self) -> List[OutcomeEvent]:
        """Hand the accumulated log to the offline refinement job."""
        log, self.outcome_log = self.outcome_log, []
        return log
