"""SemanticRouter: the serving-plane gateway (paper Fig. 1b / Fig. 2 top).

Per request: embed the query (CPU), score against the ToolsDatabase
(similarity (+ optional lexical blend) (+ optional MLP re-rank)), attach the
top-K tools, and dispatch to a backend model pool. All learning lives in the
offline control plane (`repro.core`); this module never touches a gradient.

The router is deliberately stateless across requests (production routers are
horizontally-scaled proxies); the mutable state is the swappable embedding
table inside ToolsDatabase, a version-keyed device-side cache of that table
(pure derived state, rebuilt from any snapshot), and the outcome sink.

Outcome handoff: `record_outcome` either pushes each `OutcomeEvent` straight
into an external sink (`outcome_sink=`, typically
`repro.control.OutcomeStore.append` — the control plane's bounded store)
or, with no sink configured, appends to a *bounded, lock-guarded* in-process
buffer that `drain_outcomes()` hands to the refinement job. The buffer is a
ring: an undrained router overwrites its oldest events rather than growing
without limit (`outcomes_dropped` counts the overwrites), and both record
and drain take the same lock, so a drain racing batched serving can never
lose an event. The control plane's `RefinementController` drains attached
routers on every step.

Serving is batch-first: `route_batch` embeds, scores, and top-Ks Q queries
in ONE batched scorer call (plus one batched `rerank_topk_scored` call
when the Stage-2 MLP is enabled), amortizing dispatch overhead across the
whole batch — the hot-path design the paper's single-digit-millisecond
budget assumes at production traffic. `route` is the batch-of-1 special
case and delegates, so batched and sequential serving are equivalent by
construction. `RouteResult.scores` always holds the scores that produced
the final ranking: exact similarities of the reported `table_version` on
every backend's path, f_phi MLP scores when the re-ranker reordered the
candidates.

Scoring itself is pluggable (PR 3): the router delegates to a
`repro.index.ToolIndexManager`, which serves the configured backend
(`dense` exact matmul — the default, numerically the PR 1 path — `ivf`
coarse-quantized candidates + exact re-rank for MCP-registry-scale tables,
or `pallas` fused kernel on TPU) and falls back to exact dense scoring on
the live snapshot whenever the index is stale (mid-rebuild after a
control-plane `swap_table`/`rollback`) or the batch carries candidate masks
the backend cannot honor. The swap/rollback protocol is untouched: scores
and `table_version` always come from the same atomic snapshot.

Learned stages are hot-swappable (PR 4): the adapter head and the Stage-2
re-ranker live in one immutable `StageSet` behind a version counter with
the exact discipline the table has. `route_batch` reads ONE stage snapshot
at entry (the adapter is applied to the query block before the index
backend scores — query-side only, so promotions never invalidate a built
index — and the re-ranker params come from the same snapshot), so an
in-flight batch finishes on the stages it started with even while the
learning plane promotes or demotes mid-batch. `set_stages` is
compare-and-swap (ConflictError on a lost race), superseded sets are
retained in a bounded history, and `rollback_stages` restores one — the
learning plane's `StageGuard` demotion hinge. `RouteResult.stage_version`
reports the snapshot that produced the scores, next to `table_version`.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque
from typing import Callable, Deque, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.common.bucketing import pad_amount
from repro.core import reranker as reranker_lib
from repro.core.features import OutcomeFeaturizer
from repro.core.retrieval import NEG_INF
from repro.index import ToolIndexManager
from repro.obs import clock
from repro.obs.metrics import MetricsRegistry, get_registry
from repro.router.stages import StageSet
from repro.router.tooldb import ConflictError, ToolsDatabase

__all__ = [
    "RouteResult",
    "OutcomeEvent",
    "SemanticRouter",
    "StageSet",
    "hot_path_jits",
]

PHASES = ("embed", "cache", "adapter", "score", "rerank", "assemble")


def hot_path_jits() -> "OrderedDict[str, Callable]":
    """The jitted entry points `route_batch` dispatches to, by name.

    This is the single registry of "programs whose compile behavior is a
    serving concern": `analysis.retrace.hot_path_monitor` (the CI leg) and
    `obs.profile.JitProfiler` (the live compile/cost telemetry) both source
    from it, so adding a jit to the hot path automatically puts it under
    both the offline invariant and the production counters.
    """
    from repro.core import retrieval
    from repro.router import stages as stages_mod

    return OrderedDict(
        (
            ("topk_dense", retrieval.topk_dense),
            ("adapter_apply", stages_mod._adapter_apply_j),
            ("rerank_topk_scored", reranker_lib.rerank_topk_scored),
        )
    )


class _GatewayInstruments:
    """The gateway's metric handles, resolved once at construction.

    Instrument lookup is a dict hit in MetricsRegistry but still costs a
    lock; the hot path must touch preresolved objects only. Catalog:
    `repro.obs` package docstring."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.requests = registry.counter("route_requests_total")
        self.batches = registry.counter("route_batches_total")
        self.batch_ms = registry.histogram("route_batch_ms")
        self.batch_size = registry.histogram("route_batch_size")
        self.phase = {
            name: registry.histogram("route_phase_ms", phase=name)
            for name in PHASES
        }
        self.table_version = registry.gauge("route_table_version")
        self.stage_version = registry.gauge("route_stage_version")
        self.outcomes_dropped = registry.counter("route_outcomes_dropped_total")
        # top-1/top-2 score gap per query (routing confidence; a collapsing
        # gap means the router is guessing) — recorded via record_many, one
        # vectorized pass per batch, so per-query cost stays O(1/batch)
        self.score_gap = registry.histogram("route_score_gap")
        # tripwire: cache entries whose version stamps failed the gateway's
        # independent re-check against the live pair. Such entries are
        # demoted to misses (never served), so any non-zero value means a
        # cache bug was caught — the cache_staleness SLO holds this at 0.
        self.cache_stale = registry.counter("route_cache_stale_served_total")


@dataclasses.dataclass
class RouteResult:
    tools: List[int]  # selected tool ids (top-K)
    scores: List[float]  # the scores the final ranking was computed from
    latency_ms: float  # per-query share of the (possibly batched) route call
    pool: str  # backend pool the request was dispatched to
    table_version: int
    # version of the StageSet snapshot that scored this batch: together with
    # table_version it fully determines the scores (the learning plane's
    # StageGuard keys its shadow windows on it)
    stage_version: int = 0
    # True when this result was served from the SemanticRouteCache (its
    # tools/scores were computed by an earlier batch under the SAME
    # (table_version, stage_version) pair reported above)
    cache_hit: bool = False


@dataclasses.dataclass
class OutcomeEvent:
    """A logged outcome tuple (q_j, t_i, o_j) (§4.1 step 1)."""

    query_tokens: np.ndarray
    tool_id: int
    outcome: int  # {0, 1}
    timestamp: float


class SemanticRouter:
    def __init__(
        self,
        db: ToolsDatabase,
        embed_fn: Callable[[np.ndarray], np.ndarray],  # tokens -> [384]
        k: int = 5,
        mlp_params: Optional[dict] = None,
        featurizer: Optional[OutcomeFeaturizer] = None,
        candidate_multiplier: int = 5,
        pool_selector: Optional[Callable[[np.ndarray, List[int]], str]] = None,
        embed_batch_fn: Optional[Callable[[Sequence[np.ndarray]], np.ndarray]] = None,
        outcome_capacity: int = 65_536,
        outcome_sink: Optional[Callable[["OutcomeEvent"], None]] = None,
        index: Optional[ToolIndexManager] = None,
        backend: str = "dense",
        backend_opts: Optional[dict] = None,
        stages: Optional[StageSet] = None,
        stage_history_limit: int = 4,
        metrics: Union[MetricsRegistry, bool, None] = None,
        tracer: Optional["RouteTracer"] = None,  # repro.obs.trace
        bus: Optional["EventBus"] = None,  # repro.obs.events
        quality: Optional["QualityMonitor"] = None,  # repro.obs.quality
        cache: Optional["SemanticRouteCache"] = None,  # repro.cache
    ):
        self.db = db
        self.embed_fn = embed_fn
        self.k = k
        # learned stages live in one immutable snapshot behind a version
        # counter (the table discipline applied to the adapter/re-ranker):
        # constructor args mlp_params/featurizer seed the initial set for
        # backwards compatibility with pre-learning-plane callers
        assert stage_history_limit >= 1
        if stages is None:
            stages = StageSet(mlp_params=mlp_params, featurizer=featurizer)
        else:
            assert mlp_params is None and featurizer is None, (
                "pass learned stages either via stages= or via "
                "mlp_params=/featurizer=, not both"
            )
        self._stages = stages
        self._stage_version = 0
        self._stage_history: "OrderedDict[int, StageSet]" = OrderedDict()
        self._stage_history_limit = int(stage_history_limit)
        self._stage_lock = threading.Lock()
        self.candidate_multiplier = candidate_multiplier
        self.pool_selector = pool_selector or (lambda q, tools: "default")
        # batched encoder (one call for Q queries); falls back to looping
        # embed_fn so any single-query encoder still works batch-first
        self.embed_batch_fn = embed_batch_fn
        # bounded ring: record under lock, drain under the same lock — the
        # discipline ToolsDatabase uses for its table (a lock-free list drops
        # events when a drain races batched serving). `outcome_sink` bypasses
        # the ring entirely: events go straight to the control-plane store.
        self.outcome_log: Deque[OutcomeEvent] = deque()
        assert outcome_capacity >= 1, "outcome_capacity must be >= 1"
        self.outcome_capacity = int(outcome_capacity)
        self.outcomes_dropped = 0
        self.outcome_sink = outcome_sink
        self._outcome_lock = threading.Lock()
        # the scoring layer: a shared ToolIndexManager, or one owned by this
        # router built from (backend, backend_opts) — "dense" is the PR 1
        # jitted topk_dense path, numerics unchanged
        self._owns_index = index is None
        # an owned manager inherits this router's bus at construction so its
        # very first build publishes rebuild events (attaching a bus after
        # the fact races the constructor's async build thread); a shared
        # manager keeps whatever bus its creator wired
        self.index = index if index is not None else ToolIndexManager(
            db, backend=backend, backend_opts=backend_opts, bus=bus
        )
        # telemetry: metrics default ON against the process registry
        # (`benchmarks/obs_bench.py` bounds the cost in CI at <5 % of bare
        # qps); `metrics=False` is the truly bare hot path the bench
        # compares against. Instruments are resolved once here so
        # `route_batch` never takes the registry lock.
        if metrics is False:
            self._obs: Optional[_GatewayInstruments] = None
        else:
            registry = metrics if isinstance(metrics, MetricsRegistry) else get_registry()
            self._obs = _GatewayInstruments(registry)
        self._tracer = tracer
        self._gap_tick = 0  # score-gap 1-in-4 batch sampling counter
        self._bus = bus
        # streaming quality observability (repro.obs.quality): route_batch
        # feeds it raw query embeddings for label-free drift detection
        self._quality = quality
        # near-duplicate route cache (repro.cache): probed after embed
        # (keys are embedding-space), so a hit skips the index backend and
        # the Stage-2 re-ranker for its row. Wire `cache.watch(bus)` at the
        # launcher for eager invalidation on swap/stage_swap events.
        self._cache = cache

    @property
    def cache(self):
        """The attached SemanticRouteCache, if any (read-only view for
        health surfaces and launch summaries)."""
        return self._cache

    def close(self) -> None:
        """Tear down a retiring router (idempotent).

        Unregisters the router-owned index manager from the database's swap
        listeners — without this, a discarded router over a long-lived
        ToolsDatabase keeps rebuilding its index (and pinning its table
        copies) on every future swap. A shared manager passed via `index=`
        is left alone: its lifecycle belongs to the caller.
        """
        if self._owns_index:
            self.index.close()

    # --------------------------------------------------------- learned stages
    @property
    def mlp_params(self) -> Optional[dict]:
        """Live re-ranker params (read-only view of the current StageSet)."""
        return self._stages.mlp_params

    @property
    def featurizer(self) -> Optional[OutcomeFeaturizer]:
        return self._stages.featurizer

    @property
    def stage_version(self) -> int:
        return self._stage_version

    def stage_set(self) -> Tuple[int, StageSet]:
        """(version, StageSet) read atomically w.r.t. promotions — the
        stage-side analogue of `ToolsDatabase.snapshot()`."""
        with self._stage_lock:
            return self._stage_version, self._stages

    def set_stages(
        self, stages: StageSet, expect_version: Optional[int] = None
    ) -> int:
        """Atomically deploy a new StageSet (returns the new version).

        The outgoing set is retained as a demotion target (bounded history,
        oldest evicted first). `expect_version` makes activation
        compare-and-swap: a promotion gated against stage version N is
        refused (ConflictError) if another deployment landed past N while it
        was being trained — mirroring `swap_table(expect_current=...)`.
        """
        with self._stage_lock:
            if expect_version is not None and self._stage_version != expect_version:
                raise ConflictError(
                    f"stages are v{self._stage_version}, not v{expect_version} "
                    f"the promotion was gated against; refusing activation"
                )
            self._stage_history[self._stage_version] = self._stages
            while len(self._stage_history) > self._stage_history_limit:
                self._stage_history.popitem(last=False)
            self._stages = stages
            self._stage_version += 1
            version = self._stage_version
        # publish outside the stage lock: subscribers must never be able to
        # stall a promotion racing the serving path's stage_set() read
        if self._bus is not None:
            self._bus.publish("stage_swap", plane="learn", version=version)
        return version

    def retained_stage_versions(self) -> List[int]:
        """Stage versions available as demotion targets, oldest first."""
        with self._stage_lock:
            return list(self._stage_history.keys())

    def rollback_stages(
        self,
        to_version: Optional[int] = None,
        expect_current: Optional[int] = None,
    ) -> int:
        """Instant demotion to a retained StageSet (returns the new version).

        Same semantics as `ToolsDatabase.rollback`: the restore is itself a
        version bump, the condemned set is not retained, retained sets newer
        than the target are dropped, and `expect_current` refuses
        (ConflictError) when another promotion landed after the caller
        judged `expect_current` — the StageGuard's safety hinge.
        """
        with self._stage_lock:
            if expect_current is not None and self._stage_version != expect_current:
                raise ConflictError(
                    f"stages are v{self._stage_version}, not the judged "
                    f"v{expect_current}; refusing demotion"
                )
            if not self._stage_history:
                raise RuntimeError("no previous stage set to roll back to")
            if to_version is None:
                to_version = next(reversed(self._stage_history))
            if to_version not in self._stage_history:
                raise RuntimeError(
                    f"stage version {to_version} not retained "
                    f"(available: {list(self._stage_history.keys())})"
                )
            stages = self._stage_history.pop(to_version)
            for v in [v for v in self._stage_history if v > to_version]:
                del self._stage_history[v]
            self._stages = stages
            self._stage_version += 1
            version = self._stage_version
        if self._bus is not None:
            self._bus.publish(
                "stage_swap", plane="learn", version=version,
                restored_version=to_version,
            )
        return version

    # ---------------------------------------------------------- serving path
    def _embed_batch(self, queries: Sequence[np.ndarray]) -> np.ndarray:
        if self.embed_batch_fn is not None:
            return np.asarray(self.embed_batch_fn(queries), dtype=np.float32)
        return np.stack([np.asarray(self.embed_fn(q), np.float32) for q in queries])

    def route_batch(
        self,
        queries: Sequence[np.ndarray],
        candidate_masks: Optional[np.ndarray] = None,  # [Q, T] {0,1} or None
    ) -> List[RouteResult]:
        """Route Q queries in one batched scoring pass.

        One batched index call (the configured `ScorerBackend`; exact jitted
        dense by default) scores the whole [Q, D] query block against the
        [T, D] table (with optional per-query candidate masks); when the
        Stage-2 MLP is configured, featurization and `rerank_topk_scored`
        also run over the full batch. Returns one RouteResult per query, in
        input order; each carries the per-query amortized latency. A
        candidate mask admitting fewer than k tools yields a correspondingly
        shorter tools/scores list (never masked-out ids).
        """
        t0 = clock.perf()
        n_q = len(queries)
        if n_q == 0:
            return []
        # ONE stage snapshot per batch: a promotion/demotion landing mid-call
        # cannot mix stage configurations within the batch, and the reported
        # stage_version is the set that actually produced the scores
        stage_version, stages = self.stage_set()
        obs = self._obs
        tracing = self._tracer is not None and self._tracer.sample()
        timed = tracing or obs is not None
        q = self._embed_batch(queries)  # [Q, D]
        t_embed = clock.perf() if timed else 0.0
        # cache probe (repro.cache): keys are embedding-space, so it runs
        # after embed and before everything a hit row gets to skip (index
        # backend + Stage-2 re-ranker). Masked batches bypass the cache
        # entirely — a cached decision computed without a mask must never
        # answer a masked request. Lookups are judged against the live pair
        # (db.table_version is the documented racy int read; every served
        # entry's stamps are re-verified below) and probe with raw
        # pre-adapter embeddings, so the stage_version stamp covers adapter
        # promotions too.
        cache = self._cache
        use_cache = cache is not None and candidate_masks is None
        if use_cache:
            tv_live = self.db.table_version
            cached = cache.lookup_batch(
                q, table_version=tv_live, stage_version=stage_version
            )
            # tripwire, independent of the cache's own stamp check: any
            # entry whose versions differ from the live pair is demoted to
            # a miss (never served) and counted —
            # route_cache_stale_served_total must stay 0 (cache_staleness
            # SLO; benchmarks/cache_bench.py gates it in CI)
            stale = 0
            for j, e in enumerate(cached):
                if e is not None and (
                    e.table_version != tv_live
                    or e.stage_version != stage_version
                ):
                    cached[j] = None
                    stale += 1
            if stale and obs is not None:
                obs.cache_stale.inc(stale)
            miss_idx = [j for j, e in enumerate(cached) if e is None]
        else:
            cached = []
            miss_idx = list(range(n_q))
        t_cache = clock.perf() if timed else 0.0
        n_miss = len(miss_idx)
        # swap_table asserts the table shape is invariant, so the tool count
        # is stable across versions and safe to read without a snapshot
        n_t = len(self.db)
        rerank = stages.has_reranker
        c = min(self.k * self.candidate_multiplier, n_t) if rerank else min(self.k, n_t)
        k_eff = min(self.k, c)  # tables smaller than k yield short results
        if n_miss:
            # the scoring path sees only the miss rows: a mostly-hit batch
            # pays the index backend and re-ranker for its misses alone
            if n_miss == n_q:
                q_miss, queries_miss, masks_miss = q, queries, candidate_masks
            else:
                q_miss = q[miss_idx]
                queries_miss = [queries[j] for j in miss_idx]
                masks_miss = None  # masked batches never reach this branch
            # pad the miss block up to a power-of-two bucket so the jitted
            # scoring programs compile once per bucket, not once per
            # distinct Q (the scheduler's admission batches vary with free
            # slots; a retrace is a multi-ms stall against the 10 ms
            # budget). Pad rows are zero queries whose results are sliced
            # away below.
            n_pad = pad_amount(n_miss)
            if n_pad:
                q_in = np.concatenate(
                    [q_miss, np.zeros((n_pad, q.shape[1]), np.float32)]
                )
                queries_in = list(queries_miss) + [np.zeros(0, np.int64)] * n_pad
                masks_in = None if masks_miss is None else np.concatenate(
                    [masks_miss, np.ones((n_pad, n_t), masks_miss.dtype)]
                )
            else:
                q_in, queries_in, masks_in = q_miss, queries_miss, masks_miss
            # adapter head (query-side only) runs BEFORE the index backend —
            # the tool table is untouched, so any built IVF/Pallas index
            # stays valid across adapter promotions — and on the PADDED
            # block, so the jitted head compiles once per power-of-two
            # bucket like the scoring path (a retrace per distinct Q is a
            # multi-ms stall against the budget). pool_selector below keeps
            # seeing the raw encoder embedding `q`: pool affinity must not
            # flip on stage promotions/demotions.
            q_in = stages.adapt_queries(q_in)
            t_adapter = clock.perf() if timed else 0.0
            # the index layer scores the batch against an atomic
            # (version, table) snapshot — the reported table_version and
            # the scores come from the SAME table even if swap_table lands
            # mid-batch, whichever backend (or the exact mid-rebuild
            # fallback) served it
            cand_scores_np, cand_idx_np, table_version = self.index.topk(
                q_in, c, masks_in
            )
            t_score = clock.perf() if timed else 0.0
            if rerank:
                feats = stages.featurizer.features(q_in, queries_in, cand_idx_np, cand_scores_np)
                top_idx, top_scores = reranker_lib.rerank_topk_scored(
                    stages.mlp_params,
                    jnp.asarray(feats),
                    jnp.asarray(cand_idx_np),
                    k_eff,
                    valid=jnp.asarray(cand_scores_np > NEG_INF / 2),
                )
            else:
                top_idx, top_scores = cand_idx_np[:, :k_eff], cand_scores_np[:, :k_eff]
            top_idx = np.asarray(top_idx)[:n_miss]
            top_scores = np.asarray(top_scores)[:n_miss]
        else:
            # every row hit: the adapter, index backend, and re-ranker are
            # all skipped, and the batch reports the live pair the hits
            # were verified against
            t_adapter = t_score = t_cache
            table_version = tv_live
            top_idx = np.zeros((0, k_eff), np.int64)
            top_scores = np.zeros((0, k_eff), np.float32)
        t_rank = clock.perf()
        latency_ms = (t_rank - t0) * 1e3 / n_q
        # a mask can leave fewer than k candidates; those slots carry the
        # NEG_INF sentinel and must not surface as selected tools
        miss_tools: List[List[int]] = []
        miss_scores: List[List[float]] = []
        for m in range(n_miss):
            valid_m = top_scores[m] > NEG_INF / 2
            miss_tools.append([int(t) for t in top_idx[m][valid_m]])
            miss_scores.append([float(s) for s in top_scores[m][valid_m]])
        if use_cache and n_miss:
            # fresh decisions enter the cache stamped with the versions
            # that actually produced them: the topk snapshot's
            # table_version plus the batch's stage snapshot — NOT tv_live,
            # which a mid-batch swap may already have left behind
            cache.insert_batch(
                q_miss, miss_tools, miss_scores,
                table_version=table_version, stage_version=stage_version,
            )
        out = []
        m = 0
        for j in range(n_q):
            e = cached[j] if use_cache else None
            if e is not None:
                tools, scores = list(e.tools), list(e.scores)
                tv_j, hit = e.table_version, True
            else:
                tools, scores = miss_tools[m], miss_scores[m]
                tv_j, hit = table_version, False
                m += 1
            out.append(
                RouteResult(
                    tools=tools,
                    scores=scores,
                    latency_ms=latency_ms,
                    pool=self.pool_selector(q[j], tools),
                    table_version=tv_j,
                    stage_version=stage_version,
                    cache_hit=hit,
                )
            )
        if timed:
            t_done = clock.perf()
            # spans exist only for work that actually ran: the cache span
            # only when a cache is attached, adapter/score only when misses
            # reached the index, the rerank span only when the Stage-2 MLP
            # actually ran — recording ~0 ms slice-only "reranks" (or
            # all-hit "scores") would poison the p50
            spans = [("embed", (t_embed - t0) * 1e3)]
            if use_cache:
                spans.append(("cache", (t_cache - t_embed) * 1e3))
            if n_miss:
                spans.append(("adapter", (t_adapter - t_cache) * 1e3))
                spans.append(("score", (t_score - t_adapter) * 1e3))
                if rerank:
                    spans.append(("rerank", (t_rank - t_score) * 1e3))
            spans.append(("assemble", (t_done - t_rank) * 1e3))
            total_ms = (t_done - t0) * 1e3
            # trace BEFORE metrics: a sampled batch's trace id becomes the
            # exemplar on the duration buckets it lands in, so a p99 reading
            # links straight to a concrete RouteTrace ("/slo" and
            # `repro-obs watch` render that link)
            trace = None
            if tracing:
                trace = self._tracer.record(
                    batch_size=n_q,
                    # the bucket is what the jitted programs compiled for:
                    # the padded MISS block (an all-hit batch never reached
                    # them and reports bucket 0 under path "cache")
                    bucket=(n_miss + n_pad) if n_miss else 0,
                    path="cache" if not n_miss else self.index.last_path(),
                    table_version=table_version,
                    stage_version=stage_version,
                    spans=spans,
                    total_ms=total_ms,
                )
            if obs is not None:
                exemplar = trace.trace_id if trace is not None else None
                obs.requests.inc(n_q)
                obs.batches.inc()
                obs.batch_size.record(float(n_q))
                obs.batch_ms.record(total_ms, exemplar=exemplar)
                phase = obs.phase
                for name, ms in spans:
                    phase[name].record(ms, exemplar=exemplar)
                obs.table_version.set(table_version)
                obs.stage_version.set(stage_version)
                if top_scores.shape[1] >= 2:
                    # sampled 1-in-4 batches: the gap histogram feeds
                    # percentile summaries (confidence()), which a quarter
                    # of the traffic estimates as well as all of it — and
                    # this is the priciest per-batch obs block (a vectorized
                    # pass + record_many). Racy tick increment is fine: the
                    # sampling needs to be approximate, not exact.
                    self._gap_tick += 1
                    if self._gap_tick % 4 == 0:
                        # rows with < 2 valid candidates carry the NEG_INF
                        # sentinel in slot 1 and are skipped
                        valid2 = top_scores[:, 1] > NEG_INF / 2
                        if np.any(valid2):
                            gaps = top_scores[:, 0] - top_scores[:, 1]
                            obs.score_gap.record_many(gaps[valid2])
        if self._quality is not None:
            # raw pre-adapter embeddings, unpadded rows: drift is about the
            # query population vs the live table, not about learned stages
            self._quality.observe_queries(q)
        return out

    def route(
        self,
        query_tokens: np.ndarray,
        candidate_mask: Optional[np.ndarray] = None,  # [T] {0,1} or None
    ) -> RouteResult:
        """Single-query routing: the batch-of-1 case of `route_batch`."""
        masks = None if candidate_mask is None else np.asarray(candidate_mask)[None]
        return self.route_batch([query_tokens], masks)[0]

    # ------------------------------------------------------------ feedback
    def record_outcome(self, query_tokens: np.ndarray, tool_id: int, outcome: int):
        event = OutcomeEvent(
            query_tokens=query_tokens,
            tool_id=tool_id,
            outcome=int(outcome),
            timestamp=clock.wall(),
        )
        if self.outcome_sink is not None:
            self.outcome_sink(event)
            return
        n_dropped = 0
        with self._outcome_lock:
            if len(self.outcome_log) >= self.outcome_capacity:
                self.outcome_log.popleft()
                self.outcomes_dropped += 1
                n_dropped = self.outcomes_dropped
            self.outcome_log.append(event)
        if n_dropped:
            # counter + bus outside the ring lock: telemetry must not extend
            # the record/drain critical section
            if self._obs is not None:
                self._obs.outcomes_dropped.inc()
            if self._bus is not None and n_dropped == 1:
                self._bus.publish("outcomes_dropping", plane="serve",
                                  dropped=n_dropped)

    def drain_outcomes(self) -> List[OutcomeEvent]:
        """Hand the accumulated log to the offline refinement job."""
        with self._outcome_lock:
            log = list(self.outcome_log)
            self.outcome_log.clear()
        return log
