"""SemanticRouter: the serving-plane gateway (paper Fig. 1b / Fig. 2 top).

Per request: embed the query (CPU), score against the ToolsDatabase
(similarity (+ optional lexical blend) (+ optional MLP re-rank)), attach the
top-K tools, and dispatch to a backend model pool. All learning lives in the
offline control plane (`repro.core`); this module never touches a gradient.

The router is deliberately stateless across requests (production routers are
horizontally-scaled proxies); the mutable state is the swappable embedding
table inside ToolsDatabase, a version-keyed device-side cache of that table
(pure derived state, rebuilt from any snapshot), and the outcome sink.

Outcome handoff: `record_outcome` either pushes each `OutcomeEvent` straight
into an external sink (`outcome_sink=`, typically
`repro.control.OutcomeStore.append` — the control plane's bounded store)
or, with no sink configured, appends to a *bounded, lock-guarded* in-process
buffer that `drain_outcomes()` hands to the refinement job. The buffer is a
ring: an undrained router overwrites its oldest events rather than growing
without limit (`outcomes_dropped` counts the overwrites), and both record
and drain take the same lock, so a drain racing batched serving can never
lose an event. The control plane's `RefinementController` drains attached
routers on every step.

Serving is batch-first: `route_batch` embeds, scores, and top-Ks Q queries
in ONE batched scorer call (plus one batched `rerank_topk_scored` call
when the Stage-2 MLP is enabled), amortizing dispatch overhead across the
whole batch — the hot-path design the paper's single-digit-millisecond
budget assumes at production traffic. `route` is the batch-of-1 special
case and delegates, so batched and sequential serving are equivalent by
construction. `RouteResult.scores` always holds the scores that produced
the final ranking: exact similarities of the reported `table_version` on
every backend's path, f_phi MLP scores when the re-ranker reordered the
candidates.

Scoring itself is pluggable (PR 3): the router delegates to a
`repro.index.ToolIndexManager`, which serves the configured backend
(`dense` exact matmul — the default, numerically the PR 1 path — `ivf`
coarse-quantized candidates + exact re-rank for MCP-registry-scale tables,
or `pallas` fused kernel on TPU) and falls back to exact dense scoring on
the live snapshot whenever the index is stale (mid-rebuild after a
control-plane `swap_table`/`rollback`) or the batch carries candidate masks
the backend cannot honor. The swap/rollback protocol is untouched: scores
and `table_version` always come from the same atomic snapshot.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import reranker as reranker_lib
from repro.core.features import OutcomeFeaturizer
from repro.core.retrieval import NEG_INF
from repro.index import ToolIndexManager
from repro.router.tooldb import ToolsDatabase

__all__ = ["RouteResult", "OutcomeEvent", "SemanticRouter"]


@dataclasses.dataclass
class RouteResult:
    tools: List[int]  # selected tool ids (top-K)
    scores: List[float]  # the scores the final ranking was computed from
    latency_ms: float  # per-query share of the (possibly batched) route call
    pool: str  # backend pool the request was dispatched to
    table_version: int


@dataclasses.dataclass
class OutcomeEvent:
    """A logged outcome tuple (q_j, t_i, o_j) (§4.1 step 1)."""

    query_tokens: np.ndarray
    tool_id: int
    outcome: int  # {0, 1}
    timestamp: float


class SemanticRouter:
    def __init__(
        self,
        db: ToolsDatabase,
        embed_fn: Callable[[np.ndarray], np.ndarray],  # tokens -> [384]
        k: int = 5,
        mlp_params: Optional[dict] = None,
        featurizer: Optional[OutcomeFeaturizer] = None,
        candidate_multiplier: int = 5,
        pool_selector: Optional[Callable[[np.ndarray, List[int]], str]] = None,
        embed_batch_fn: Optional[Callable[[Sequence[np.ndarray]], np.ndarray]] = None,
        outcome_capacity: int = 65_536,
        outcome_sink: Optional[Callable[["OutcomeEvent"], None]] = None,
        index: Optional[ToolIndexManager] = None,
        backend: str = "dense",
        backend_opts: Optional[dict] = None,
    ):
        self.db = db
        self.embed_fn = embed_fn
        self.k = k
        self.mlp_params = mlp_params
        self.featurizer = featurizer
        self.candidate_multiplier = candidate_multiplier
        self.pool_selector = pool_selector or (lambda q, tools: "default")
        # batched encoder (one call for Q queries); falls back to looping
        # embed_fn so any single-query encoder still works batch-first
        self.embed_batch_fn = embed_batch_fn
        # bounded ring: record under lock, drain under the same lock — the
        # discipline ToolsDatabase uses for its table (a lock-free list drops
        # events when a drain races batched serving). `outcome_sink` bypasses
        # the ring entirely: events go straight to the control-plane store.
        self.outcome_log: Deque[OutcomeEvent] = deque()
        assert outcome_capacity >= 1, "outcome_capacity must be >= 1"
        self.outcome_capacity = int(outcome_capacity)
        self.outcomes_dropped = 0
        self.outcome_sink = outcome_sink
        self._outcome_lock = threading.Lock()
        # the scoring layer: a shared ToolIndexManager, or one owned by this
        # router built from (backend, backend_opts) — "dense" is the PR 1
        # jitted topk_dense path, numerics unchanged
        self._owns_index = index is None
        self.index = index if index is not None else ToolIndexManager(
            db, backend=backend, backend_opts=backend_opts
        )

    def close(self) -> None:
        """Tear down a retiring router (idempotent).

        Unregisters the router-owned index manager from the database's swap
        listeners — without this, a discarded router over a long-lived
        ToolsDatabase keeps rebuilding its index (and pinning its table
        copies) on every future swap. A shared manager passed via `index=`
        is left alone: its lifecycle belongs to the caller.
        """
        if self._owns_index:
            self.index.close()

    # ---------------------------------------------------------- serving path
    def _embed_batch(self, queries: Sequence[np.ndarray]) -> np.ndarray:
        if self.embed_batch_fn is not None:
            return np.asarray(self.embed_batch_fn(queries), dtype=np.float32)
        return np.stack([np.asarray(self.embed_fn(q), np.float32) for q in queries])

    def route_batch(
        self,
        queries: Sequence[np.ndarray],
        candidate_masks: Optional[np.ndarray] = None,  # [Q, T] {0,1} or None
    ) -> List[RouteResult]:
        """Route Q queries in one batched scoring pass.

        One batched index call (the configured `ScorerBackend`; exact jitted
        dense by default) scores the whole [Q, D] query block against the
        [T, D] table (with optional per-query candidate masks); when the
        Stage-2 MLP is configured, featurization and `rerank_topk_scored`
        also run over the full batch. Returns one RouteResult per query, in
        input order; each carries the per-query amortized latency. A
        candidate mask admitting fewer than k tools yields a correspondingly
        shorter tools/scores list (never masked-out ids).
        """
        t0 = time.perf_counter()
        n_q = len(queries)
        if n_q == 0:
            return []
        q = self._embed_batch(queries)  # [Q, D]
        # swap_table asserts the table shape is invariant, so the tool count
        # is stable across versions and safe to read without a snapshot
        n_t = len(self.db)
        rerank = self.mlp_params is not None and self.featurizer is not None
        c = min(self.k * self.candidate_multiplier, n_t) if rerank else min(self.k, n_t)
        k_eff = min(self.k, c)  # tables smaller than k yield short results
        # pad the batch up to a power-of-two bucket so the jitted scoring
        # programs compile once per bucket, not once per distinct Q (the
        # scheduler's admission batches vary with free slots; a retrace is
        # a multi-ms stall against the 10 ms budget). Pad rows are zero
        # queries whose results are sliced away below.
        n_pad = (1 << max(n_q - 1, 0).bit_length()) - n_q
        if n_pad:
            q_in = np.concatenate([q, np.zeros((n_pad, q.shape[1]), np.float32)])
            queries_in = list(queries) + [np.zeros(0, np.int64)] * n_pad
            masks_in = None if candidate_masks is None else np.concatenate(
                [candidate_masks, np.ones((n_pad, n_t), candidate_masks.dtype)]
            )
        else:
            q_in, queries_in, masks_in = q, queries, candidate_masks
        # the index layer scores the batch against an atomic (version, table)
        # snapshot — the reported table_version and the scores come from the
        # SAME table even if swap_table lands mid-batch, whichever backend
        # (or the exact mid-rebuild fallback) served it
        cand_scores_np, cand_idx_np, table_version = self.index.topk(
            q_in, c, masks_in
        )
        if rerank:
            feats = self.featurizer.features(q_in, queries_in, cand_idx_np, cand_scores_np)
            top_idx, top_scores = reranker_lib.rerank_topk_scored(
                self.mlp_params,
                jnp.asarray(feats),
                jnp.asarray(cand_idx_np),
                k_eff,
                valid=jnp.asarray(cand_scores_np > NEG_INF / 2),
            )
        else:
            top_idx, top_scores = cand_idx_np[:, :k_eff], cand_scores_np[:, :k_eff]
        top_idx = np.asarray(top_idx)[:n_q]
        top_scores = np.asarray(top_scores)[:n_q]
        latency_ms = (time.perf_counter() - t0) * 1e3 / n_q
        out = []
        for j in range(n_q):
            # a mask can leave fewer than k candidates; those slots carry the
            # NEG_INF sentinel and must not surface as selected tools
            valid_j = top_scores[j] > NEG_INF / 2
            tools = [int(t) for t in top_idx[j][valid_j]]
            out.append(
                RouteResult(
                    tools=tools,
                    scores=[float(s) for s in top_scores[j][valid_j]],
                    latency_ms=latency_ms,
                    pool=self.pool_selector(q[j], tools),
                    table_version=table_version,
                )
            )
        return out

    def route(
        self,
        query_tokens: np.ndarray,
        candidate_mask: Optional[np.ndarray] = None,  # [T] {0,1} or None
    ) -> RouteResult:
        """Single-query routing: the batch-of-1 case of `route_batch`."""
        masks = None if candidate_mask is None else np.asarray(candidate_mask)[None]
        return self.route_batch([query_tokens], masks)[0]

    # ------------------------------------------------------------ feedback
    def record_outcome(self, query_tokens: np.ndarray, tool_id: int, outcome: int):
        event = OutcomeEvent(
            query_tokens=query_tokens,
            tool_id=tool_id,
            outcome=int(outcome),
            timestamp=time.time(),
        )
        if self.outcome_sink is not None:
            self.outcome_sink(event)
            return
        with self._outcome_lock:
            if len(self.outcome_log) >= self.outcome_capacity:
                self.outcome_log.popleft()
                self.outcomes_dropped += 1
            self.outcome_log.append(event)

    def drain_outcomes(self) -> List[OutcomeEvent]:
        """Hand the accumulated log to the offline refinement job."""
        with self._outcome_lock:
            log = list(self.outcome_log)
            self.outcome_log.clear()
        return log
