"""Latency measurement harness (paper §5.5, Tables 1 & 6).

Measures per-request p50/p99 wall-clock on a single CPU process, covering
embedding computation, similarity search, and any re-ranking overhead —
exactly the paper's protocol. The embedding forward uses the MiniLM-shaped
22M-parameter transformer (repro.embedding.transformer), so the dominant cost
term matches the production router's, independent of weight values.

The percentile math itself lives in `repro.obs.summary` (one implementation
shared by this harness, the benchmarks, and the tracer report);
`LatencyStats`/`percentile_stats` are re-exported here for compatibility.
"""
from __future__ import annotations

from typing import Callable, List

from repro.obs import clock
from repro.obs.summary import LatencyStats, percentile_stats

__all__ = ["LatencyStats", "measure_latency", "percentile_stats"]


def measure_latency(
    serve_one: Callable[[int], object],
    n_requests: int,
    warmup: int = 20,
) -> LatencyStats:
    """Time `serve_one(i)` per request (one at a time — router semantics)."""
    for i in range(min(warmup, n_requests)):
        serve_one(i)
    samples: List[float] = []
    for i in range(n_requests):
        t0 = clock.perf()
        serve_one(i)
        samples.append(clock.duration_ms(t0))
    return percentile_stats(samples)
