"""Latency measurement harness (paper §5.5, Tables 1 & 6).

Measures per-request p50/p99 wall-clock on a single CPU process, covering
embedding computation, similarity search, and any re-ranking overhead —
exactly the paper's protocol. The embedding forward uses the MiniLM-shaped
22M-parameter transformer (repro.embedding.transformer), so the dominant cost
term matches the production router's, independent of weight values.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Sequence

import numpy as np

__all__ = ["LatencyStats", "measure_latency", "percentile_stats"]


@dataclasses.dataclass
class LatencyStats:
    p50_ms: float
    p99_ms: float
    mean_ms: float
    n: int

    def as_dict(self) -> Dict[str, float]:
        return {
            "p50_ms": self.p50_ms,
            "p99_ms": self.p99_ms,
            "mean_ms": self.mean_ms,
            "n": self.n,
        }


def percentile_stats(samples_ms: Sequence[float]) -> LatencyStats:
    arr = np.asarray(samples_ms, dtype=np.float64)
    return LatencyStats(
        p50_ms=float(np.percentile(arr, 50)),
        p99_ms=float(np.percentile(arr, 99)),
        mean_ms=float(arr.mean()),
        n=len(arr),
    )


def measure_latency(
    serve_one: Callable[[int], object],
    n_requests: int,
    warmup: int = 20,
) -> LatencyStats:
    """Time `serve_one(i)` per request (one at a time — router semantics)."""
    for i in range(min(warmup, n_requests)):
        serve_one(i)
    samples: List[float] = []
    for i in range(n_requests):
        t0 = time.perf_counter()
        serve_one(i)
        samples.append((time.perf_counter() - t0) * 1e3)
    return percentile_stats(samples)
