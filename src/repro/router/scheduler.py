"""Continuous-batching scheduler for the backend decode pool.

The paper's gateway (Fig. 1b) forwards requests to model pools; this module
is the pool-side scheduler a production deployment needs: a fixed number of
decode *slots*, requests admitted from a queue as slots free up, one batched
decode step per tick (all active slots advance together), prefill on
admission. When constructed with a `SemanticRouter`, the admission loop
tool-routes incoming requests through the batched serving API
(`route_batch`): all requests admitted in a tick are embedded/scored/top-K'd
in one jitted call instead of one route per request.
Orchestrated in Python, compute in two jitted programs
(prefill / decode_step) over a fixed-capacity batch — the standard
continuous-batching design (Orca/vLLM) mapped to JAX's static shapes: the
decode batch is always [n_slots, 1]; empty slots carry a pad token and their
outputs are ignored.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # [S] (or [S, K] for codebook archs)
    max_new_tokens: int
    tools: Optional[List[int]] = None  # attached by the semantic router
    query_tokens: Optional[np.ndarray] = None  # routed at admission when set
    route_result: Optional[object] = None  # RouteResult from batched routing
    # filled by the scheduler
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_at_tick: int = -1
    finished_at_tick: int = -1

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ContinuousBatcher:
    """Fixed-slot continuous batching over (prefill, decode_step)."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        n_slots: int = 4,
        max_len: int = 256,
        sample: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
        router=None,  # Optional[SemanticRouter]: batch-routes at admission
    ):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample = sample or (lambda logits: jnp.argmax(logits, axis=-1))
        self.router = router
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int32)  # next position
        self.tick_count = 0
        self.completed: List[Request] = []
        self._decode = jax.jit(lambda p, c, b: M.decode_step(cfg, p, c, b))
        self._cache = self._empty_cache()
        self._tokens = self._pad_tokens()

    # ---------------------------------------------------------------- setup
    def _empty_cache(self):
        spec = M.cache_spec(self.cfg, self.n_slots, self.max_len)
        from repro.models.params import ParamSpec

        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(self.cfg.dtype)),
            spec,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )

    def _pad_tokens(self):
        shape = (self.n_slots, 1, self.cfg.n_codebooks) if self.cfg.n_codebooks else (
            self.n_slots, 1,
        )
        return jnp.zeros(shape, jnp.int32)

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        self.queue.append(req)

    def _route_admissible(self):
        """Tool-route the queue head in ONE `route_batch` call.

        Only the requests that can actually be admitted this tick (up to the
        number of free slots) are routed, so routing work tracks admission
        rate rather than queue depth.
        """
        if self.router is None:
            return
        free = sum(1 for s in self.slots if s is None)
        head = itertools.islice(self.queue, free)
        pending = [r for r in head if r.tools is None and r.query_tokens is not None]
        if not pending:
            return
        results = self.router.route_batch([r.query_tokens for r in pending])
        for req, res in zip(pending, results):
            req.tools = res.tools
            req.route_result = res

    def _admit(self):
        self._route_admissible()
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.admitted_at_tick = self.tick_count
            # prefill this request alone (batch-1) and splice into the cache
            batch = {"tokens": jnp.asarray(req.prompt[None])}
            if self.cfg.cross_attn_every:
                batch["image_embeds"] = jnp.zeros(
                    (1, self.cfg.n_image_tokens, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype),
                )
            logits, cache1 = M.prefill(self.cfg, self.params, batch, max_cache_len=self.max_len)
            self._splice_cache(slot, cache1)
            tok = np.asarray(self.sample(logits[:, -1]))
            first = int(tok.reshape(-1)[0]) if not self.cfg.n_codebooks else tok.reshape(-1).tolist()
            req.generated.append(first)
            self._set_slot_token(slot, tok)
            self.slots[slot] = req
            self.slot_pos[slot] = len(req.prompt)

    def _splice_cache(self, slot: int, cache1):
        def splice(full, one):
            return full.at[:, slot : slot + 1].set(one.astype(full.dtype))

        self._cache = jax.tree.map(splice, self._cache, cache1)

    def _set_slot_token(self, slot: int, tok: np.ndarray):
        t = jnp.asarray(tok).reshape((1, 1, -1) if self.cfg.n_codebooks else (1, 1))
        if self.cfg.n_codebooks:
            self._tokens = self._tokens.at[slot : slot + 1].set(t)
        else:
            self._tokens = self._tokens.at[slot : slot + 1].set(t)

    # ------------------------------------------------------------------ tick
    def tick(self) -> Dict[str, int]:
        """Admit -> one batched decode step -> retire finished requests."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if active:
            # positions differ per slot; our decode_step takes a scalar pos,
            # so we step at the max position and mask validity per slot via
            # the cache contents (pad slots attend only their own prefix).
            pos = int(self.slot_pos[active].max())
            logits, self._cache = self._decode(
                self.params, self._cache,
                {"token": self._tokens, "pos": jnp.asarray(pos, jnp.int32)},
            )
            toks = np.asarray(self.sample(logits[:, -1]))
            for i in active:
                req = self.slots[i]
                val = int(toks[i].reshape(-1)[0]) if not self.cfg.n_codebooks else toks[i].reshape(-1).tolist()
                req.generated.append(val)
                self._set_slot_token(i, toks[i])
                self.slot_pos[i] += 1
                if req.done or self.slot_pos[i] >= self.max_len - 1:
                    req.finished_at_tick = self.tick_count
                    self.completed.append(req)
                    self.slots[i] = None
        self.tick_count += 1
        return {
            "tick": self.tick_count,
            "active": len(active),
            "queued": len(self.queue),
            "completed": len(self.completed),
        }

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        while (self.queue or any(s is not None for s in self.slots)) and self.tick_count < max_ticks:
            self.tick()
        return self.completed
