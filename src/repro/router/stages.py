"""StageSet: one atomic snapshot of the gateway's learned serving stages.

The PR 2 control plane made the *embedding table* hot-swappable
(`ToolsDatabase.swap_table`); this module does the same for the learned
stages the paper layers on top — the §4.3 contrastive adapter and the §4.2
MLP re-ranker. A `StageSet` is an immutable value: the adapter params
applied to query embeddings before the index backend scores (query-side
only, so the tool table — and any built IVF/Pallas index over it — is
untouched by a promotion), plus the re-ranker params + featurizer applied
per batch after candidate retrieval.

`SemanticRouter` holds exactly one live StageSet behind a version counter
with the same discipline as the table: `route_batch` reads one snapshot at
entry and finishes on it even if a promotion lands mid-batch, promotions
are compare-and-swap (`set_stages(expect_version=...)` raises
`ConflictError` on a lost race), and a bounded history of superseded sets
makes demotion (`rollback_stages`) instant — the learning plane's
`StageGuard` uses it exactly like the table guard uses
`ToolsDatabase.rollback`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapter import adapter_apply
from repro.core.features import OutcomeFeaturizer

__all__ = ["StageSet"]

# one jitted adapter application shared by every router — the hot path runs
# it per batch, and a per-call trace would cost more than the matmuls
_adapter_apply_j = jax.jit(adapter_apply, static_argnames=("scale",))


@dataclasses.dataclass(frozen=True)
class StageSet:
    """Immutable learned-stage configuration served by one router snapshot.

    `adapter_artifact` / `rerank_artifact` are the `ArtifactRegistry`
    versions the params came from (None for hand-wired params), so serving
    results stay attributable to a specific trained artifact.
    """

    adapter_params: Optional[dict] = None  # §4.3 head, query-side at serving
    adapter_scale: float = 1.0
    adapter_artifact: Optional[int] = None
    mlp_params: Optional[dict] = None  # §4.2 [7,64,32,1] MLP
    featurizer: Optional[OutcomeFeaturizer] = None
    rerank_artifact: Optional[int] = None

    @property
    def has_adapter(self) -> bool:
        return self.adapter_params is not None

    @property
    def has_reranker(self) -> bool:
        return self.mlp_params is not None and self.featurizer is not None

    @property
    def active(self) -> frozenset:
        """Stage names live in this set (mirrors `DeploymentPlan.stages`)."""
        s = set()
        if self.has_adapter:
            s.add("adapter")
        if self.has_reranker:
            s.add("rerank")
        return frozenset(s)

    def adapt_queries(self, q: np.ndarray) -> np.ndarray:
        """Apply the adapter head to a [Q, D] query block (identity when no
        adapter is active). Unit rows in, unit rows out — the index backend
        scores the adapted queries against the *unadapted* table."""
        if not self.has_adapter:
            return q
        return np.asarray(
            _adapter_apply_j(
                self.adapter_params, jnp.asarray(q), scale=self.adapter_scale
            ),
            dtype=np.float32,
        )
