"""ToolsDatabase: the router's tool-embedding table + metadata store.

The serving-plane object the paper's Stage 1 updates: `swap_table` atomically
replaces the embedding table after an offline refinement job passes the
validation gate (§7.2 — "read outcome logs, compute centroid updates,
validate, and swap the embedding table. No code changes to the serving
path"). Keeps a rollback slot so deployment is instantly reversible.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional

import numpy as np

__all__ = ["ToolRecord", "ToolsDatabase"]


@dataclasses.dataclass
class ToolRecord:
    tool_id: int
    name: str
    description_tokens: np.ndarray
    category: int


class ToolsDatabase:
    """Thread-safe embedding table with atomic swap + rollback."""

    def __init__(self, records: List[ToolRecord], embeddings: np.ndarray):
        assert len(records) == embeddings.shape[0]
        self._records = records
        self._table = embeddings.astype(np.float32)
        self._previous: Optional[np.ndarray] = None
        self._lock = threading.Lock()
        self.table_version = 0

    def __len__(self) -> int:
        return len(self._records)

    @property
    def embeddings(self) -> np.ndarray:
        return self._table

    def snapshot(self) -> tuple:
        """(table_version, embedding table) read atomically w.r.t. swaps,
        so a serving batch can never score with table N+1 while labelling
        its outcomes with version N."""
        with self._lock:
            return self.table_version, self._table

    def record(self, tool_id: int) -> ToolRecord:
        return self._records[tool_id]

    def categories(self) -> np.ndarray:
        return np.array([r.category for r in self._records], dtype=np.int64)

    def swap_table(self, new_table: np.ndarray) -> int:
        """Atomically deploy a refined embedding table (returns new version)."""
        assert new_table.shape == self._table.shape, (
            f"table shape {new_table.shape} != {self._table.shape}"
        )
        with self._lock:
            self._previous = self._table
            self._table = new_table.astype(np.float32)
            self.table_version += 1
            return self.table_version

    def rollback(self) -> int:
        """Instant rollback to the previous table (§7.2)."""
        with self._lock:
            if self._previous is None:
                raise RuntimeError("no previous table to roll back to")
            self._table, self._previous = self._previous, None
            self.table_version += 1
            return self.table_version
