"""ToolsDatabase: the router's tool-embedding table + metadata store.

The serving-plane object the paper's Stage 1 updates: `swap_table` atomically
replaces the embedding table after an offline refinement job passes the
validation gate (§7.2 — "read outcome logs, compute centroid updates,
validate, and swap the embedding table. No code changes to the serving
path"). Keeps a small bounded *version history* of superseded tables so
deployment is instantly reversible: `rollback()` restores the most recent
retained table, `rollback(to_version=...)` targets any retained version
(the control plane's guard uses this to unwind a regressing swap even after
further swaps have landed). A rollback discards the replaced table and every
retained version newer than the target — they are dead lineage once the
guard has condemned them.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, List, Optional

import numpy as np

__all__ = ["ToolRecord", "ToolsDatabase", "ConflictError"]


class ConflictError(RuntimeError):
    """A versioned operation lost a race: the table moved under the caller."""


@dataclasses.dataclass
class ToolRecord:
    tool_id: int
    name: str
    description_tokens: np.ndarray
    category: int


class ToolsDatabase:
    """Thread-safe embedding table with atomic swap + versioned rollback."""

    def __init__(
        self,
        records: List[ToolRecord],
        embeddings: np.ndarray,
        history_limit: int = 4,
    ):
        assert len(records) == embeddings.shape[0]
        assert history_limit >= 1
        self._records = records
        self._table = embeddings.astype(np.float32)
        # superseded tables, oldest first: {version -> table at that version}
        self._history: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._history_limit = history_limit
        self._lock = threading.Lock()
        # version-change listeners (repro.index.ToolIndexManager registers its
        # rebuild trigger here); invoked AFTER the lock is released so a
        # listener may call snapshot()/swap_table() without deadlocking
        self._swap_listeners: List[Callable[[int], None]] = []
        self.table_version = 0

    def __len__(self) -> int:
        return len(self._records)

    @property
    def embeddings(self) -> np.ndarray:
        return self._table

    def snapshot(self) -> tuple:
        """(table_version, embedding table) read atomically w.r.t. swaps,
        so a serving batch can never score with table N+1 while labelling
        its outcomes with version N."""
        with self._lock:
            return self.table_version, self._table

    def record(self, tool_id: int) -> ToolRecord:
        return self._records[tool_id]

    def categories(self) -> np.ndarray:
        return np.array([r.category for r in self._records], dtype=np.int64)

    def retained_versions(self) -> List[int]:
        """Versions currently available as rollback targets, oldest first."""
        with self._lock:
            return list(self._history.keys())

    def add_swap_listener(self, fn: Callable[[int], None]) -> None:
        """Register `fn(new_version)` to run after every swap/rollback.

        The index layer uses this to kick async index rebuilds the moment a
        new table deploys; the serving path keeps an exact fallback until the
        rebuilt index lands, so listeners are fire-and-forget. Exceptions
        raised by a listener are swallowed — a broken rebuild hook must never
        turn a successful deployment into a failed one.

        The database holds a strong reference until `remove_swap_listener`:
        a retiring router/manager must unregister (`ToolIndexManager.close`)
        or it keeps rebuilding — and keeps its table copies alive — on every
        swap for the database's lifetime.
        """
        with self._lock:
            self._swap_listeners.append(fn)

    def remove_swap_listener(self, fn: Callable[[int], None]) -> None:
        """Unregister a listener added by `add_swap_listener` (idempotent)."""
        with self._lock:
            try:
                self._swap_listeners.remove(fn)
            except ValueError:
                pass

    def _notify_swap(self, new_version: int) -> None:
        for fn in list(self._swap_listeners):
            try:
                fn(new_version)
            except Exception:
                pass

    def swap_table(
        self, new_table: np.ndarray, expect_current: Optional[int] = None
    ) -> int:
        """Atomically deploy a refined embedding table (returns new version).

        The outgoing table is retained as a rollback target; the history is
        bounded at `history_limit` entries (oldest evicted first).

        `expect_current` makes the swap compare-and-swap: a deployment
        derived from version N is refused (ConflictError) if the table has
        moved past N while it was being computed, instead of silently
        clobbering someone else's swap.
        """
        assert new_table.shape == self._table.shape, (
            f"table shape {new_table.shape} != {self._table.shape}"
        )
        with self._lock:
            if expect_current is not None and self.table_version != expect_current:
                raise ConflictError(
                    f"table is v{self.table_version}, not v{expect_current} "
                    f"the deployment was derived from; refusing swap"
                )
            self._history[self.table_version] = self._table
            while len(self._history) > self._history_limit:
                self._history.popitem(last=False)
            self._table = new_table.astype(np.float32)
            self.table_version += 1
            new_version = self.table_version
        self._notify_swap(new_version)
        return new_version

    def rollback(
        self, to_version: Optional[int] = None, expect_current: Optional[int] = None
    ) -> int:
        """Instant rollback (§7.2) to a retained version's table.

        Default target is the most recent retained version (the table that
        served immediately before the current one). Restoring bumps
        `table_version` — a rollback is itself a swap, so serving snapshots
        stay strictly versioned. The condemned current table is *not*
        retained, and retained versions newer than the target are dropped.

        `expect_current` makes the rollback compare-and-swap: if another
        swap landed after the caller judged version `expect_current`, the
        rollback is refused (ConflictError) instead of condemning a table
        the caller never evaluated — the guard's safety hinge.
        """
        with self._lock:
            if expect_current is not None and self.table_version != expect_current:
                raise ConflictError(
                    f"table is v{self.table_version}, not the judged "
                    f"v{expect_current}; refusing rollback"
                )
            if not self._history:
                raise RuntimeError("no previous table to roll back to")
            if to_version is None:
                to_version = next(reversed(self._history))
            if to_version not in self._history:
                raise RuntimeError(
                    f"version {to_version} not retained "
                    f"(available: {list(self._history.keys())})"
                )
            table = self._history.pop(to_version)
            for v in [v for v in self._history if v > to_version]:
                del self._history[v]
            self._table = table
            self.table_version += 1
            new_version = self.table_version
        self._notify_swap(new_version)
        return new_version
