"""Realistic request traffic for the serving plane.

Production router traffic is nothing like the uniform shuffled streams unit
benches replay: request popularity is Zipfian (a small hot set dominates),
repeats are *near*-duplicates (paraphrases, not byte-equal), arrival is
bursty, and the hot set drifts. This package synthesizes that shape,
deterministically per seed, so two runs — e.g. a bare router and a cached
one in `benchmarks/cache_bench.py` — can be driven with the IDENTICAL
stream and compared query-for-query.

`ZipfTrafficGenerator` (generator.py) samples ranks from a Zipf(s) law over
a fixed pool of distinct intents, applies paraphrase jitter (token
drop+append, tuned to stay within a route cache's cosine threshold), draws
lognormal burst batch sizes, and adversarially rotates the rank→intent
mapping every `hot_set_rotate_every` batches — the churn that flushes any
recency-based cache.

`drive` (harness.py) replays a stream through `route_batch`, timing route
calls only, and enforces the **staleness gate** on every result: the
served `(table_version, stage_version)` must lie inside the live version
window read around the call (versions are monotone, so the window is
exact even while control-plane swaps land concurrently mid-stream).
`agreement` compares two replays of the same stream top-1-for-top-1 — the
cached-vs-uncached routing-agreement number BENCH_cache.json records.
"""
from repro.traffic.generator import TrafficConfig, ZipfTrafficGenerator
from repro.traffic.harness import TrafficReport, agreement, drive

__all__ = [
    "TrafficConfig",
    "ZipfTrafficGenerator",
    "TrafficReport",
    "agreement",
    "drive",
]
