"""Seeded Zipfian near-duplicate query streams (package docstring has the
traffic-shape rationale)."""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

import numpy as np

__all__ = ["TrafficConfig", "ZipfTrafficGenerator"]


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    zipf_s: float = 1.1  # popularity exponent; higher = hotter hot set
    pool_size: int = 512  # distinct intents behind the stream
    query_len: int = 24  # tokens per intent (longer = milder jitter cosine)
    batch_size: int = 32  # mean arrival batch
    burstiness: float = 0.0  # lognormal sigma on batch size (0 = constant)
    paraphrase_p: float = 0.5  # fraction of requests jittered
    jitter_tokens: int = 1  # tokens dropped+appended per paraphrase
    hot_set_rotate_every: int = 0  # batches between rank->intent reshuffles
    vocab: int = 4096
    seed: int = 0

    def __post_init__(self):
        assert self.zipf_s > 0 and self.pool_size >= 1
        assert self.query_len > 2 * self.jitter_tokens >= 0
        assert self.batch_size >= 1 and 0.0 <= self.paraphrase_p <= 1.0


class ZipfTrafficGenerator:
    """Deterministic per (config, call sequence): two generators built from
    the same config emit the IDENTICAL stream, which is what lets
    `benchmarks/cache_bench.py` replay one stream through a bare router and
    a cached one and compare agreement query-for-query."""

    def __init__(
        self,
        config: TrafficConfig,
        pool: Optional[Sequence[np.ndarray]] = None,
    ):
        self.config = config
        self._rng = np.random.default_rng(config.seed)
        if pool is not None:
            # realistic intents (e.g. a Benchmark's query_tokens): routing
            # agreement between two replays is only meaningful when queries
            # actually resolve to a tool, so prefer this in benches. The
            # pool is cycled up to pool_size deterministically.
            assert all(len(t) > 2 * config.jitter_tokens for t in pool)
            self._pool = [
                np.asarray(pool[i % len(pool)], dtype=np.int64)
                for i in range(config.pool_size)
            ]
        else:
            # synthetic intents: token rows a BagEncoder-style embedder maps
            # to separated directions; paraphrases of one stay near it
            self._pool = [
                self._rng.integers(0, config.vocab, size=config.query_len).astype(np.int64)
                for _ in range(config.pool_size)
            ]
        # Zipf(s) over ranks, normalized; rank r -> intent _perm[r]
        p = (np.arange(config.pool_size) + 1.0) ** -config.zipf_s
        self._p = p / p.sum()
        self._perm = np.arange(config.pool_size)
        self._batches_emitted = 0

    def rotate_hot_set(self) -> None:
        """Adversarial churn: remap every rank to a fresh intent, so the
        whole hot set a cache has warmed goes cold at once."""
        self._rng.shuffle(self._perm)

    def _paraphrase(self, tokens: np.ndarray) -> np.ndarray:
        """Near-duplicate: drop `jitter_tokens` positions, append as many
        fresh ones. Length is preserved, so under a bag encoder the cosine
        to the original is ~((L - j) / L) — query_len 24 with one jittered
        token keeps ~0.958, inside the cache's default serving threshold
        region (see `repro.cache` for the threshold/agreement tradeoff)."""
        cfg = self.config
        drop = self._rng.choice(len(tokens), size=cfg.jitter_tokens, replace=False)
        kept = np.delete(tokens, drop)
        fresh = self._rng.integers(0, cfg.vocab, size=cfg.jitter_tokens)
        return np.concatenate([kept, fresh.astype(np.int64)])

    def next_batch(self) -> List[np.ndarray]:
        """One arrival batch: Zipf-ranked intents, jittered per request."""
        cfg = self.config
        if cfg.hot_set_rotate_every and self._batches_emitted \
                and self._batches_emitted % cfg.hot_set_rotate_every == 0:
            self.rotate_hot_set()
        self._batches_emitted += 1
        n = cfg.batch_size
        if cfg.burstiness:
            n = max(1, int(round(n * np.exp(self._rng.normal(0.0, cfg.burstiness)))))
        ranks = self._rng.choice(cfg.pool_size, size=n, p=self._p)
        batch = []
        for r in ranks:
            tokens = self._pool[int(self._perm[r])]
            if cfg.paraphrase_p and self._rng.random() < cfg.paraphrase_p:
                tokens = self._paraphrase(tokens)
            batch.append(tokens)
        return batch

    def stream(self, n_batches: int) -> Iterator[List[np.ndarray]]:
        for _ in range(n_batches):
            yield self.next_batch()
