"""Stream replay harness: drive `route_batch`, time it, gate staleness.

The staleness gate is the harness's reason to exist beyond timing: every
result's served `(table_version, stage_version)` must lie inside the live
version window read immediately around the `route_batch` call. Both
counters are monotone (swap/rollback/promotion/demotion are all version
bumps — see `ToolsDatabase` / `SemanticRouter.set_stages`), so
[versions-at-entry, versions-at-exit] is an exact bound on what any
correct path — cached or not — may serve, even while control-plane churn
lands concurrently mid-stream. A violation means a cache served a decision
from a dead snapshot; `benchmarks/cache_bench.py` fails CI on the first
one.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.obs import clock

__all__ = ["TrafficReport", "agreement", "drive"]


@dataclasses.dataclass
class TrafficReport:
    batches: int
    queries: int
    route_s: float  # wall time inside route_batch only (generation excluded)
    qps: float
    p50_ms: float  # per-batch route_batch latency percentiles
    p99_ms: float
    hit_rate: float  # fraction of results served from the route cache
    stale_serves: int  # results outside the live version window (MUST be 0)
    stale_examples: List[dict]  # first few violations, for the artifact
    results: Optional[List[List["RouteResult"]]] = None  # kept when record=True


def drive(
    router,
    batches: Sequence[List[np.ndarray]],
    record: bool = False,
    on_batch: Optional[Callable[[int], None]] = None,
) -> TrafficReport:
    """Replay pre-materialized arrival batches through `route_batch`.

    Batches are materialized by the caller (`list(gen.stream(n))`) so the
    generator's cost never pollutes the timing, and so the same list can be
    replayed against a second router. `on_batch(i)` runs between batches —
    the hook cache_bench uses to fire control-plane swaps mid-stream.
    `record=True` retains every RouteResult for `agreement` comparison.
    """
    lat_ms: List[float] = []
    kept: List[List] = []
    n_queries = n_hits = stale = 0
    stale_examples: List[dict] = []
    route_s = 0.0
    for i, batch in enumerate(batches):
        if on_batch is not None:
            on_batch(i)
        # live version window around the call: monotone counters make
        # [entry, exit] an exact staleness bound (module docstring)
        tv0, sv0 = router.db.table_version, router.stage_version
        t0 = clock.perf()
        results = router.route_batch(batch)
        route_s += clock.perf() - t0
        tv1, sv1 = router.db.table_version, router.stage_version
        lat_ms.append((clock.perf() - t0) * 1e3)
        for r in results:
            n_queries += 1
            n_hits += bool(r.cache_hit)
            if not (tv0 <= r.table_version <= tv1 and sv0 <= r.stage_version <= sv1):
                stale += 1
                if len(stale_examples) < 8:
                    stale_examples.append({
                        "batch": i,
                        "served": [r.table_version, r.stage_version],
                        "window": [[tv0, sv0], [tv1, sv1]],
                        "cache_hit": r.cache_hit,
                    })
        if record:
            kept.append(results)
    lat = np.asarray(lat_ms) if lat_ms else np.zeros(1)
    return TrafficReport(
        batches=len(lat_ms),
        queries=n_queries,
        route_s=route_s,
        qps=n_queries / route_s if route_s > 0 else 0.0,
        p50_ms=float(np.percentile(lat, 50)),
        p99_ms=float(np.percentile(lat, 99)),
        hit_rate=n_hits / n_queries if n_queries else 0.0,
        stale_serves=stale,
        stale_examples=stale_examples,
        results=kept if record else None,
    )


def agreement(a: List[List], b: List[List]) -> float:
    """Top-1 routing agreement between two replays of the same stream.

    The routing decision that matters downstream is which tool a request is
    dispatched to — the top-1 — so agreement is the fraction of queries
    whose top-1 tool matches (empty results agree only with empty).
    """
    total = same = 0
    for batch_a, batch_b in zip(a, b):
        assert len(batch_a) == len(batch_b), "streams differ in shape"
        for ra, rb in zip(batch_a, batch_b):
            total += 1
            ta = ra.tools[0] if ra.tools else None
            tb = rb.tools[0] if rb.tools else None
            same += ta == tb
    return same / total if total else 1.0
