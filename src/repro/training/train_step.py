"""Train-step factory: loss -> grads -> clip -> optimizer -> params.

The optimizer is chosen per model size: Adafactor for the very large
assigned architectures (optimizer state would not fit HBM as fp32 Adam),
AdamW otherwise. `make_train_state_specs` mirrors the parameter spec tree so
dry-run lowering can supply optimizer-state ShapeDtypeStructs without ever
allocating.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.models import model as M
from repro.models.config import ModelConfig

__all__ = ["TrainConfig", "choose_optimizer", "make_train_step"]

ADAFACTOR_THRESHOLD = 30_000_000_000  # params; above this, factored states


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "auto"  # auto | adamw | adafactor | sgd


def choose_optimizer(cfg: ModelConfig, tc: TrainConfig) -> optim.Optimizer:
    name = tc.optimizer
    if name == "auto":
        name = "adafactor" if cfg.param_count() > ADAFACTOR_THRESHOLD else "adamw"
    sched = optim.warmup_cosine(tc.learning_rate, tc.warmup_steps, tc.total_steps)
    if name == "adamw":
        return optim.adamw(sched, weight_decay=tc.weight_decay)
    if name == "adafactor":
        return optim.adafactor(sched)
    if name == "sgd":
        return optim.sgd(sched, momentum=0.9)
    raise ValueError(f"unknown optimizer {name!r}")


def make_train_step(
    cfg: ModelConfig, tc: TrainConfig = TrainConfig()
) -> Tuple[Callable, optim.Optimizer]:
    """Returns (train_step(params, opt_state, batch) -> (params, opt_state,
    metrics), optimizer)."""
    optimizer = choose_optimizer(cfg, tc)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True
        )(params)
        grads, gnorm = optim.clip_by_global_norm(grads, tc.grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step, optimizer
