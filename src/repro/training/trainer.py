"""Training loop: metrics, checkpointing, determinism.

Used by examples/train_100m.py (the end-to-end driver) and by the per-arch
smoke tests. Runs on whatever mesh is active; on this CPU container that is
the 1-device local mesh, on a pod it is the production mesh with the same
code path (pjit via shardings on params/batch).

Mesh activation is version-portable: pass `mesh=` and the trainer wraps
init/step/restore in `repro.common.meshctx.use_mesh`, so the logical
sharding constraints in the model resolve identically across JAX releases
(see meshctx's portability contract). With `mesh=None` (the default) the
trainer runs in whatever ambient context the caller established.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.msgpack_ckpt import restore_checkpoint, save_checkpoint
from repro.common import meshctx
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training.train_step import TrainConfig, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 0  # 0 = no checkpoints
    ckpt_dir: str = "checkpoints"
    seed: int = 0
    train: TrainConfig = TrainConfig()


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.step_fn, self.optimizer = make_train_step(cfg, tcfg.train)
        self.step_fn = jax.jit(self.step_fn)
        with self._mesh_ctx():
            self.params = M.init(cfg, jax.random.PRNGKey(tcfg.seed))
            self.opt_state = self.optimizer.init(self.params)
        self.step = 0
        self.history: List[Dict[str, float]] = []

    def _mesh_ctx(self):
        """Portable activation of the configured mesh (no-op when None)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return meshctx.use_mesh(self.mesh)

    def restore(self, directory: Optional[str] = None):
        d = directory or self.tcfg.ckpt_dir
        step, tree, _ = restore_checkpoint(d)
        with self._mesh_ctx():
            self.params = jax.tree.map(jnp.asarray, tree["params"])
            self.opt_state = jax.tree.unflatten(
                jax.tree.structure(self.opt_state),
                [jnp.asarray(x) for x in jax.tree.leaves(tree["opt_state"])],
            )
        self.step = step

    def save(self):
        save_checkpoint(
            self.tcfg.ckpt_dir,
            self.step,
            {"params": self.params, "opt_state": self.opt_state},
            meta={"arch": self.cfg.name, "step": self.step},
        )

    def fit(self, batches: Iterator[Dict[str, np.ndarray]], log: Callable = print):
        t0 = time.time()
        for _ in range(self.tcfg.steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            with self._mesh_ctx():
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                m["wall_s"] = round(time.time() - t0, 1)
                self.history.append(m)
                log(
                    f"step {self.step:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                    f"gnorm={m['grad_norm']:.3f} [{m['wall_s']}s]"
                )
            if self.tcfg.ckpt_every and self.step % self.tcfg.ckpt_every == 0:
                self.save()
        return self.history
