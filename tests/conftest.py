"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512 placeholder devices).

Also installs a minimal `hypothesis` stand-in when the real package is not
in the container, so the property-based test modules collect and run. The
shim covers exactly what this suite uses — `@given` over `st.integers`
strategies with `@settings(max_examples=..., deadline=...)` — by expanding
each property into a deterministic seeded loop over drawn examples.
"""
import random
import sys
import types

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ImportError:
    _SHIM_SEED = 0xA75  # fixed: the suite must be deterministic across runs

    class _IntegersStrategy:
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def example(self, rng):
            return rng.randint(self.min_value, self.max_value)

    def _integers(min_value, max_value):
        return _IntegersStrategy(min_value, max_value)

    def _settings(max_examples=10, deadline=None, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def _given(*strategies):
        def deco(fn):
            def runner():
                # examples drawn at call time so @settings works whether it
                # is applied above or below @given (both set the attribute)
                n = getattr(
                    runner, "_shim_max_examples",
                    getattr(fn, "_shim_max_examples", 10),
                )
                rng = random.Random(_SHIM_SEED)
                for _ in range(n):
                    fn(*(s.example(rng) for s in strategies))

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.pytestmark = list(getattr(fn, "pytestmark", []))
            return runner

        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

from repro.data.benchmarks import make_benchmark


@pytest.fixture(scope="session")
def small_bench():
    """A small but structurally complete metatool-like benchmark."""
    return make_benchmark(
        name="mt-small",
        n_tools=60,
        n_queries=600,
        n_topics=12,
        n_categories=6,
        candidate_set_size=10,
        lexical_overlap=0.06,
        topic_word_frac=0.30,
        name_mention_p=0.02,
        opacity_beta=(1.0, 4.0),
        decoy_fraction=0.15,
        function_spread=1.05,
        hard_query_frac=0.14,
        tool_word_noise=0.35,
        query_noise_words=0,
        reliability_extra_noise=2,
        seed=0,
    )


@pytest.fixture(scope="session")
def small_bench_sparse():
    """Sparse toolbench-like regime: few queries over many tools."""
    return make_benchmark(
        name="tb-small",
        n_tools=400,
        n_queries=120,
        n_topics=50,
        n_categories=10,
        candidate_set_size=6,
        candidate_style="function_nn",
        lexical_overlap=0.18,
        topic_word_frac=0.10,
        name_mention_p=0.05,
        function_spread=0.9,
        tool_word_noise=0.40,
        query_noise_words=1,
        hard_query_frac=0.27,
        seed=1,
    )
