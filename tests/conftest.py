"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512 placeholder devices)."""
import numpy as np
import pytest

from repro.data.benchmarks import make_benchmark


@pytest.fixture(scope="session")
def small_bench():
    """A small but structurally complete metatool-like benchmark."""
    return make_benchmark(
        name="mt-small",
        n_tools=60,
        n_queries=600,
        n_topics=12,
        n_categories=6,
        candidate_set_size=10,
        lexical_overlap=0.06,
        topic_word_frac=0.30,
        name_mention_p=0.02,
        opacity_beta=(1.0, 4.0),
        decoy_fraction=0.15,
        function_spread=1.05,
        hard_query_frac=0.14,
        tool_word_noise=0.35,
        query_noise_words=0,
        reliability_extra_noise=2,
        seed=0,
    )


@pytest.fixture(scope="session")
def small_bench_sparse():
    """Sparse toolbench-like regime: few queries over many tools."""
    return make_benchmark(
        name="tb-small",
        n_tools=400,
        n_queries=120,
        n_topics=50,
        n_categories=10,
        candidate_set_size=6,
        candidate_style="function_nn",
        lexical_overlap=0.18,
        topic_word_frac=0.10,
        name_mention_p=0.05,
        function_spread=0.9,
        tool_word_noise=0.40,
        query_noise_words=1,
        hard_query_frac=0.27,
        seed=1,
    )
