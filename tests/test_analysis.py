"""Analyzer tests: per-rule true-positive/true-negative fixtures, noqa +
baseline handling, the retrace detector (catching an unbucketed jit, and
confirming route_batch stays inside its bucket set), the lockgraph checker
(catching an inverted two-lock fixture, confirming the live planes are
clean), and the daemon-loop health surface the thread-discipline rule
verifies on the real controllers."""
import json
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import engine
from repro.analysis.findings import Baseline, Finding, noqa_rules_by_line
from repro.analysis.rules import REGISTRY

REPO = Path(__file__).resolve().parent.parent


# ------------------------------------------------------------------ helpers


def _check(tmp_path, source, rule, *, relpath="mod.py", tests_dir=None):
    """Run one rule over one fixture file; return its active findings."""
    f = tmp_path / relpath
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(source)
    res = engine.run([str(f)], tests_dir=tests_dir, rules=[rule])
    return res["active"]


# ------------------------------------------------------------ rule fixtures


def test_registry_has_all_rules():
    assert set(REGISTRY) == {
        "mesh-api",
        "cas-discipline",
        "snapshot-discipline",
        "jit-in-function",
        "jit-static-scalar",
        "pow2-bucket",
        "lock-dispatch",
        "cache-version-stamp",
        "thread-discipline",
        "kernel-contract",
        "obs-discipline",
    }
    for rule in REGISTRY.values():
        assert rule.description and rule.hint


def test_mesh_api_flags_raw_usage(tmp_path):
    src = (
        "import jax\n"
        "from jax.sharding import use_mesh\n"
        "def f(m):\n"
        "    jax.set_mesh(m)\n"
        "    return jax.sharding.get_abstract_mesh()\n"
    )
    found = _check(tmp_path, src, "mesh-api")
    assert len(found) >= 3
    assert all(f.rule == "mesh-api" for f in found)


def test_mesh_api_allows_meshctx_and_mesh_type(tmp_path):
    # the one module allowed to touch the raw APIs
    src = "import jax\n\ndef g(m):\n    jax.set_mesh(m)\n"
    assert _check(tmp_path, src, "mesh-api", relpath="common/meshctx.py") == []
    # jax.sharding.Mesh type annotations are NOT a mesh-context API
    src2 = "import jax\n\ndef h(m: 'jax.sharding.Mesh'):\n    return m\n"
    assert _check(tmp_path, src2, "mesh-api") == []


def test_cas_discipline_flags_bare_swaps(tmp_path):
    src = (
        "def f(db, router, t, s):\n"
        "    db.swap_table(t)\n"
        "    db.rollback()\n"
        "    router.set_stages(s)\n"
        "    router.rollback_stages()\n"
    )
    found = _check(tmp_path, src, "cas-discipline")
    assert len(found) == 4


def test_cas_discipline_accepts_cas_and_exempts_registry(tmp_path):
    src = (
        "def f(db, router, registry, t, s, v):\n"
        "    db.swap_table(t, expect_current=v)\n"
        "    db.rollback(v, v)\n"  # expectation passed positionally
        "    router.set_stages(s, expect_version=v)\n"
        "    router.rollback_stages(expect_current=v)\n"
        "    registry.rollback('adapter', to_version=v)\n"  # bounded trim
    )
    assert _check(tmp_path, src, "cas-discipline") == []


def test_snapshot_discipline_flags_foreign_private_access(tmp_path):
    src = "def f(db):\n    return db._table, db._history\n"
    found = _check(tmp_path, src, "snapshot-discipline")
    assert len(found) == 2


def test_snapshot_discipline_allows_self_and_owners(tmp_path):
    src = "class T:\n    def g(self):\n        return self._table\n"
    assert _check(tmp_path, src, "snapshot-discipline") == []
    src2 = "def f(db):\n    return db._table\n"
    assert (
        _check(tmp_path, src2, "snapshot-discipline", relpath="router/tooldb.py")
        == []
    )


def test_jit_in_function_flags_calls_and_nested_decorators(tmp_path):
    src = (
        "import jax\n"
        "def train():\n"
        "    g = jax.jit(lambda x: x)\n"
        "    @jax.jit\n"
        "    def step(p):\n"
        "        return p\n"
        "    return g, step\n"
    )
    found = _check(tmp_path, src, "jit-in-function")
    assert len(found) == 2


def test_jit_in_function_allows_module_scope(tmp_path):
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('k',))\n"
        "def f(x, k: int):\n"
        "    return x\n"
        "g = jax.jit(f)\n"
    )
    assert _check(tmp_path, src, "jit-in-function") == []


def test_jit_static_scalar_flags_traced_scalars(tmp_path):
    src = "import jax\n@jax.jit\ndef f(x, k: int):\n    return x\n"
    found = _check(tmp_path, src, "jit-static-scalar")
    assert len(found) == 1 and "k" in found[0].message


def test_jit_static_scalar_accepts_static_argnames(tmp_path):
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('k', 'mode'))\n"
        "def f(x, k: int, mode: str):\n"
        "    return x\n"
    )
    assert _check(tmp_path, src, "jit-static-scalar") == []


def test_jit_static_scalar_assignment_form(tmp_path):
    src = (
        "import jax\n"
        "def f(x, k: int):\n"
        "    return x\n"
        "g = jax.jit(f)\n"
        "h = jax.jit(f, static_argnames=('k',))\n"
    )
    found = _check(tmp_path, src, "jit-static-scalar")
    assert len(found) == 1  # g traced-scalar; h is fine


def test_pow2_bucket_flags_manual_arithmetic(tmp_path):
    src = "def pad(n):\n    return (1 << max(n - 1, 0).bit_length()) - n\n"
    assert len(_check(tmp_path, src, "pow2-bucket")) == 1
    # the canonical helper itself is allowed
    assert (
        _check(tmp_path, src, "pow2-bucket", relpath="common/bucketing.py") == []
    )


def test_lock_dispatch_flags_device_work_under_lock(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "from repro.core.retrieval import topk_dense\n"
        "class S:\n"
        "    def f(self, q, t):\n"
        "        with self._lock:\n"
        "            a = jnp.asarray(q)\n"
        "            return topk_dense(a, t, 5)\n"
    )
    found = _check(tmp_path, src, "lock-dispatch", relpath="router/mod.py")
    assert len(found) == 2


def test_lock_dispatch_ignores_outside_packages_and_nested_defs(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "class S:\n"
        "    def f(self, q):\n"
        "        with self._lock:\n"
        "            def later():\n"
        "                return jnp.asarray(q)\n"  # deferred, not dispatched here
        "            return later\n"
        "    def g(self, q):\n"
        "        a = jnp.asarray(q)\n"  # no lock held
        "        with self._lock:\n"
        "            self.out = a\n"
    )
    assert _check(tmp_path, src, "lock-dispatch", relpath="index/mod.py") == []
    # same dispatch-under-lock source OUTSIDE the serving packages: not flagged
    src2 = (
        "import jax.numpy as jnp\n"
        "def f(lock, q):\n"
        "    with lock:\n"
        "        return jnp.asarray(q)\n"
    )
    assert _check(tmp_path, src2, "lock-dispatch", relpath="tools/mod.py") == []


def test_cache_version_stamp_flags_unstamped_sites(tmp_path):
    src = (
        "def serve(cache, q, tools, scores, tv, sv):\n"
        "    hit = cache.lookup_batch(q, table_version=tv)\n"  # missing stage
        "    cache.insert_batch(q, tools, scores)\n"  # missing both
        "    return hit\n"
    )
    found = _check(tmp_path, src, "cache-version-stamp")
    assert len(found) == 2
    assert "stage_version=" in found[0].message
    assert "table_version=" in found[1].message


def test_cache_version_stamp_allows_stamped_and_noncache(tmp_path):
    # fully stamped call sites on a cache receiver: clean
    src = (
        "def serve(route_cache, q, tools, scores, tv, sv):\n"
        "    hit = route_cache.lookup_batch(q, table_version=tv, stage_version=sv)\n"
        "    route_cache.insert_batch(q, tools, scores, table_version=tv,\n"
        "                             stage_version=sv)\n"
        "    return hit\n"
    )
    assert _check(tmp_path, src, "cache-version-stamp") == []
    # same method names on a non-cache receiver are someone else's API
    src2 = "def f(store, q):\n    return store.lookup_batch(q)\n"
    assert _check(tmp_path, src2, "cache-version-stamp") == []


def test_cache_version_stamp_flags_dispatch_under_cache_lock(tmp_path):
    src = (
        "import jax.numpy as jnp\n"
        "class C:\n"
        "    def lookup(self, q):\n"
        "        with self._lock:\n"
        "            return jnp.asarray(q)\n"
    )
    found = _check(tmp_path, src, "cache-version-stamp", relpath="cache/mod.py")
    assert len(found) == 1
    assert "critical section" in found[0].message
    # identical source outside cache/: this rule leaves it alone
    assert _check(tmp_path, src, "cache-version-stamp", relpath="tools/mod.py") == []


def test_obs_discipline_flags_raw_clocks_and_print(tmp_path):
    src = (
        "import time\n"
        "def serve(q):\n"
        "    t0 = time.perf_counter()\n"
        "    print('served', q)\n"
        "    return time.time() - t0\n"
        "def wait():\n"
        "    return time.monotonic()\n"
    )
    for pkg in ("router", "index", "control", "learn"):
        found = _check(tmp_path, src, "obs-discipline",
                       relpath=f"{pkg}/mod.py")
        assert len(found) == 4, pkg


def test_obs_discipline_allows_clock_module_and_other_packages(tmp_path):
    src = (
        "import time\n"
        "from repro.obs import clock\n"
        "def serve(q):\n"
        "    t0 = clock.perf()\n"
        "    time.sleep(0.01)\n"  # sleep is not a clock read
        "    return clock.duration_ms(t0)\n"
    )
    assert _check(tmp_path, src, "obs-discipline", relpath="router/mod.py") == []
    assert _check(tmp_path, src, "obs-discipline", relpath="control/mod.py") == []
    # the same raw calls OUTSIDE the covered packages are fine (benches,
    # the launcher's operator output, the obs plane itself)
    src2 = (
        "import time\n"
        "def bench():\n"
        "    print(time.perf_counter())\n"
    )
    assert _check(tmp_path, src2, "obs-discipline", relpath="launch/mod.py") == []
    assert _check(tmp_path, src2, "obs-discipline", relpath="obs/clock.py") == []


def test_thread_discipline_flags_silent_and_swallowing_loops(tmp_path):
    silent = (
        "import threading\n"
        "class C:\n"
        "    def start(self):\n"
        "        def loop():\n"
        "            while True:\n"
        "                self.step()\n"
        "        self._t = threading.Thread(target=loop, daemon=True)\n"
    )
    found = _check(tmp_path, silent, "thread-discipline")
    assert len(found) == 1 and "silently" in found[0].message
    swallowing = (
        "import threading\n"
        "class C:\n"
        "    def start(self):\n"
        "        def loop():\n"
        "            while True:\n"
        "                try:\n"
        "                    self.step()\n"
        "                except Exception:\n"
        "                    pass\n"
        "        self._t = threading.Thread(target=loop, daemon=True)\n"
    )
    found = _check(tmp_path, swallowing, "thread-discipline")
    assert len(found) == 1 and "recording" in found[0].message


def test_thread_discipline_accepts_error_recording_loop(tmp_path):
    src = (
        "import threading\n"
        "class C:\n"
        "    def start(self):\n"
        "        def loop():\n"
        "            while True:\n"
        "                try:\n"
        "                    self.step()\n"
        "                    self.last_loop_error = None\n"
        "                except Exception as exc:\n"
        "                    self.last_loop_error = exc\n"
        "        self._t = threading.Thread(target=loop, daemon=True)\n"
    )
    assert _check(tmp_path, src, "thread-discipline") == []


def test_thread_discipline_clean_on_real_controllers():
    res = engine.run(
        [
            str(REPO / "src/repro/control/controller.py"),
            str(REPO / "src/repro/learn/controller.py"),
        ],
        tests_dir=None,
        rules=["thread-discipline"],
    )
    assert res["active"] == []


def test_kernel_contract_requires_ref_and_parity_test(tmp_path):
    kdir = tmp_path / "kernels" / "mykern"
    kdir.mkdir(parents=True)
    (kdir / "kernel.py").write_text("def run():\n    return 0\n")
    tdir = tmp_path / "tests"
    tdir.mkdir()
    (tdir / "test_nothing.py").write_text("def test_x():\n    pass\n")
    res = engine.run(
        [str(tmp_path / "kernels")], tests_dir=str(tdir), rules=["kernel-contract"]
    )
    msgs = [f.message for f in res["active"]]
    assert any("ref.py" in m for m in msgs)
    assert any("parity test" in m for m in msgs)
    # satisfy both: ref sibling + a test referencing kernels.mykern
    (kdir / "ref.py").write_text("def run_ref():\n    return 0\n")
    (tdir / "test_mykern.py").write_text(
        "from x.kernels.mykern.kernel import run\n"
    )
    res = engine.run(
        [str(tmp_path / "kernels")], tests_dir=str(tdir), rules=["kernel-contract"]
    )
    assert res["active"] == []


def test_kernel_contract_topk_sentinel(tmp_path):
    kdir = tmp_path / "kernels" / "topk_fancy"
    kdir.mkdir(parents=True)
    (kdir / "kernel.py").write_text("NEG = -1e30\ndef run():\n    return NEG\n")
    (kdir / "ref.py").write_text("def run_ref():\n    return 0\n")
    res = engine.run(
        [str(tmp_path / "kernels")], tests_dir=None, rules=["kernel-contract"]
    )
    assert any("sentinel" in f.message for f in res["active"])
    (kdir / "kernel.py").write_text(
        "from repro.core.retrieval import NEG_INF\nNEG = NEG_INF\n"
        "def run():\n    return NEG\n"
    )
    res = engine.run(
        [str(tmp_path / "kernels")], tests_dir=None, rules=["kernel-contract"]
    )
    assert res["active"] == []


# -------------------------------------------------- suppression + baseline


def test_noqa_parsing():
    lines = [
        "x = 1",
        "db.swap_table(t)  # repro: noqa[cas-discipline]",
        "y = 2  # repro: noqa",
        "z = 3  # repro: noqa[a-rule, b-rule]",
    ]
    got = noqa_rules_by_line(lines)
    assert got == {2: {"cas-discipline"}, 3: None, 4: {"a-rule", "b-rule"}}


def test_noqa_suppresses_only_named_rule(tmp_path):
    src = (
        "def f(db, t):\n"
        "    db.swap_table(t)  # repro: noqa[cas-discipline]\n"
        "    db.rollback()  # repro: noqa[some-other-rule]\n"
    )
    f = tmp_path / "mod.py"
    f.write_text(src)
    res = engine.run([str(f)], tests_dir=None, rules=["cas-discipline"])
    assert len(res["suppressed"]) == 1
    assert len(res["active"]) == 1  # wrong rule id in the noqa: still active
    assert engine.exit_code(res) == 1


def test_baseline_matches_on_content_not_line(tmp_path):
    src = "def f(db, t):\n    db.swap_table(t)\n"
    f = tmp_path / "mod.py"
    f.write_text(src)
    res = engine.run([str(f)], tests_dir=None, rules=["cas-discipline"])
    (finding,) = res["active"]
    baseline = Baseline(
        [Baseline.entry_for(finding, "db.swap_table(t)", "test entry")]
    )
    # shift the flagged line down: content-matching must survive the edit
    f.write_text("import os\n\n" + src)
    res = engine.run(
        [str(f)], tests_dir=None, baseline=baseline, rules=["cas-discipline"]
    )
    assert res["active"] == [] and len(res["baselined"]) == 1
    assert engine.exit_code(res) == 0


def test_baseline_stale_entries_reported(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("x = 1\n")
    baseline = Baseline(
        [
            {
                "rule": "cas-discipline",
                "file": "gone.py",
                "content": "db.swap_table(t)",
                "justification": "obsolete",
            }
        ]
    )
    res = engine.run([str(f)], tests_dir=None, baseline=baseline)
    assert res["active"] == []
    assert len(res["stale_baseline"]) == 1


def test_cli_write_baseline_then_clean(tmp_path, monkeypatch):
    from repro.analysis.__main__ import main

    f = tmp_path / "mod.py"
    f.write_text("def f(db, t):\n    db.swap_table(t)\n")
    bl = tmp_path / "bl.json"
    monkeypatch.chdir(tmp_path)
    # dirty without a baseline
    assert main([str(f), "--tests-dir", "", "--no-baseline"]) == 1
    assert main([str(f), "--tests-dir", "", "--baseline", str(bl),
                 "--write-baseline"]) == 0
    data = json.loads(bl.read_text())
    assert data["entries"][0]["justification"] == "TODO: justify"
    # a justification survives a rewrite
    data["entries"][0]["justification"] = "deliberate (test)"
    bl.write_text(json.dumps(data))
    assert main([str(f), "--tests-dir", "", "--baseline", str(bl),
                 "--write-baseline"]) == 0
    assert (
        json.loads(bl.read_text())["entries"][0]["justification"]
        == "deliberate (test)"
    )
    # and now the run is clean
    assert main([str(f), "--tests-dir", "", "--baseline", str(bl)]) == 0


def test_cli_list_rules_and_unknown_rule():
    from repro.analysis.__main__ import main

    assert main(["--list-rules"]) == 0
    assert main(["--rule", "no-such-rule"]) == 2


def test_parse_errors_fail_the_run(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def f(:\n")
    res = engine.run([str(f)], tests_dir=None)
    assert res["errors"] and engine.exit_code(res) == 1


def test_repo_is_clean_under_checked_in_baseline():
    """The merge gate: `python -m repro.analysis src/` exits 0 at HEAD."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src"],
        cwd=REPO,
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ----------------------------------------------------------------- retrace


def test_retrace_monitor_catches_unbucketed_jit():
    import jax

    from repro.analysis.retrace import RetraceMonitor
    from repro.common.bucketing import expected_buckets

    # a fresh jit so no other test has warmed its cache
    f = jax.jit(lambda x: x * 2.0)
    mon = RetraceMonitor()
    assert mon.track("f", f)
    sizes = [1, 2, 3, 4, 5]
    with mon:
        for n in sizes:
            f(np.zeros((n, 4), np.float32))  # ragged: one trace per size
    assert mon.traces()["f"] == len(sizes)
    violations = mon.check({"f": len(expected_buckets(sizes))})
    assert violations and "escaped" in violations[0]


def test_retrace_monitor_clean_on_bucketed_sweep():
    import jax

    from repro.analysis.retrace import RetraceMonitor
    from repro.common.bucketing import expected_buckets, pow2_bucket

    g = jax.jit(lambda x: x + 1.0)
    mon = RetraceMonitor()
    mon.track("g", g)
    sizes = [1, 2, 3, 4, 5, 7, 8]
    with mon:
        for n in sizes:
            g(np.zeros((pow2_bucket(n), 4), np.float32))
    assert mon.check({"g": len(expected_buckets(sizes))}) == []


def test_retrace_monitor_unsupported_degrades():
    from repro.analysis.retrace import RetraceMonitor, supports_cache_size

    def plain(x):
        return x

    assert not supports_cache_size(plain)
    mon = RetraceMonitor()
    assert not mon.track("plain", plain)
    assert mon.unsupported == ["plain"]
    with mon:
        plain(1)
    assert mon.check({"plain": 0}) == []  # untracked: never a violation


def test_route_batch_stays_inside_bucket_set():
    """Acceptance: route_batch traces only the expected pow2 buckets."""
    from repro.analysis.retrace import run_scenario

    report = run_scenario([1, 2, 3, 4, 5, 8, 3], n_tools=32, dim=12, seed=3)
    assert report["violations"] == [], report
    assert report["buckets"] == [1, 2, 4, 8]
    # deltas can undershoot if another test warmed an identical shape, but
    # can never exceed one compile per bucket without a violation firing
    for name, n in report["traces"].items():
        assert n <= len(report["buckets"]), (name, n)


def test_bucketing_helpers():
    from repro.common.bucketing import expected_buckets, pad_amount, pow2_bucket

    assert [pow2_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 16]
    assert pad_amount(5) == 3 and pad_amount(8) == 0
    assert expected_buckets([1, 2, 3, 5, 9, 16]) == [1, 2, 4, 8, 16]


# ---------------------------------------------------------------- lockgraph


def test_lockgraph_catches_inverted_two_lock_order():
    from repro.analysis.lockgraph import LockGraph, TrackedLock

    graph = LockGraph()
    a = TrackedLock(graph, name="lock-a")
    b = TrackedLock(graph, name="lock-b")

    def ab():
        with a:
            with b:
                pass

    def ba():
        with b:
            with a:
                pass

    t1 = threading.Thread(target=ab)
    t1.start()
    t1.join()
    ba()  # sequential: records the inverted order without deadlocking
    cycles = graph.cycles()
    assert cycles, graph.edges
    assert set(cycles[0]) == {"lock-a", "lock-b"}


def test_lockgraph_no_cycle_on_consistent_order():
    from repro.analysis.lockgraph import LockGraph, TrackedLock

    graph = LockGraph()
    a = TrackedLock(graph, name="lock-a")
    b = TrackedLock(graph, name="lock-b")
    for _ in range(3):
        with a:
            with b:
                pass
    assert graph.cycles() == []


def test_lockgraph_detects_dispatch_under_lock():
    import jax.numpy as jnp

    from repro.analysis.lockgraph import LockGraph, TrackedLock, watch_dispatch

    graph = LockGraph()
    lock = TrackedLock(graph, name="hot-lock")
    with watch_dispatch(graph):
        with lock:
            jnp.asarray(np.zeros(3, np.float32))  # the hazard
        jnp.asarray(np.zeros(3, np.float32))  # no lock: fine
    # asarray may route through the (also wrapped) device_put internally —
    # one or more events, all attributed to the held lock, none from the
    # unlocked call
    assert graph.dispatch_events
    assert all(ev["locks"] == ["hot-lock"] for ev in graph.dispatch_events)
    assert "asarray" in {ev["fn"] for ev in graph.dispatch_events}


def test_tracked_lock_supports_condition():
    from repro.analysis.lockgraph import LockGraph, TrackedLock

    graph = LockGraph()
    lock = TrackedLock(graph, name="cond-lock")
    cond = threading.Condition(lock)
    hits = []

    def waiter():
        with cond:
            cond.wait(timeout=5)
            hits.append(True)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cond:
        cond.notify_all()
    t.join(timeout=5)
    assert hits == [True]
    assert graph.held_locks() == []  # fully released on this thread


def test_patch_threading_scopes_the_monkeypatch():
    from repro.analysis.lockgraph import LockGraph, TrackedLock, patch_threading

    graph = LockGraph()
    with patch_threading(graph):
        inside = threading.Lock()
    outside = threading.Lock()
    assert isinstance(inside, TrackedLock)
    assert not isinstance(outside, TrackedLock)


@pytest.mark.slow
def test_live_planes_have_no_cycles_or_dispatch_under_lock():
    """Acceptance: the threaded serve/swap/churn scenario is clean."""
    from repro.analysis.lockgraph import run_scenario

    report = run_scenario(iters=8, seed=1)
    assert report["errors"] == []
    assert report["cycles"] == []
    assert report["dispatch_under_lock"] == []


# ------------------------------------------- daemon-loop health (satellite)


def _mini_world():
    from repro.control import OutcomeStore
    from repro.router.gateway import SemanticRouter
    from repro.router.tooldb import ToolRecord, ToolsDatabase

    db = ToolsDatabase(
        [ToolRecord(i, f"t{i}", np.arange(1, dtype=np.int64), 0) for i in range(4)],
        np.eye(4, dtype=np.float32),
    )
    store = OutcomeStore(n_tools=4, capacity=64)
    router = SemanticRouter(
        db,
        embed_fn=lambda t: np.eye(4, dtype=np.float32)[0],
        k=2,
        outcome_sink=store.append,
    )
    return db, store, router


def _wait_for(predicate, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.01)
    return False


def test_refinement_controller_records_last_loop_error():
    from repro.control.controller import RefinementController

    db, store, router = _mini_world()
    ctl = RefinementController(db, store, embed_batch_fn=lambda b: np.eye(4)[: len(b)])
    assert ctl.last_loop_error is None

    def boom():
        raise RuntimeError("boom (test)")

    ctl.step = boom
    ctl.start(interval_s=0.01)
    try:
        assert _wait_for(lambda: ctl.last_loop_error is not None)
        assert "boom" in repr(ctl.last_loop_error)
        assert any("step failed" in r.reason for r in ctl.reports)
        # a successful step clears the health flag
        ctl.step = lambda: None
        assert _wait_for(lambda: ctl.last_loop_error is None)
    finally:
        ctl.stop()
    router.close()


def test_learning_controller_records_last_loop_error():
    from repro.learn.controller import LearningController

    db, store, router = _mini_world()
    ctl = LearningController(
        db, store, router, embed_batch_fn=lambda b: np.eye(4)[: len(b)]
    )
    assert ctl.last_loop_error is None

    def boom():
        raise RuntimeError("kaput (test)")

    ctl.step = boom
    ctl.start(interval_s=0.01)
    try:
        assert _wait_for(lambda: ctl.last_loop_error is not None)
        assert "kaput" in repr(ctl.last_loop_error)
        ctl.step = lambda: None
        assert _wait_for(lambda: ctl.last_loop_error is None)
    finally:
        ctl.stop()
    router.close()
