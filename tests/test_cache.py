"""Route-cache tests: LSH/LRU/invalidation unit behavior, gateway
integration (hit correctness, mask bypass, mixed batches, swap and stage
invalidation), and the threaded churn race the version stamps exist for —
no stale result may ever be served while swaps/rollbacks/promotions land
concurrently with routing, and the hit rate must recover afterwards."""
import threading

import numpy as np
import pytest

from repro.cache import CacheConfig, CachedRoute, SemanticRouteCache
from repro.obs.events import EventBus
from repro.obs.metrics import MetricsRegistry
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase

D = 32


def _embed(tokens):
    v = np.bincount(np.asarray(tokens, np.int64) % D, minlength=D).astype(np.float32)
    n = np.linalg.norm(v)
    return v / n if n else v


def _embed_batch(token_lists):
    return np.stack([_embed(t) for t in token_lists])


def _unit(rng, n=1):
    v = rng.standard_normal((n, D)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _make_router(n_tools=24, cache=None, metrics=False, bus=None):
    rng = np.random.default_rng(0)
    records = [ToolRecord(i, f"t{i}", np.arange(3), 0) for i in range(n_tools)]
    table = rng.standard_normal((n_tools, D)).astype(np.float32)
    table /= np.linalg.norm(table, axis=1, keepdims=True)
    db = ToolsDatabase(records, table)
    router = SemanticRouter(
        db, embed_fn=_embed, embed_batch_fn=_embed_batch, k=3,
        cache=cache, metrics=metrics, bus=bus,
    )
    return router, db


def _queries(rng, n, lo=0, hi=200):
    return [rng.integers(lo, hi, size=8).astype(np.int64) for _ in range(n)]


# ------------------------------------------------------------------ unit


def test_insert_then_lookup_hits_with_stamps():
    cache = SemanticRouteCache(CacheConfig(threshold=0.95), metrics=False)
    rng = np.random.default_rng(1)
    q = _unit(rng, 3)
    cache.insert_batch(q, [[1, 2], [3, 4], [5, 6]],
                       [[0.9, 0.5], [0.8, 0.4], [0.7, 0.3]],
                       table_version=7, stage_version=2)
    out = cache.lookup_batch(q, table_version=7, stage_version=2)
    assert all(e is not None for e in out)
    assert out[0].tools == (1, 2) and out[0].scores == (0.9, 0.5)
    assert out[0].table_version == 7 and out[0].stage_version == 2
    # a mild perturbation (cosine ~0.995) still hits the same entries
    near = q + 0.05 * _unit(rng, 3)
    near /= np.linalg.norm(near, axis=1, keepdims=True)
    hits = cache.lookup_batch(near, table_version=7, stage_version=2)
    assert sum(e is not None for e in hits) >= 2  # LSH recall is probabilistic
    # an unrelated direction misses
    far = cache.lookup_batch(_unit(rng, 1), table_version=7, stage_version=2)
    assert far == [None]
    assert cache.hit_rate() > 0.0


def test_stamp_mismatch_is_never_served_and_reclaims():
    cache = SemanticRouteCache(metrics=False)
    rng = np.random.default_rng(2)
    q = _unit(rng, 1)
    cache.insert_batch(q, [[1]], [[0.9]], table_version=1, stage_version=1)
    assert len(cache) == cache.config.n_tables
    # either version moving makes the entry dead — and lookup purges it
    assert cache.lookup_batch(q, table_version=2, stage_version=1) == [None]
    assert len(cache) == 0
    cache.insert_batch(q, [[1]], [[0.9]], table_version=2, stage_version=1)
    assert cache.lookup_batch(q, table_version=2, stage_version=2) == [None]
    assert cache.stats["invalidated"] == 2 * cache.config.n_tables


def test_threshold_two_is_supported_never_hit_mode():
    cache = SemanticRouteCache(CacheConfig(threshold=2.0), metrics=False)
    q = _unit(np.random.default_rng(3), 2)
    cache.insert_batch(q, [[1], [2]], [[0.9], [0.8]],
                       table_version=1, stage_version=1)
    # even a byte-identical duplicate misses: cosine 1.0 < 2.0
    out = cache.lookup_batch(q, table_version=1, stage_version=1)
    assert out == [None, None]
    assert cache.stats["misses"] == 2 and cache.stats["hits"] == 0


def test_min_gap_guards_near_tie_decisions():
    cache = SemanticRouteCache(CacheConfig(min_gap=0.05), metrics=False)
    rng = np.random.default_rng(4)
    q = _unit(rng, 2)
    cache.insert_batch(q, [[1, 2], [3, 4]],
                       [[0.90, 0.89], [0.90, 0.70]],  # gaps 0.01 and 0.20
                       table_version=1, stage_version=1)
    out = cache.lookup_batch(q, table_version=1, stage_version=1)
    assert out[0] is None  # near-tie: scored fresh
    assert out[1] is not None and out[1].gap == pytest.approx(0.20)


def test_lru_eviction_bounds_capacity():
    cfg = CacheConfig(n_tables=4, capacity=16)  # 4 distinct decisions
    cache = SemanticRouteCache(cfg, metrics=False)
    rng = np.random.default_rng(5)
    for i in range(10):
        cache.insert_batch(_unit(rng, 1), [[i]], [[0.5]],
                           table_version=1, stage_version=1)
    assert len(cache) <= cfg.capacity
    assert cache.stats["evictions"] > 0


def test_invalidate_and_watch_purge_eagerly():
    bus = EventBus()
    registry = MetricsRegistry()
    cache = SemanticRouteCache(metrics=registry, bus=bus)
    detach = cache.watch(bus)
    rng = np.random.default_rng(6)
    cache.insert_batch(_unit(rng, 2), [[1], [2]], [[0.9], [0.8]],
                       table_version=1, stage_version=0)
    # a table swap event purges everything stamped with the old version
    bus.publish("swap", plane="control", version=2)
    assert len(cache) == 0
    assert registry.counter("route_cache_invalidated_total").value() > 0
    events = bus.events(kind="cache_invalidated")
    assert events and events[-1].details["purged"] == 2 * cache.config.n_tables
    assert events[-1].details["reason"] == "swap"
    # stage events purge by the stage stamp
    cache.insert_batch(_unit(rng, 1), [[3]], [[0.9]],
                       table_version=2, stage_version=0)
    bus.publish("stage_swap", plane="learn", version=1)
    assert len(cache) == 0
    detach()
    cache.insert_batch(_unit(rng, 1), [[4]], [[0.9]],
                       table_version=2, stage_version=1)
    bus.publish("swap", plane="control", version=3)
    assert len(cache) > 0  # detached: no eager purge (stamps still protect)


# ------------------------------------------------------------- integration


def test_gateway_serves_identical_results_from_cache():
    cache = SemanticRouteCache(metrics=False)
    router, _ = _make_router(cache=cache)
    qs = _queries(np.random.default_rng(7), 4)
    first = router.route_batch(qs)
    second = router.route_batch(qs)
    assert all(not r.cache_hit for r in first)
    assert all(r.cache_hit for r in second)
    for a, b in zip(first, second):
        assert a.tools == b.tools
        assert np.allclose(a.scores, b.scores)
        assert (b.table_version, b.stage_version) == (
            a.table_version, a.stage_version)
    router.close()


def test_gateway_masked_batches_bypass_cache():
    cache = SemanticRouteCache(metrics=False)
    router, db = _make_router(cache=cache)
    qs = _queries(np.random.default_rng(8), 2)
    router.route_batch(qs)  # warm the cache
    before = dict(cache.stats)
    masks = np.ones((2, len(db)), dtype=np.float32)
    masked = router.route_batch(qs, candidate_masks=masks)
    assert all(not r.cache_hit for r in masked)
    assert cache.stats == before  # never probed, never inserted
    router.close()


def test_gateway_mixed_hit_miss_batch_preserves_order():
    cache = SemanticRouteCache(metrics=False)
    router, _ = _make_router(cache=cache)
    rng = np.random.default_rng(9)
    qs = _queries(rng, 3)
    baseline = router.route_batch(qs)  # inserts all three
    fresh = _queries(rng, 2, lo=300, hi=900)
    mixed = router.route_batch([qs[1], fresh[0], qs[2], fresh[1]])
    assert [r.cache_hit for r in mixed] == [True, False, True, False]
    assert mixed[0].tools == baseline[1].tools
    assert mixed[2].tools == baseline[2].tools
    # the misses were really scored: they carry k tools with finite scores
    assert len(mixed[1].tools) == router.k
    router.close()


def test_swap_and_stage_bump_invalidate_lazily():
    cache = SemanticRouteCache(metrics=False)
    router, db = _make_router(cache=cache)
    qs = _queries(np.random.default_rng(10), 2)
    router.route_batch(qs)
    assert all(r.cache_hit for r in router.route_batch(qs))
    # content-identical table swap: routing unchanged, version moved —
    # every cached decision must be re-scored, results must agree
    version, live = db.snapshot()
    db.swap_table(live.copy(), expect_current=version)
    post = router.route_batch(qs)
    assert all(not r.cache_hit for r in post)
    assert all(r.table_version == db.table_version for r in post)
    assert all(r.cache_hit for r in router.route_batch(qs))  # re-warmed
    # stage bump (re-deploying the same StageSet) invalidates the same way
    sv, stages = router.stage_set()
    router.set_stages(stages, expect_version=sv)
    post_stage = router.route_batch(qs)
    assert all(not r.cache_hit for r in post_stage)
    assert all(r.stage_version == router.stage_version for r in post_stage)
    router.close()


def test_threaded_churn_never_serves_stale_and_recovers():
    registry = MetricsRegistry()
    cache = SemanticRouteCache(metrics=registry)
    router, db = _make_router(cache=cache, metrics=registry)
    rng = np.random.default_rng(11)
    pools = [_queries(rng, 4) for _ in range(6)]
    stop = threading.Event()
    violations = []

    def serve(worker: int):
        i = 0
        while not stop.is_set() or i < 20:
            batch = pools[(i + worker) % len(pools)]
            tv0, sv0 = db.table_version, router.stage_version
            results = router.route_batch(batch)
            tv1, sv1 = db.table_version, router.stage_version
            for r in results:
                if not (tv0 <= r.table_version <= tv1
                        and sv0 <= r.stage_version <= sv1):
                    violations.append(
                        (worker, r.table_version, r.stage_version,
                         (tv0, sv0), (tv1, sv1)))
            i += 1
            if i >= 300:
                break

    workers = [threading.Thread(target=serve, args=(w,)) for w in range(3)]
    for t in workers:
        t.start()
    # control-plane churn from the main thread: swaps, rollbacks, stage
    # promotions — all content-identical, so any disagreement is a cache bug
    for step in range(30):
        if step % 3 == 0:
            version, live = db.snapshot()
            db.swap_table(live.copy(), expect_current=version)
        elif step % 3 == 1 and db.retained_versions():
            db.rollback(expect_current=db.table_version)
        else:
            sv, stages = router.stage_set()
            router.set_stages(stages, expect_version=sv)
    stop.set()
    for t in workers:
        t.join(timeout=30)
    assert not any(t.is_alive() for t in workers)
    assert violations == []
    # the gateway tripwire never demoted a hit either: the cache's own
    # stamp check caught every dead entry first
    assert registry.counter("route_cache_stale_served_total").value() == 0
    # and the cache still works: hit rate recovers once churn stops
    qs = pools[0]
    router.route_batch(qs)
    assert all(r.cache_hit for r in router.route_batch(qs))
    router.close()


def test_cache_metrics_exported_through_gateway():
    registry = MetricsRegistry()
    cache = SemanticRouteCache(metrics=registry)
    router, _ = _make_router(cache=cache, metrics=registry)
    qs = _queries(np.random.default_rng(12), 3)
    router.route_batch(qs)
    router.route_batch(qs)
    assert registry.counter("route_cache_hits_total").value() == 3
    assert registry.counter("route_cache_misses_total").value() == 3
    assert registry.gauge("route_cache_hit_ratio").value() == pytest.approx(0.5)
    assert registry.gauge("route_cache_size").value() == len(cache)
    # the cache phase span was recorded for both batches
    hist = registry.histogram("route_phase_ms", phase="cache")
    assert hist.count() == 2
    router.close()
