"""Control-plane tests: OutcomeStore ring/counters/masks/persistence,
RefinementController trigger + gate semantics, TableGuard rollback, the
generalized ToolsDatabase version history, and a threaded smoke test of
route_batch concurrent with table swaps."""
import threading
import time

import numpy as np
import pytest

from repro.control import (
    ControllerConfig,
    GuardConfig,
    OutcomeStore,
    RefinementController,
    TableGuard,
)
from repro.core.outcomes import masks_from_stream
from repro.core.refine import RefineConfig
from repro.embedding.bag_encoder import BagEncoder
from repro.router.gateway import OutcomeEvent, SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase


def _event(tokens, tool_id, outcome, ts=0.0):
    return OutcomeEvent(
        query_tokens=np.asarray(tokens, dtype=np.int64),
        tool_id=tool_id,
        outcome=outcome,
        timestamp=ts,
    )


def _db_and_encoder(bench, **kw):
    enc = BagEncoder(bench.vocab)
    records = [
        ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
        for i in range(bench.n_tools)
    ]
    return ToolsDatabase(records, enc.encode(bench.desc_tokens), **kw), enc


# ---------------------------------------------------------------- OutcomeStore


def test_store_ring_eviction_keeps_counters_consistent():
    store = OutcomeStore(n_tools=4, capacity=3)
    for i, (tool, out) in enumerate([(0, 1), (1, 0), (2, 1), (3, 1)]):
        store.append(_event([i], tool, out))
    # capacity 3: the first event (tool 0 positive) was evicted
    assert len(store) == 3
    assert store.total_ingested == 4
    assert store.dropped == 1
    pos, neg = store.tool_counts()
    np.testing.assert_array_equal(pos, [0, 0, 1, 1])
    np.testing.assert_array_equal(neg, [0, 1, 0, 0])


def test_store_dedupes_queries_and_builds_masks():
    store = OutcomeStore(n_tools=3, capacity=100)
    q_a, q_b = [1, 2, 3], [4, 5]
    store.ingest([
        _event(q_a, 0, 1),
        _event(q_a, 1, 0),
        _event(q_b, 2, 1),
        _event(q_a, 1, 1),  # later success on same (query, tool): pos wins
    ])
    batch = store.build_refinement_batch(
        lambda toks: np.ones((len(toks), 8), np.float32)
    )
    assert batch.n_queries == 2 and batch.n_events == 4
    np.testing.assert_array_equal(batch.pos_mask, [[1, 1, 0], [0, 0, 1]])
    assert batch.neg_mask.sum() == 0  # the lone negative was vetoed
    assert (batch.pos_mask * batch.neg_mask).sum() == 0


def test_masks_from_stream_pos_vetoes_neg():
    pos, neg = masks_from_stream(
        query_ids=[0, 0, 1], tool_ids=[2, 2, 0], outcomes=[0, 1, 0],
        n_queries=2, n_tools=3,
    )
    assert pos[0, 2] == 1 and neg[0, 2] == 0
    assert neg[1, 0] == 1 and pos[1, 0] == 0


def test_store_persistence_roundtrip(tmp_path):
    store = OutcomeStore(n_tools=5, capacity=4)
    for i in range(6):  # overflow: 2 evictions
        store.append(_event([i, i + 1], i % 5, i % 2, ts=float(i)))
    path = str(tmp_path / "store")
    store.save(path, step=3)
    restored = OutcomeStore.restore(path)
    assert restored.n_tools == 5 and restored.capacity == 4
    assert len(restored) == len(store) == 4
    assert restored.total_ingested == 6 and restored.dropped == 2
    for a, b in zip(store.snapshot_events(), restored.snapshot_events()):
        np.testing.assert_array_equal(a.query_tokens, b.query_tokens)
        assert (a.tool_id, a.outcome, a.timestamp) == (b.tool_id, b.outcome, b.timestamp)
    np.testing.assert_array_equal(
        np.stack(store.tool_counts()), np.stack(restored.tool_counts())
    )


# ------------------------------------------------------------------- ToolsDB


def test_versioned_rollback_history():
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(6, 8)).astype(np.float32)
    db = ToolsDatabase(
        [ToolRecord(i, f"t{i}", np.arange(2), 0) for i in range(6)],
        emb, history_limit=2,
    )
    tables = {0: db.embeddings.copy()}
    for v in range(1, 4):
        tables[v] = np.roll(emb, v, axis=0)
        db.swap_table(tables[v])
    # history bounded at 2: version 0 evicted
    assert db.retained_versions() == [1, 2]
    with pytest.raises(RuntimeError):
        db.rollback(to_version=0)
    v = db.rollback(to_version=1)  # explicit target skips newer retained v2
    assert v == 4 and db.table_version == 4
    np.testing.assert_array_equal(db.embeddings, tables[1])
    assert db.retained_versions() == []  # v2 was dead lineage, dropped
    with pytest.raises(RuntimeError):
        db.rollback()


def test_default_rollback_targets_most_recent():
    emb = np.eye(4, dtype=np.float32)
    db = ToolsDatabase(
        [ToolRecord(i, f"t{i}", np.arange(1), 0) for i in range(4)], emb
    )
    db.swap_table(np.roll(emb, 1, axis=0))
    db.swap_table(np.roll(emb, 2, axis=0))
    db.rollback()  # default: most recent retained (v1)
    np.testing.assert_array_equal(db.embeddings, np.roll(emb, 1, axis=0))
    assert db.retained_versions() == [0]  # deeper history still available
    db.rollback()
    np.testing.assert_array_equal(db.embeddings, emb)


# ---------------------------------------------------------------- Controller


def _stub_refine(accepted, delta=0.0):
    """A refine_fn stand-in with a deterministic gate decision."""
    import jax.numpy as jnp

    def fn(table, tq, tr, vq, vr, config):
        from repro.core.refine import RefineResult

        return RefineResult(
            embeddings=table + delta,
            accepted=jnp.asarray(accepted),
            recall_before=jnp.asarray(0.5),
            recall_after=jnp.asarray(0.5 + (0.1 if accepted else -0.1)),
            history=None,
        )

    return fn


def _controller_world(small_bench, refine_fn, *, min_events=50, guard=None,
                      clock=None, max_interval_s=300.0):
    db, enc = _db_and_encoder(small_bench)
    store = OutcomeStore(n_tools=len(db), capacity=10_000)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,
    )
    cfg = ControllerConfig(
        min_events=min_events, max_interval_s=max_interval_s,
        min_queries=5, refine=RefineConfig(keep_history=False),
    )
    kw = {} if clock is None else {"clock": clock}
    ctl = RefinementController(
        db, store, enc.encode, routers=[router], config=cfg,
        guard=guard, refine_fn=refine_fn, **kw,
    )
    return db, store, router, ctl


def _serve(router, bench, idx):
    for qi in idx:
        res = router.route(bench.query_tokens[qi])
        for t in res.tools:
            router.record_outcome(
                bench.query_tokens[qi], t, int(t in bench.relevant[qi])
            )


def test_controller_event_count_trigger(small_bench):
    db, store, router, ctl = _controller_world(
        small_bench, _stub_refine(True), min_events=100
    )
    _serve(router, small_bench, small_bench.train_idx[:10])  # 50 events < 100
    rep = ctl.step()
    assert not rep.triggered and not rep.swapped
    assert db.table_version == 0
    _serve(router, small_bench, small_bench.train_idx[10:30])  # now 150 total
    rep = ctl.step()
    assert rep.triggered and rep.swapped and rep.accepted
    assert db.table_version == 1
    assert "swapped v0 -> v1" in rep.reason
    # watermark consumed: no new events -> no re-trigger
    rep = ctl.step()
    assert not rep.triggered


def test_controller_staleness_trigger(small_bench):
    t = [0.0]
    db, store, router, ctl = _controller_world(
        small_bench, _stub_refine(True), min_events=10_000,
        clock=lambda: t[0], max_interval_s=60.0,
    )
    _serve(router, small_bench, small_bench.train_idx[:10])  # far below count
    rep = ctl.step()
    assert not rep.triggered
    t[0] = 61.0  # stale + at least one new event -> trigger
    rep = ctl.step()
    assert rep.triggered and rep.swapped
    t[0] = 130.0  # stale again but no new events -> idle router stays idle
    rep = ctl.step()
    assert not rep.triggered


def test_controller_skips_gate_without_positive_queries(small_bench):
    """A window of failure-only outcomes must not deploy: all-zero relevance
    rows are excluded from recall, so the gate would accept vacuously."""
    db, enc = _db_and_encoder(small_bench)
    store = OutcomeStore(n_tools=len(db))
    ctl = RefinementController(
        db, store, enc.encode,
        config=ControllerConfig(min_events=1, min_queries=1),
        refine_fn=_stub_refine(True),
    )
    store.ingest([_event([i, i + 1], i % len(db), 0) for i in range(30)])
    rep = ctl.step()
    assert rep.triggered and not rep.swapped
    assert "positive queries" in rep.reason
    assert db.table_version == 0


def test_controller_gate_reject_leaves_table_untouched(small_bench):
    db, store, router, ctl = _controller_world(
        small_bench, _stub_refine(False), min_events=50
    )
    before = db.embeddings.copy()
    _serve(router, small_bench, small_bench.train_idx[:30])
    rep = ctl.step()
    assert rep.triggered and rep.accepted is False and not rep.swapped
    assert "gate rejected" in rep.reason
    assert db.table_version == 0
    np.testing.assert_array_equal(db.embeddings, before)


def test_controller_real_refinement_improves_recall(small_bench):
    """End-to-end with the real refine_with_gate: streamed outcomes -> swap
    -> held-out recall through the live router does not degrade."""
    from repro.core.refine import refine_with_gate

    db, enc = _db_and_encoder(small_bench)
    store = OutcomeStore(n_tools=len(db), capacity=50_000)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,
    )
    ctl = RefinementController(
        db, store, enc.encode, routers=[router],
        config=ControllerConfig(min_events=100, min_queries=20),
    )

    def recall(idx):
        hits = 0
        for qi in idx:
            res = router.route(small_bench.query_tokens[qi])
            hits += int(small_bench.relevant[qi][0] in res.tools)
        return hits / len(idx)

    test_idx = small_bench.test_idx[:60]
    before = recall(test_idx)
    _serve(router, small_bench, small_bench.train_idx)
    rep = ctl.step()
    assert rep.triggered
    after = recall(test_idx)
    assert after >= before - 0.02  # gate guarantee (split-noise tolerance)
    if rep.swapped:
        assert db.table_version == 1


def test_guard_rollback_restores_prior_version(small_bench):
    db, enc = _db_and_encoder(small_bench)
    guard = TableGuard(db, GuardConfig(k=5, min_samples=8, tolerance=0.02))
    good = db.embeddings.copy()
    # healthy traffic on v0 (observed ranking hits the relevant tool)
    for _ in range(10):
        guard.observe(0, [1, 2, 3, 4, 5], [1])
    assert guard.check().action == "no_baseline"  # v0 has no predecessor
    # a bad swap lands out-of-band (no note_swap — the bypass case)
    db.swap_table(np.roll(good, 3, axis=0))
    for _ in range(10):
        guard.observe(1, [7, 8, 9, 10, 11], [1])  # misses everywhere
    rep = guard.check()
    assert rep.action == "rolled_back"
    assert rep.table_version == 1 and rep.restored_version == 2
    assert rep.baseline is not None and rep.ndcg < rep.baseline
    np.testing.assert_array_equal(db.embeddings, good)
    # restored table is its own baseline: never judged, never flaps
    for _ in range(10):
        guard.observe(2, [7, 8, 9, 10, 11], [1])
    assert guard.check().action == "no_baseline"


def test_guard_regression_without_history_is_distinct(small_bench):
    """A confirmed regression with nothing to restore must be reported as
    its own alertable state, not conflated with 'nothing to judge'."""
    db, enc = _db_and_encoder(small_bench, history_limit=1)
    guard = TableGuard(db, GuardConfig(min_samples=4, tolerance=0.02))
    for _ in range(5):
        guard.observe(0, [1, 2, 3, 4, 5], [1])
    db.swap_table(np.roll(db.embeddings, 3, axis=0))
    db.rollback()  # history consumed: v2 live, nothing retained
    guard.note_swap(0, 2)  # baseline inherited, but no rollback target
    for _ in range(5):
        guard.observe(2, [7, 8, 9, 10, 11], [1])
    rep = guard.check()
    assert rep.action == "regressed_unrestorable"
    assert rep.baseline is not None and rep.ndcg < rep.baseline
    assert db.table_version == 2  # no rollback happened


def test_guard_rollback_refused_when_table_moved(small_bench):
    """Compare-and-swap rollback: a swap landing after judgement must make
    the guard stand down, never condemn a table it did not evaluate."""
    from repro.router.tooldb import ConflictError

    db, enc = _db_and_encoder(small_bench)
    with pytest.raises(ConflictError):
        db.swap_table(np.roll(db.embeddings, 1, axis=0))
        db.rollback(expect_current=0)  # judged v0, but v1 is live
    guard = TableGuard(db, GuardConfig(min_samples=4, tolerance=0.02))
    # make v1 look judged-bad with a real baseline, then race a swap in
    # before check() by patching rollback to simulate the interleaving
    for _ in range(5):
        guard.observe(0, [1, 2, 3], [1])
    guard.note_swap(0, 1)
    for _ in range(5):
        guard.observe(1, [7, 8, 9], [1])
    real_rollback = db.rollback

    def racing_rollback(*a, **kw):
        # another swap lands between judgement and rollback
        db.swap_table(np.roll(db.embeddings, 2, axis=0))
        return real_rollback(*a, **kw)

    db.rollback = racing_rollback
    try:
        rep = guard.check()
    finally:
        db.rollback = real_rollback
    assert rep.action == "stale"
    assert not guard.rollbacks


def test_controller_cooldown_after_guard_rollback(small_bench):
    db, enc = _db_and_encoder(small_bench)
    guard = TableGuard(db, GuardConfig(min_samples=4, tolerance=0.02))
    store = OutcomeStore(n_tools=len(db))
    ctl = RefinementController(
        db, store, enc.encode,
        config=ControllerConfig(min_events=1, min_queries=1),
        guard=guard, refine_fn=_stub_refine(True),
    )
    for _ in range(5):
        guard.observe(0, [0, 1, 2, 3, 4], [0])
    db.swap_table(np.roll(db.embeddings, 1, axis=0))
    for _ in range(5):
        guard.observe(1, [7, 8, 9, 10, 11], [0])
    _serve_events = [_event([1, 2], 0, 1) for _ in range(10)]
    store.ingest(_serve_events)
    rep = ctl.step()
    assert rep.guard.action == "rolled_back"
    assert not rep.triggered and "cooldown" in rep.reason
    assert db.table_version == 2  # rollback bumped, controller did NOT swap
    # condemned-era evidence purged: the next trigger can't rebuild and
    # re-swap the same bad table from the same window (flap prevention)
    assert len(store) == 0
    rep = ctl.step()
    assert not rep.triggered  # watermark consumed, no fresh events


# ------------------------------------------------------- threaded smoke test


@pytest.mark.slow
def test_route_batch_concurrent_with_swaps(small_bench):
    """Every RouteResult must be internally consistent with ONE table that
    actually served: its table_version's table reproduces its scores."""
    db, enc = _db_and_encoder(small_bench, history_limit=3)
    router = SemanticRouter(db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5)
    rng = np.random.default_rng(0)
    base = db.embeddings.copy()
    tables = {0: base}
    version_lock = threading.Lock()
    stop = threading.Event()

    def churn():
        i = 0
        while not stop.is_set():
            new = np.roll(base, (i % 5) + 1, axis=0)
            with version_lock:
                v = db.swap_table(new)
                tables[v] = new
            i += 1
            time.sleep(0.001)

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        queries = [small_bench.query_tokens[qi] for qi in small_bench.test_idx[:16]]
        q_emb = enc.encode(queries)
        for _ in range(30):
            results = router.route_batch(queries)
            versions = {r.table_version for r in results}
            assert len(versions) == 1  # one snapshot per batch
            v = versions.pop()
            with version_lock:
                table = tables[v]
            sims = q_emb @ table.T
            for j, r in enumerate(results):
                expected = np.sort(sims[j])[::-1][: len(r.scores)]
                np.testing.assert_allclose(
                    np.asarray(r.scores), expected, atol=1e-4,
                    err_msg=f"scores inconsistent with table v{v}",
                )
    finally:
        stop.set()
        t.join()


@pytest.mark.slow
def test_record_outcome_concurrent_with_drain():
    """The locked ring never loses an event to a racing drain."""
    db = ToolsDatabase(
        [ToolRecord(i, f"t{i}", np.arange(1), 0) for i in range(4)],
        np.eye(4, dtype=np.float32),
    )
    router = SemanticRouter(
        db, embed_fn=lambda t: np.ones(4, np.float32), outcome_capacity=100_000
    )
    n_writers, n_each = 4, 2000
    drained = []
    stop = threading.Event()

    def writer(w):
        for i in range(n_each):
            router.record_outcome(np.asarray([w, i]), w, 1)

    def drainer():
        while not stop.is_set():
            drained.extend(router.drain_outcomes())
        drained.extend(router.drain_outcomes())

    d = threading.Thread(target=drainer)
    ws = [threading.Thread(target=writer, args=(w,)) for w in range(n_writers)]
    d.start()
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    d.join()
    assert router.outcomes_dropped == 0
    assert len(drained) == n_writers * n_each
