"""Substrate tests: benchmark generator determinism/structure, LM pipeline,
optimizers, checkpointing, BM25, encoders."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint.msgpack_ckpt import restore_checkpoint, save_checkpoint
from repro.core.baselines import BM25
from repro.data.benchmarks import SUBTASKS, make_metatool_like
from repro.data.lm_data import LMDataConfig, synthetic_lm_batches
from repro.embedding.bag_encoder import BagEncoder, pad_token_lists
from repro.embedding.transformer import EncoderConfig, encode, encoder_param_count, init_encoder


# ----------------------------------------------------------- benchmark data
def test_benchmark_determinism():
    a = make_metatool_like(seed=3, n_tools=40, n_queries=100)
    b = make_metatool_like(seed=3, n_tools=40, n_queries=100)
    assert all((x == y).all() for x, y in zip(a.desc_tokens, b.desc_tokens))
    assert all((x == y).all() for x, y in zip(a.query_tokens, b.query_tokens))
    assert (a.train_idx == b.train_idx).all()
    c = make_metatool_like(seed=4, n_tools=40, n_queries=100)
    assert any((x != y).any() for x, y in zip(a.query_tokens, c.query_tokens))


def test_benchmark_structure(small_bench):
    b = small_bench
    assert b.n_tools == 60 and b.n_queries == 600
    # 70/30 split, disjoint, covering
    assert len(b.train_idx) + len(b.test_idx) == 600
    assert len(np.intersect1d(b.train_idx, b.test_idx)) == 0
    # ground truth always inside the candidate set
    for j in range(b.n_queries):
        assert np.isin(b.relevant[j], b.candidates[j]).all()
    # subtask mix covers all four types
    assert set(np.unique(b.subtask)) == set(range(len(SUBTASKS)))
    # multi-tool queries have >=2 ground-truth tools
    for j in np.flatnonzero(b.subtask == SUBTASKS.index("multi")):
        assert len(b.relevant[j]) >= 2


def test_encoders_agree(small_bench):
    enc = BagEncoder(small_bench.vocab)
    ragged = enc.encode(small_bench.desc_tokens[:8])
    ids, mask = pad_token_lists(small_bench.desc_tokens[:8])
    padded = np.asarray(enc.encode_padded(jnp.asarray(ids), jnp.asarray(mask)))
    np.testing.assert_allclose(ragged, padded, atol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(ragged, axis=1), 1.0, atol=1e-5)


def test_transformer_encoder_is_minilm_shaped():
    cfg = EncoderConfig()
    params = init_encoder(jax.random.PRNGKey(0), cfg)
    n = encoder_param_count(params)
    assert 21e6 < n < 24e6  # ~22M like all-MiniLM-L6-v2
    ids = np.zeros((2, 16), np.int32)
    mask = np.ones((2, 16), np.int32)
    out = encode(params, jnp.asarray(ids), jnp.asarray(mask))
    assert out.shape == (2, 384)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=1), 1.0, atol=1e-5)


def test_bm25_prefers_exact_overlap():
    docs = [np.array([1, 2, 3, 4]), np.array([5, 6, 7, 8]), np.array([1, 9, 10, 11])]
    bm = BM25.fit(docs, vocab_size=16)
    scores = bm.scores([np.array([5, 6])])
    assert scores[0].argmax() == 1
    # rare terms outweigh common ones
    scores2 = bm.scores([np.array([1, 5])])
    assert scores2[0, 1] > scores2[0, 2]  # doc1 has rare 5; docs 0,2 share 1


# ------------------------------------------------------------- LM pipeline
def test_lm_pipeline_deterministic_and_shaped():
    from repro.configs import ARCHITECTURES
    from repro.models.config import reduced

    cfg = reduced(ARCHITECTURES["musicgen-medium"])
    it1 = synthetic_lm_batches(cfg, LMDataConfig(batch_size=2, seq_len=32, seed=1))
    it2 = synthetic_lm_batches(cfg, LMDataConfig(batch_size=2, seq_len=32, seed=1))
    b1, b2 = next(it1), next(it2)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert b1["tokens"].shape == (2, 32, cfg.n_codebooks)
    assert b1["tokens"].max() < cfg.vocab_size


# --------------------------------------------------------------- optimizers
def _quadratic(p):
    return sum(jnp.sum(jnp.square(x - 3.0)) for x in jax.tree.leaves(p))


@pytest.mark.parametrize("name", ["adamw", "adam", "sgd", "adafactor"])
def test_optimizers_minimize_quadratic(name):
    opt = {
        "adamw": lambda: optim.adamw(0.1),
        "adam": lambda: optim.adam(0.1),
        "sgd": lambda: optim.sgd(0.05, momentum=0.9),
        "adafactor": lambda: optim.adafactor(0.5),
    }[name]()
    params = {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    loss0 = float(_quadratic(params))

    @jax.jit
    def step(params, state):
        grads = jax.grad(_quadratic)(params)
        updates, state = opt.update(grads, state, params)
        return optim.apply_updates(params, updates), state

    for _ in range(100):
        params, state = step(params, state)
    assert float(_quadratic(params)) < 0.05 * loss0


def test_grad_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(tree, 1.0)
    assert float(norm) > 1.0
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_schedules_bounded(seed):
    sched = optim.warmup_cosine(1e-3, 10, 100, floor=1e-5)
    step = jnp.asarray(seed)
    lr = float(sched(step))
    assert 0 <= lr <= 1e-3 + 1e-9


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "opt": [np.ones(3, np.int64), {"x": np.float32(2.5)}],
    }
    save_checkpoint(str(tmp_path), 7, tree, meta={"arch": "t"})
    step, restored, meta = restore_checkpoint(str(tmp_path))
    assert step == 7 and meta["arch"] == "t"
    np.testing.assert_array_equal(restored["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(restored["opt"][0], tree["opt"][0])
    # rotation: newer step wins
    save_checkpoint(str(tmp_path), 9, tree)
    assert restore_checkpoint(str(tmp_path))[0] == 9
