"""Paper §7.2-7.3 deployment policy tests."""
from hypothesis import given, settings, strategies as st

from repro.core.deployment import (
    ADAPTER_MIN_LOGS,
    ADAPTER_MIN_TOOLS,
    MLP_DENSITY_THRESHOLD,
    data_density,
    recommend_stages,
)


def test_toolbench_regime_rejects_mlp():
    # 357 train queries x ~2 labels over 2,413 tools: <0.15 examples/tool
    plan = recommend_stages(n_tools=2413, n_outcome_examples=700)
    assert plan.refine and not plan.mlp_reranker
    assert "hurt" in plan.reason or "adapter" in plan.reason


def test_metatool_regime():
    # ~13 examples/tool, 199 tools -> refinement alone per §7.3 (<200 tools)
    plan = recommend_stages(n_tools=199, n_outcome_examples=2600)
    assert plan.refine
    assert not plan.mlp_reranker  # small set: refinement alone


def test_midsize_dense_logs_enables_mlp():
    plan = recommend_stages(n_tools=300, n_outcome_examples=6000)
    assert plan.mlp_reranker


def test_large_set_abundant_logs_enables_adapter():
    plan = recommend_stages(n_tools=2413, n_outcome_examples=50_000)
    assert plan.contrastive_adapter and not plan.mlp_reranker


@given(st.integers(1, 5000), st.integers(0, 100_000))
@settings(max_examples=50, deadline=None)
def test_refinement_always_on_and_stages_consistent(n_tools, n_logs):
    plan = recommend_stages(n_tools, n_logs)
    assert plan.refine  # zero-cost, gate-protected: always deploy
    assert plan.stages >= {"refine"}
    if plan.mlp_reranker:
        assert plan.density >= 10.0


# -------------------------------------------------- density boundary values


def test_mlp_density_threshold_is_inclusive():
    """§7.2: the re-ranker gate is >= 10 examples/tool, exactly at the
    boundary (300 tools avoids the small-set 5x rule)."""
    at = recommend_stages(n_tools=300, n_outcome_examples=int(300 * MLP_DENSITY_THRESHOLD))
    below = recommend_stages(n_tools=300, n_outcome_examples=int(300 * MLP_DENSITY_THRESHOLD) - 1)
    assert at.mlp_reranker and at.density == MLP_DENSITY_THRESHOLD
    assert not below.mlp_reranker


def test_mlp_tool_count_boundary():
    """The re-ranker is only viable up to 500 tools (inclusive)."""
    dense_logs = 500 * 20  # well past the density threshold either way
    assert recommend_stages(500, dense_logs).mlp_reranker
    assert not recommend_stages(501, dense_logs).mlp_reranker


def test_small_set_needs_5x_density():
    """<200 tools: refinement alone captures most gains; the re-ranker needs
    5x the usual density to deploy (§7.3)."""
    n = 199
    just_under = int(n * 5 * MLP_DENSITY_THRESHOLD) - 1
    at = int(n * 5 * MLP_DENSITY_THRESHOLD)
    assert not recommend_stages(n, just_under).mlp_reranker
    assert recommend_stages(n, at).mlp_reranker
    assert recommend_stages(200, int(200 * MLP_DENSITY_THRESHOLD)).mlp_reranker


def test_adapter_boundaries_are_strict():
    """§7.3: |T| > 500 AND > 10K logs — both strict inequalities."""
    assert not recommend_stages(ADAPTER_MIN_TOOLS, ADAPTER_MIN_LOGS + 1).contrastive_adapter
    assert not recommend_stages(ADAPTER_MIN_TOOLS + 1, ADAPTER_MIN_LOGS).contrastive_adapter
    assert recommend_stages(ADAPTER_MIN_TOOLS + 1, ADAPTER_MIN_LOGS + 1).contrastive_adapter


def test_data_density_handles_zero_tools():
    assert data_density(100, 0) == 100.0  # clamped divisor, no crash
    assert recommend_stages(0, 0).refine


# ----------------------------------------------- DeploymentPlan.stages frozen


def test_stages_reflects_exact_flag_combination():
    sparse = recommend_stages(n_tools=2413, n_outcome_examples=700)
    assert sparse.stages == frozenset({"refine"})
    rerank = recommend_stages(n_tools=300, n_outcome_examples=6000)
    assert rerank.stages == frozenset({"refine", "rerank"})
    adapter = recommend_stages(n_tools=2413, n_outcome_examples=50_000)
    assert adapter.stages == frozenset({"refine", "adapter"})


def test_stages_is_reusable_frozenset():
    """stages is a property over the frozen flags: hashable, stable across
    reads, and usable as a set key (the learning plane keys decisions and
    StageSet.active comparisons on it)."""
    plan = recommend_stages(n_tools=300, n_outcome_examples=6000)
    assert plan.stages == plan.stages
    assert hash(plan.stages) == hash(frozenset({"refine", "rerank"}))
    assert {plan.stages: "x"}[frozenset({"refine", "rerank"})] == "x"
    assert "adapter" not in plan.stages
