"""Paper §7.2-7.3 deployment policy tests."""
from hypothesis import given, settings, strategies as st

from repro.core.deployment import recommend_stages


def test_toolbench_regime_rejects_mlp():
    # 357 train queries x ~2 labels over 2,413 tools: <0.15 examples/tool
    plan = recommend_stages(n_tools=2413, n_outcome_examples=700)
    assert plan.refine and not plan.mlp_reranker
    assert "hurt" in plan.reason or "adapter" in plan.reason


def test_metatool_regime():
    # ~13 examples/tool, 199 tools -> refinement alone per §7.3 (<200 tools)
    plan = recommend_stages(n_tools=199, n_outcome_examples=2600)
    assert plan.refine
    assert not plan.mlp_reranker  # small set: refinement alone


def test_midsize_dense_logs_enables_mlp():
    plan = recommend_stages(n_tools=300, n_outcome_examples=6000)
    assert plan.mlp_reranker


def test_large_set_abundant_logs_enables_adapter():
    plan = recommend_stages(n_tools=2413, n_outcome_examples=50_000)
    assert plan.contrastive_adapter and not plan.mlp_reranker


@given(st.integers(1, 5000), st.integers(0, 100_000))
@settings(max_examples=50, deadline=None)
def test_refinement_always_on_and_stages_consistent(n_tools, n_logs):
    plan = recommend_stages(n_tools, n_logs)
    assert plan.refine  # zero-cost, gate-protected: always deploy
    assert plan.stages >= {"refine"}
    if plan.mlp_reranker:
        assert plan.density >= 10.0
