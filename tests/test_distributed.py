"""Multi-device distribution tests (subprocess with forced host devices).

The main pytest process must keep seeing 1 CPU device (conftest guarantee),
so each case runs in a child interpreter with
XLA_FLAGS=--xla_force_host_platform_device_count=4 and asserts parity
between the GSPMD baseline and the shard_map §Perf implementations.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


PRELUDE = """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.common.meshctx import make_mesh, use_mesh
from repro.common.sharding import set_policy
from repro.configs import get_config
from repro.models.config import reduced
from repro.models import model as M
mesh = make_mesh((2, 2), ("data", "model"))
"""


@pytest.mark.slow
def test_shard_map_moe_matches_gspmd_when_capacity_unbound():
    _run(PRELUDE + """
cfg = reduced(get_config("dbrx-132b"), capacity_factor=8.0)
cfg2 = dataclasses.replace(cfg, moe_impl="shard_map")
params = M.init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}
with use_mesh(mesh):
    l1, _ = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
    l2, _ = jax.jit(lambda p, b: M.forward(cfg2, p, b))(params, batch)
err = float(jnp.max(jnp.abs(l1 - l2)))
assert err < 1e-4, err
# gradients agree too
g1 = jax.jit(jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0]))(params)
with use_mesh(mesh):
    g2 = jax.jit(jax.grad(lambda p: M.loss_fn(cfg2, p, batch)[0]))(params)
# relative per-leaf: partitioned reductions reorder float accumulation,
# so large-magnitude leaves (embed scatter-add) carry proportional noise
gerr = max(float(jnp.max(jnp.abs(a - b)) / (1.0 + jnp.max(jnp.abs(a))))
           for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
assert gerr < 1e-2, gerr
print("moe parity ok", err, gerr)
""")


@pytest.mark.slow
def test_seq_sharded_decode_matches_baseline():
    _run(PRELUDE + """
for arch in ("musicgen-medium", "stablelm-3b", "qwen2.5-3b", "hymba-1.5b"):
    cfg = reduced(get_config(arch))
    cfg2 = dataclasses.replace(cfg, decode_attn="seq_shard")
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :S-1]}, max_cache_len=S)
    dec = {"token": toks[:, S-1:S], "pos": jnp.asarray(S-1, jnp.int32)}
    l1, c1 = M.decode_step(cfg, params, cache, dec)
    with use_mesh(mesh):
        set_policy("tp_kvs")
        l2, c2 = jax.jit(lambda p, c, b: M.decode_step(cfg2, p, c, b))(params, cache, dec)
        set_policy("tp")
    err = float(jnp.max(jnp.abs(l1 - l2)))
    kerr = float(jnp.max(jnp.abs(c1["k"] - c2["k"])))
    assert err < 2e-3 and kerr < 1e-3, (arch, err, kerr)
    print(arch, "ok", err, kerr)
""")


@pytest.mark.slow
def test_policies_all_lower_train_step():
    _run(PRELUDE + """
from repro.common.meshctx import cost_analysis_dict
from repro.launch.specs import ShapeCase, input_specs
from repro.launch.state_specs import opt_state_structs
from repro.models.params import param_structs
from repro.training.train_step import TrainConfig, make_train_step
cfg = reduced(get_config("qwen2.5-3b"))
shape = ShapeCase("t", 64, 8, "train")
for policy in ("tp", "tp_sp", "fsdp"):
    set_policy(policy)
    specs = M.make_specs(cfg)
    ps = param_structs(specs, dtype=jnp.float32, mesh=mesh)
    batch = input_specs(cfg, shape, mesh)
    step_fn, _ = make_train_step(cfg, TrainConfig(optimizer="adamw"))
    os_ = opt_state_structs("adamw", specs, mesh)
    with use_mesh(mesh):
        c = jax.jit(step_fn).lower(ps, os_, batch).compile()
    assert cost_analysis_dict(c)["flops"] > 0
    print(policy, "lowers ok")
set_policy("tp")
""")


@pytest.mark.slow
def test_refinement_shards_over_tool_axis():
    """Alg. 1 refinement is embarrassingly parallel in T (DESIGN.md §4):
    sharding the tool table over devices gives identical embeddings."""
    _run(PRELUDE + """
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.refine import refine_embeddings
rng = np.random.default_rng(0)
def unit(x): return x / np.linalg.norm(x, axis=-1, keepdims=True)
qe = jnp.asarray(unit(rng.normal(size=(64, 32))).astype(np.float32))
te = jnp.asarray(unit(rng.normal(size=(16, 32))).astype(np.float32))
rel = np.zeros((64, 16), np.float32)
rel[np.arange(64), rng.integers(0, 16, 64)] = 1.0
rel = jnp.asarray(rel)
ref = refine_embeddings(te, qe, rel)
mesh1 = make_mesh((4,), ("model",))
with use_mesh(mesh1):
    te_s = jax.device_put(te, NamedSharding(mesh1, P("model", None)))
    rel_s = jax.device_put(rel, NamedSharding(mesh1, P(None, "model")))
    out = refine_embeddings(te_s, qe, rel_s)
err = float(jnp.max(jnp.abs(ref - out)))
assert err < 1e-5, err
print("sharded refinement parity ok", err)
""")
