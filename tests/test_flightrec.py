"""Flight-recorder + continuous-profiling tests (ISSUE 9):

* black-box dumps — an injected ``slo_burn`` and an injected controller
  crash each produce exactly ONE crash-consistent dump (debounce dedupes
  the storm), with the triggering event, >=1 linked RouteTrace, and
  (table_version, stage_version) stamps matching the serving router;
* crash consistency — abandoned ``.tmp-`` staging dirs are never listed
  and get swept; retention keeps only the newest ``max_dumps``;
* ``repro-obs replay`` renders a dump offline (trigger + timeline + trace
  spans) straight from the directory, no live server;
* JitProfiler — warmup baselining (first collect counts nothing),
  post-baseline cache growth becomes ``jit_compiles_total{fn=}``, cost
  stamping records FLOPs/bytes WITHOUT growing the compile cache, and the
  counter keys line up exactly with ``default_slos()``'s
  ``jit_retrace_rate`` event keys through a real ring tick;
* SamplingProfiler — samples a watched thread, idempotent stop;
* shutdown discipline — recorder -> ring -> server stop order leaves no
  non-daemon threads and every stop() is idempotent;
* concurrent scrapes — /slo + /traces + /dumps hammered during table
  swaps and stage promotions: every response parses, version stamps are
  self-consistent, no torn reads.
"""
import json
import os
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.control import ControllerConfig, OutcomeStore, RefinementController
from repro.obs import (
    EventBus,
    FlightRecorder,
    HealthMonitor,
    JitProfiler,
    MetricsRegistry,
    ObsServer,
    RouteTracer,
    SamplingProfiler,
    SLOEngine,
    TimeSeriesRing,
    default_slos,
    list_dumps,
    load_dump,
    render_replay,
)
from repro.obs.flightrec import DUMP_FORMAT_VERSION
from repro.obs.report import main as report_main
from repro.obs.slo import SLO, BurnWindow
from repro.router.gateway import SemanticRouter, hot_path_jits
from repro.router.stages import StageSet
from repro.router.tooldb import ToolRecord, ToolsDatabase

D = 16


def _embed(tokens):
    return np.bincount(
        np.asarray(tokens, np.int64) % D, minlength=D
    ).astype(np.float32)


def _embed_batch(token_lists):
    return np.stack([_embed(t) for t in token_lists])


def _make_router(n_tools=12, **kw):
    rng = np.random.default_rng(0)
    records = [ToolRecord(i, f"t{i}", np.arange(3), 0) for i in range(n_tools)]
    table = rng.standard_normal((n_tools, D)).astype(np.float32)
    db = ToolsDatabase(records, table)
    return SemanticRouter(db, _embed, k=3, **kw), db


def _route_some(router, n=4, seed=1):
    rng = np.random.default_rng(seed)
    router.route_batch(
        [rng.integers(0, 40, size=4).astype(np.int64) for _ in range(n)]
    )


def _wait_for(cond, timeout_s=5.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.005)
    return False


class _FakeJit:
    """A `_cache_size`-bearing stand-in so profiler tests don't compile."""

    def __init__(self, size=0):
        self.size = size

    def _cache_size(self):
        return self.size


# ------------------------------------------------------------ trigger dumps


def test_slo_burn_triggers_exactly_one_debounced_dump(tmp_path):
    bus = EventBus()
    reg = MetricsRegistry()
    tracer = RouteTracer(sample_every=1)
    router, db = _make_router(metrics=reg, tracer=tracer, bus=bus)
    try:
        _route_some(router)
        rec = FlightRecorder(
            str(tmp_path / "dumps"), bus=bus, registry=reg, tracer=tracer,
            routers=[router], debounce_s=60.0,
        )
        # an incident storm: burn + the rollback it provokes, close together
        bus.publish("slo_burn", plane="serve", slo="route_p99_budget",
                    sli="latency", burn=25.0)
        bus.publish("rollback", plane="control", condemned_version=1)
        dumps = rec.list()
        assert len(dumps) == 1, "debounce must collapse the storm to one dump"
        assert rec.dumps_written == 1 and rec.dumps_suppressed == 1
        m = dumps[0].manifest
        assert m["format_version"] == DUMP_FORMAT_VERSION
        assert m["reason"] == "slo_burn"
        assert m["trigger"]["slo"] == "route_p99_budget"
        # version stamps must match the live serving composition
        sv, _stages = router.stage_set()
        assert m["serving"] == [{
            "table_version": db.table_version,
            "stage_version": sv,
            "active_stages": [],
        }]
        assert m["n_traces"] >= 1
        d = load_dump(dumps[0].path)
        assert any(e["kind"] == "slo_burn" for e in d["events"])
        for t in d["traces"]:  # linked traces carry the same stamps
            assert t["table_version"] == db.table_version
            assert t["stage_version"] == sv
        # recorder's own counters surface in the registry
        assert reg.counter("flightrec_dumps_total").value() == 1.0
        assert reg.counter("flightrec_suppressed_total").value() == 1.0
    finally:
        router.close()


def test_controller_crash_produces_one_dump_despite_bus_event(tmp_path):
    bus = EventBus()
    router, db = _make_router(metrics=False)
    store = OutcomeStore(n_tools=len(db), capacity=64)
    try:
        rec = FlightRecorder(str(tmp_path / "d"), bus=bus,
                             routers=[router], debounce_s=60.0)
        controller = RefinementController(
            db, store, _embed_batch, routers=[router],
            config=ControllerConfig(min_events=10**9, max_interval_s=10**9),
            bus=bus, flight_recorder=rec,
        )

        def boom():
            raise RuntimeError("injected daemon crash")

        controller.step = boom
        controller.start(interval_s=0.01)
        try:
            assert _wait_for(lambda: rec.dumps_written >= 1)
            # the loop keeps crashing but loop_error is transition-latched
            # and the crash dump is debounced: still exactly one dump
            time.sleep(0.05)
            dumps = rec.list()
            assert len(dumps) == 1
            m = dumps[0].manifest
            assert m["reason"] == "crash"
            assert m["trigger"]["source"] == "RefinementController"
            assert "injected daemon crash" in m["trigger"]["error"]
            # the direct hook fired before the bus event, so the bus-side
            # loop_error was suppressed by debounce, not double-dumped
            assert bus.last("loop_error") is not None
        finally:
            controller.stop()
    finally:
        router.close()


def test_crash_dump_without_bus_and_errors_never_escape(tmp_path):
    # the hook works with no bus wired at all
    rec = FlightRecorder(str(tmp_path / "d"), debounce_s=0.0)
    path = rec.record_crash(ValueError("standalone"), source="unit")
    assert path is not None and os.path.isdir(path)
    m = list_dumps(str(tmp_path / "d"))[0].manifest
    assert m["trigger"]["error_type"] == "ValueError"
    # a recorder whose out_dir write fails must raise to ITS caller only —
    # the controller loop wraps record_crash, verified here by the wrapper
    # contract: dump() cleans its staging dir on failure
    rec2 = FlightRecorder(str(tmp_path / "d2"), debounce_s=0.0)
    os.chmod(tmp_path / "d2", 0o500)
    try:
        if os.access(tmp_path / "d2", os.W_OK):
            pytest.skip("running as privileged user; chmod cannot revoke")
        with pytest.raises(OSError):
            rec2.dump(reason="unwritable")
        assert not [e for e in os.listdir(tmp_path / "d2")]
    finally:
        os.chmod(tmp_path / "d2", 0o700)


def test_retention_and_tmp_sweep(tmp_path):
    out = tmp_path / "dumps"
    rec = FlightRecorder(str(out), debounce_s=0.0, max_dumps=2)
    # an abandoned staging dir from a "crashed" prior process
    stale = out / ".tmp-dump-0-9999-crash"
    stale.mkdir()
    (stale / "manifest.json").write_text("{not json")
    for i in range(4):
        rec.dump(reason=f"r{i}")
    names = sorted(os.listdir(out))
    assert len(names) == 2, names
    assert all(n.startswith("dump-") for n in names)  # tmp dir swept
    assert [d.manifest["reason"] for d in list_dumps(str(out))] == ["r2", "r3"]
    # a dump dir without a readable manifest is not a dump
    bad = out / "dump-0-0000-zzz"
    bad.mkdir()
    assert [d.manifest["reason"] for d in list_dumps(str(out))] == ["r2", "r3"]


def test_replay_renders_trigger_traces_and_versions(tmp_path):
    bus = EventBus()
    reg = MetricsRegistry()
    tracer = RouteTracer(sample_every=1)
    ring = TimeSeriesRing(reg, bus=bus)
    router, db = _make_router(metrics=reg, tracer=tracer, bus=bus)
    try:
        _route_some(router)
        db.swap_table(np.asarray(db.embeddings) * 1.0, expect_current=0)
        _route_some(router, seed=2)
        ring.tick(now=0.0)
        ring.tick(now=1.0)
        rec = FlightRecorder(
            str(tmp_path / "dumps"), bus=bus, registry=reg, tracer=tracer,
            ring=ring, routers=[router], debounce_s=0.0,
        )
        bus.publish("quality_drift", plane="serve", score=9.9, threshold=4.0)
        [dump] = rec.list()
        text = render_replay(dump.path)
        assert "reason: quality_drift" in text
        assert "<-- trigger" in text
        assert "trace #" in text and "table=v1" in text
        assert "serving: table v1" in text
        # the CLI renders the same thing from the dumps root
        rc = report_main(["replay", str(tmp_path / "dumps")])
        assert rc == 0
        d = load_dump(dump.path)
        assert d["timeseries"]["points"], "ring window must be preserved"
    finally:
        router.close()


# ------------------------------------------------------------- jit profiler


def test_profiler_baselines_warmup_then_counts_growth():
    reg = MetricsRegistry()
    fn = _FakeJit(size=3)  # 3 warmup compiles before the profiler attaches
    prof = JitProfiler(jits={"fake": fn}, registry=reg)
    prof.collect()  # baseline
    assert prof.snapshot()["jits"]["fake"]["compiles_total"] == 0
    assert reg.counter("jit_compiles_total", fn="fake").value() == 0.0
    assert reg.gauge("jit_cache_size", fn="fake").value() == 3.0
    fn.size = 5  # two production retraces
    prof.collect()
    snap = prof.snapshot()["jits"]["fake"]
    assert snap["compiles_total"] == 2 and snap["cache_size"] == 5
    assert reg.counter("jit_compiles_total", fn="fake").value() == 2.0
    # unsupported callables degrade, never fail
    prof2 = JitProfiler(jits={"plain": lambda x: x})
    assert prof2.unsupported == ["plain"] and prof2.names() == []


def test_cost_stamping_reports_flops_without_growing_cache():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def mm(a, b):
        return a @ b

    a = jnp.ones((4, 8), jnp.float32)
    mm(a, a.T).block_until_ready()  # warm
    prof = JitProfiler(jits={"mm": mm})
    prof.collect()
    before = mm._cache_size()
    cost = prof.stamp_cost("mm", a, a.T)
    assert mm._cache_size() == before, "stamping must not retrace"
    assert cost.get("flops", 0) > 0
    assert cost["arg_shapes"] == [[4, 8], [8, 4]]
    snap = prof.snapshot()["jits"]["mm"]
    assert snap["cost"]["flops"] == cost["flops"]
    assert snap["compiles_total"] == 0


def test_compile_rate_slo_keys_match_profiler_counters():
    # the contract chain: hot_path_jits() names -> profiler counter labels
    # -> ring point keys -> default_slos() jit_retrace_rate event_keys
    reg = MetricsRegistry()
    fakes = {name: _FakeJit(1) for name in hot_path_jits()}
    prof = JitProfiler(jits=fakes, registry=reg)
    prof.collect()
    ring = TimeSeriesRing(reg)
    point = ring.tick(now=0.0)
    slo = next(s for s in default_slos() if s.name == "jit_retrace_rate")
    for key in slo.event_keys:
        assert key in point.counters, key
    # and the SLO actually fires on sustained post-warmup compile growth
    engine = SLOEngine(
        ring,
        slos=(SLO(
            name="jit_retrace_rate", kind="rate",
            event_keys=slo.event_keys, max_per_hour=60.0,
            windows=(BurnWindow(long_s=10.0, short_s=4.0, factor=1.0),),
        ),),
        bus=(bus := EventBus()),
    )
    for step in range(1, 6):
        fakes["topk_dense"].size += 2  # retracing every tick
        prof.collect()
        ring.tick(now=float(step))
        engine.evaluate(now=float(step))
    assert engine.burning() == ["jit_retrace_rate"]
    assert bus.last("slo_burn") is not None


def test_sampling_profiler_catches_a_busy_thread_and_stops_clean():
    stop = threading.Event()

    def busy_loop():
        while not stop.is_set():
            sum(range(100))

    t = threading.Thread(target=busy_loop, name="busy", daemon=True)
    t.start()
    prof = SamplingProfiler(interval_s=0.001)
    prof.watch_thread(t, "busy")
    try:
        prof.start()
        assert _wait_for(
            lambda: prof.snapshot()["threads"].get("busy") is not None
        )
    finally:
        prof.stop()
        prof.stop()  # idempotent
        stop.set()
        t.join(timeout=5.0)
    snap = prof.snapshot()
    [top] = [s for s in snap["threads"]["busy"][:1]]
    assert "busy_loop@" in top["stack"] and top["samples"] >= 1
    assert snap["n_samples"] >= top["samples"]


# -------------------------------------------------------- shutdown discipline


def test_shutdown_order_leaves_no_leaked_threads():
    baseline = set(threading.enumerate())
    bus = EventBus()
    reg = MetricsRegistry()
    ring = TimeSeriesRing(reg, bus=bus)
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        rec = FlightRecorder(td, bus=bus, registry=reg, ring=ring,
                             debounce_s=60.0)
        ring.start(interval_s=0.01)
        server = ObsServer(registry=reg, bus=bus, recorder=rec).start()
        sampler = SamplingProfiler(interval_s=0.005)
        sampler.watch_thread(ring.thread(), "ring")
        sampler.start()
        assert _wait_for(lambda: len(ring) >= 2)
        # the serve.py signal order: recorder -> daemons -> server
        rec.stop()
        bus.publish("slo_burn", plane="serve", slo="x")  # post-stop: ignored
        assert rec.dumps_written == 0
        sampler.stop()
        ring.stop()
        server.stop()
        # all idempotent
        rec.stop(); sampler.stop(); ring.stop(); server.stop()
    leaked = [
        t for t in set(threading.enumerate()) - baseline
        if t.is_alive() and not t.daemon
    ]
    assert leaked == [], leaked
    # and the telemetry daemons we created are genuinely gone (not merely
    # daemonized): stop() joined them
    ours = [t for t in set(threading.enumerate()) - baseline
            if t.name in ("timeseries-ring", "obs-server", "sampling-profiler")
            and t.is_alive()]
    assert ours == [], ours


# ------------------------------------------------------- concurrent scrapes


def _get_json(url):
    with urllib.request.urlopen(url, timeout=5) as resp:
        return json.loads(resp.read())


def test_concurrent_slo_traces_dumps_scrapes_during_swaps(tmp_path):
    bus = EventBus()
    reg = MetricsRegistry()
    tracer = RouteTracer(sample_every=1)
    ring = TimeSeriesRing(reg, bus=bus)
    engine = SLOEngine(ring, bus=bus, registry=reg)
    router, db = _make_router(metrics=reg, tracer=tracer, bus=bus)
    adapter = {
        "w1": np.zeros((D, 4), np.float32), "b1": np.zeros(4, np.float32),
        "w2": np.zeros((4, D), np.float32), "b2": np.zeros(D, np.float32),
    }
    try:
        _route_some(router)
        ring.tick(now=0.0)
        ring.tick(now=1.0)
        rec = FlightRecorder(str(tmp_path / "d"), bus=bus, registry=reg,
                             tracer=tracer, ring=ring, slo=engine,
                             routers=[router], debounce_s=0.0, max_dumps=32)
        server = ObsServer(
            HealthMonitor(routers=[router], bus=bus, slo=engine),
            reg, bus, slo=engine, tracer=tracer, recorder=rec,
        ).start()
        base = f"http://{server.host}:{server.port}"
        stop = threading.Event()
        errors = []

        def churn():
            # table swaps + stage promotions + dump-producing triggers
            i = 0
            while not stop.is_set():
                i += 1
                db.swap_table(np.asarray(db.embeddings),
                              expect_current=db.table_version)
                sv, _ = router.stage_set()
                router.set_stages(
                    StageSet(adapter_params=adapter, adapter_scale=0.0)
                    if i % 2 else StageSet(),
                    expect_version=sv,
                )
                bus.publish("demotion", plane="learn", condemned_version=i)

        def scrape(path, check):
            while not stop.is_set():
                try:
                    check(_get_json(base + path))
                except Exception as exc:  # noqa: BLE001 — collected
                    errors.append(f"{path}: {exc!r}")
                    return

        def check_slo(snap):
            assert set(snap) >= {"status", "burning", "slos"}

        def check_traces(recs):
            for t in recs:
                # stamps are internally consistent: versions the db/router
                # actually passed through, never torn/interleaved values
                assert 0 <= t["table_version"] <= db.table_version
                assert set(t["spans"]) <= {
                    "embed", "adapter", "score", "rerank", "assemble"
                }

        def check_dumps(body):
            assert body["recorder"]["out_dir"]
            for dmp in body["dumps"]:
                m = dmp["manifest"]
                assert m["format_version"] == DUMP_FORMAT_VERSION
                [s] = m["serving"]
                assert 0 <= s["table_version"] <= db.table_version

        threads = [threading.Thread(target=churn, daemon=True)] + [
            threading.Thread(target=scrape, args=(p, c), daemon=True)
            for p, c in (("/slo", check_slo), ("/traces", check_traces),
                         ("/dumps", check_dumps))
        ]
        for t in threads:
            t.start()
        time.sleep(0.8)
        stop.set()
        for t in threads:
            t.join(timeout=10.0)
        server.stop()
        assert errors == [], errors
        assert rec.dumps_written >= 1  # the demotion triggers actually fired
        # every dump that landed is complete and readable after the fact
        for dmp in rec.list():
            d = load_dump(dmp.path)
            assert d["manifest"]["artifacts"]
    finally:
        router.close()
